//! Quickstart: compress the trained MoE model with MC (PMQ + ODP),
//! compare it against FP32 on the benchmark suite, reload it under an
//! expert residency budget (DESIGN.md §5), serve it over HTTP and
//! stream a generation across a real socket (DESIGN.md §6), then
//! serve under a hard memory ceiling that refuses with `503` instead
//! of OOM-ing (DESIGN.md §8).
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::{
    memmodel, GenerateRequest, McEngine, SamplingParams, Server,
    ServerConfig,
};
use mc_moe::eval::eval_suite;
use mc_moe::moe::{qz, MoeModel, WeightFile};
use mc_moe::odp;
use mc_moe::offload::{self, PrefetchMode, ResidencyPriors};
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::serve::{client as serve_client, HttpServer, ServeConfig};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    let fp = MoeModel::load_f32(&cfg, wf)?;
    println!("loaded {} ({:.1}M params, {:.1} MB fp32)",
             cfg.name, cfg.param_count() as f64 / 1e6,
             memmodel::loading_bytes(&fp) as f64 / 1e6);

    // 1. build the PMQ workbench: one calibration pass + GPTQ zoo
    println!("\n[1/7] calibrating + quantizing (GPTQ at 1/2/3 bits)...");
    let wb = Workbench::build(fp, WorkbenchConfig::default())?;

    // 2. solve the Eq.-4 integer program at a 2.5-bit average budget
    println!("[2/7] solving bit allocation (PMQ, avg 2.5 bits)...");
    let total = 5 * cfg.n_experts / 2;
    let (mc_model, alloc) = wb.compress(Allocator::Pmq, total, PmqHyper::default())?;
    println!("  allocation histogram 1/2/3-bit: {:?}", alloc.histogram());
    println!("  {:.1} MB -> {:.1} MB ({:.1}% of FP32)",
             memmodel::loading_bytes(&wb.fp) as f64 / 1e6,
             memmodel::loading_bytes(&mc_model) as f64 / 1e6,
             100.0 * memmodel::loading_bytes(&mc_model) as f64
                 / memmodel::loading_bytes(&wb.fp) as f64);

    // save the compressed model (v2 segmented layout) with the
    // significance priors the residency cache will reuse in step 5
    let mcqz_path = std::env::temp_dir().join("mc_quickstart.mcqz");
    qz::save_with_priors(&mcqz_path, &mc_model,
                         Some(&ResidencyPriors::from_significance(&wb.sig)))?;
    let expert_bytes = mc_model.expert_storage_bytes();

    // 3. evaluate FP vs MC (+ODP) on the 8-task suite
    println!("[3/7] evaluating...");
    let odp_policy = odp::odp_default(&wb.cal);
    let fp_r = eval_suite(&wb.fp, 40, 0, 4242, None);
    let mc_r = eval_suite(&mc_model, 40, 0, 4242, None);
    let mco_r = eval_suite(&mc_model, 40, 0, 4242, Some(&odp_policy));
    println!("\n{:12} {:>8} {:>8} {:>10}", "task", "FP32", "MC", "MC+ODP");
    for i in 0..8 {
        println!("{:12} {:>7.1}% {:>7.1}% {:>9.1}%",
                 fp_r.rows[i].0, fp_r.rows[i].2 * 100.0,
                 mc_r.rows[i].2 * 100.0, mco_r.rows[i].2 * 100.0);
    }
    println!("{:12} {:>7.2}% {:>7.2}% {:>9.2}%", "AVERAGE",
             fp_r.average * 100.0, mc_r.average * 100.0, mco_r.average * 100.0);
    println!("\nODP pruned {:.1}% of expert compute",
             mco_r.stats.compression_ratio() * 100.0);

    // 4. generate through the unified request API: one GenerateRequest
    // drives the compressed engine, streaming tokens as they decode
    println!("\n[4/7] sampled generation on the MC model...");
    let engine = McEngine::new(mc_model, Some(odp_policy), None);
    let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 16)
        .with_sampling(SamplingParams::temperature(0.8, 4242));
    print!("  tokens:");
    let done = engine.generate_stream(&req, |t| {
        print!(" {t}");
        let _ = std::io::Write::flush(&mut std::io::stdout());
    })?;
    println!("\n  finish={:?}  {}", done.finish, engine.summary());

    // 5. reload under a 50% expert budget: the residency cache serves
    // misses from the segmented file, the predictor prefetches ahead
    println!("\n[5/7] reloading under a 50% expert budget...");
    let budget = expert_bytes / 2;
    let capped = offload::load_cached(&mcqz_path, budget, PrefetchMode::Async)?;
    let capped = McEngine::new(capped, None, None);
    let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 24);
    let out = capped.generate(&req)?;
    println!("  generated {} tokens under a {:.2} MB budget ({:.2} MB of experts)",
             out.tokens.len(), budget as f64 / 1e6, expert_bytes as f64 / 1e6);
    println!("  cache: {}", capped.metrics.cache_summary());
    println!("  {}", capped.summary());

    // 6. serve the compressed model over HTTP and stream a generation
    // across a real socket (SSE), then drain gracefully
    println!("\n[6/7] serving over HTTP (SSE stream + graceful drain)...");
    let served = Arc::new(qz::load(&mcqz_path)?);
    let scfg = ServeConfig { port: 0, max_batch: 2, ..ServeConfig::default() };
    let engine = Server::spawn(served, None, scfg.max_batch);
    let http = HttpServer::bind(engine, scfg)?;
    let addr = http.addr();
    println!("  listening on http://{addr}  (try: curl -N -X POST \
              http://{addr}/v1/generate -d '{{\"prompt\":[1,5,80,3]}}')");
    let body = br#"{"prompt":[1,5,80,3],"max_new_tokens":12,"stop":"max_len"}"#;
    let reply = serve_client::open_generate(
        addr, body, &[("X-Tenant", "quickstart")], Duration::from_secs(60))?;
    match reply {
        serve_client::GenerateReply::Stream(mut sse) => {
            print!("  streamed:");
            while let Some(ev) = sse.next_event()? {
                match ev.name.as_str() {
                    "token" => print!(" {}", ev.data),
                    _ => {
                        println!("\n  terminal frame: {}", ev.name);
                        break;
                    }
                }
            }
        }
        serve_client::GenerateReply::Response(r) => {
            anyhow::bail!("expected an SSE stream, got status {}", r.status);
        }
    }
    http.begin_drain();
    let report = http.serve_until_drained();
    println!("  drained in {:.1} ms (inflight at drain: {})",
             report.drain_ms, report.inflight_at_start);

    // 7. memory-governed serving (DESIGN.md §8): every allocation is
    // accounted against one byte ceiling (`--mem-budget-mb` on the
    // CLI); admission reserves the session's worst-case KV footprint
    // up front, so over budget means 503 + Retry-After, never an OOM
    println!("\n[7/7] serving under a hard memory budget...");
    let served = Arc::new(qz::load(&mcqz_path)?);
    let scfg = ServeConfig { port: 0, max_batch: 2, ..ServeConfig::default() };
    let engine = Server::spawn_cfg(
        served, None,
        ServerConfig {
            max_batch: scfg.max_batch,
            mem_budget: Some(32 << 20), // 32 MiB ceiling
            ..ServerConfig::default()
        });
    let governor = engine.governor().clone();
    let http = HttpServer::bind(engine, scfg)?;
    let addr = http.addr();
    let body = br#"{"prompt":[1,5,80,3],"max_new_tokens":12,"stop":"max_len","stream":false}"#;
    let ok = serve_client::request(addr, "POST", "/v1/generate", &[],
                                   body, Duration::from_secs(60))?;
    println!("  within budget: status {} (worst-case session {:.1} KB \
              reserved up front, released on retire)",
             ok.status,
             governor.worst_case_session_bytes(4, 12, 0) as f64 / 1e3);
    println!("  ledger: {}/{} bytes reserved, pressure {:.0}%, rung {}",
             governor.bytes_reserved(), governor.budget_bytes(),
             100.0 * governor.pressure(), governor.rung());
    http.shutdown();

    // the same request against a 1-byte ceiling: refused at admission
    let served = Arc::new(qz::load(&mcqz_path)?);
    let scfg = ServeConfig { port: 0, max_batch: 2, ..ServeConfig::default() };
    let engine = Server::spawn_cfg(
        served, None,
        ServerConfig {
            max_batch: scfg.max_batch,
            mem_budget: Some(1),
            ..ServerConfig::default()
        });
    let http = HttpServer::bind(engine, scfg)?;
    let refused = serve_client::request(http.addr(), "POST", "/v1/generate",
                                        &[], body, Duration::from_secs(60))?;
    println!("  over budget:   status {} Retry-After {} — shed, not killed",
             refused.status, refused.header("retry-after").unwrap_or("?"));
    http.shutdown();

    std::fs::remove_file(&mcqz_path).ok();
    Ok(())
}
