//! End-to-end serving driver (EXPERIMENTS.md §E2E): load the trained
//! model, compress it with MC, spawn the continuous-batching server,
//! replay a synthetic request trace, and report latency/throughput —
//! FP32 engine vs MC engine vs MC+ODP. Before the trace, one request
//! is streamed token-by-token (the `RequestHandle` iterator) to show
//! the per-token event path, with a second request cancelled
//! mid-decode to show slot reclamation.
//!
//!   cargo run --release --example serve_moe [-- --requests 24 --batch 4]

use std::io::Write;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::{
    memmodel, DecodeOdp, GenerateRequest, SamplingParams, Server,
    StopCondition,
};
use mc_moe::data::{calibration_set, task_sequence, Split};
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::util::cli::Args;
use mc_moe::util::rng::Rng;
use mc_moe::util::stats::percentile;

struct TraceResult {
    name: String,
    wall_s: f64,
    tok_s: f64,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    prune_pct: f64,
    load_mb: f64,
}

fn trace_prompt(rng: &mut Rng) -> Vec<u32> {
    // request = a task prompt (stop at SEP) like a real workload
    let task = rng.below(8);
    let mut prompt = task_sequence(rng, task);
    let sep = prompt.iter().position(|&t| t == 3).unwrap();
    prompt.truncate(sep + 1);
    prompt
}

/// Stream one sampled request token-by-token, cancel another
/// mid-decode: the live view of the per-request event channel.
fn streaming_demo(model: Arc<MoeModel>, max_new: usize) {
    let server = Server::spawn(model, None, 2);
    let mut rng = Rng::new(7);
    let doomed = server.submit(
        GenerateRequest::greedy(trace_prompt(&mut rng), max_new * 4)
            .with_stop(StopCondition::MaxLen));
    let mut live = server.submit(
        GenerateRequest::greedy(trace_prompt(&mut rng), max_new)
            .with_sampling(SamplingParams::temperature(0.8, 42)));
    print!("streamed tokens: ");
    let _ = std::io::stdout().flush();
    for (i, tok) in live.tokens().enumerate() {
        print!("{tok} ");
        let _ = std::io::stdout().flush();
        if i == 2 {
            doomed.cancel(); // frees its batch slot mid-decode
        }
    }
    doomed.cancel(); // idempotent: covers a live stream shorter than 3
    let done = live.completion().expect("completion").clone();
    println!("\nfinish={:?}  ttft={:.2}ms  cancelled-peer={}",
             done.finish, done.ttft_ns as f64 / 1e6,
             doomed.wait().is_none());
    println!("{}", server.metrics.render_text());
    server.shutdown();
}

fn run_trace(name: &str, model: Arc<MoeModel>, odp: Option<DecodeOdp>,
             n_req: usize, batch: usize, max_new: usize) -> TraceResult {
    let load_mb = memmodel::loading_bytes(&model) as f64 / 1e6;
    let server = Server::spawn(model, odp, batch);
    let mut rng = Rng::new(2024);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_req)
        .map(|_| {
            server.submit(GenerateRequest::greedy(
                trace_prompt(&mut rng), max_new))
        })
        .collect();
    let mut ttfts = Vec::new();
    for h in handles {
        let done = h.wait().expect("completion");
        ttfts.push(done.ttft_ns as f32 / 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    let tokens = server.metrics.tokens_generated.load(Ordering::Relaxed) as f64;
    let out = TraceResult {
        name: name.to_string(),
        wall_s: wall,
        tok_s: tokens / wall,
        ttft_p50_ms: percentile(&ttfts, 50.0) as f64,
        ttft_p95_ms: percentile(&ttfts, 95.0) as f64,
        prune_pct: server.metrics.prune_ratio() * 100.0,
        load_mb,
    };
    server.shutdown();
    out
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let n_req = args.usize_or("requests", 24)?;
    let batch = args.usize_or("batch", 4)?;
    let max_new = args.usize_or("max-new", 24)?;

    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    let fp = MoeModel::load_f32(&cfg, wf)?;

    eprintln!("compressing (PMQ 2.5-bit avg)...");
    let wb = Workbench::build(fp, WorkbenchConfig {
        fast_eps: true, ..Default::default()
    })?;
    let (mc, alloc) = wb.compress(Allocator::Pmq, 5 * cfg.n_experts / 2,
                                  PmqHyper::default())?;
    let seqs = calibration_set(17, 4, cfg.max_seq, Split::General);
    let odp = DecodeOdp::calibrate(&wb.fp, &seqs, wb.cal.mu_median(), 0.02);

    eprintln!("live streaming + cancellation on the MC engine:");
    streaming_demo(Arc::new(mc.clone()), max_new);

    eprintln!("replaying trace: {n_req} requests, batch {batch}, {max_new} new tokens each\n");
    let results = vec![
        run_trace("FP32", Arc::new(wb.fp.clone()), None, n_req, batch, max_new),
        run_trace(&format!("MC {:.2}b", alloc.avg_bits()),
                  Arc::new(mc.clone()), None, n_req, batch, max_new),
        run_trace(&format!("MC {:.2}b+ODP", alloc.avg_bits()),
                  Arc::new(mc), Some(odp), n_req, batch, max_new),
    ];
    println!("{:<14} {:>9} {:>9} {:>11} {:>11} {:>8} {:>9}",
             "engine", "wall s", "tok/s", "ttft p50ms", "ttft p95ms",
             "prune%", "load MB");
    let base = results[0].tok_s;
    for r in &results {
        println!("{:<14} {:>9.2} {:>9.1} {:>11.2} {:>11.2} {:>8.1} {:>9.1}  ({:.2}x)",
                 r.name, r.wall_s, r.tok_s, r.ttft_p50_ms, r.ttft_p95_ms,
                 r.prune_pct, r.load_mb, r.tok_s / base);
    }
    Ok(())
}
