//! Fig. 9 driver: Needle-in-a-Haystack retrieval heatmap across
//! (context length × needle depth) for FP32 vs MC-compressed models,
//! then a long-context burst through the memory-governed serving path
//! with the flight recorder armed (DESIGN.md §9) — the exported
//! Chrome trace shows the governor's KV down-quantization firing
//! under pressure alongside the per-layer routing timeline.
//!
//!   cargo run --release --example niah_heatmap [-- --samples 20]
//!   # trace lands in niah_trace.json (override: --trace-out <path>)

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::coordinator::memgov::{
    scratch_estimate_bytes, worst_case_kv_bytes,
};
use mc_moe::coordinator::{
    GenerateRequest, MemReservation, Server, ServerConfig, StopCondition,
};
use mc_moe::eval::eval_niah_grid;
use mc_moe::moe::exec::DEFAULT_PAGE_ROWS;
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::obs;
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::util::cli::Args;

fn print_grid(name: &str, lengths: &[usize], depths: &[f64], g: &[Vec<f64>]) {
    println!("\nFig.9 — NIAH accuracy, {name} (green=1.0)");
    print!("{:>6}", "len\\d");
    for d in depths {
        print!("{d:>6.1}");
    }
    println!();
    for (i, row) in g.iter().enumerate() {
        print!("{:>6}", lengths[i]);
        for v in row {
            print!("{:>6.2}", v);
        }
        println!();
    }
    let avg: f64 = g.iter().flatten().sum::<f64>() / (g.len() * g[0].len()) as f64;
    println!("  mean retrieval: {:.1}%", avg * 100.0);
}

/// Drive the governed serving path under deliberate memory pressure
/// with the flight recorder on, and export the timeline: long-context
/// sessions decode while a probe reservation pushes the governor up
/// its ladder, so the trace carries `kv_pages_downquantized` events
/// next to the routing/decode spans.
fn governed_trace(cfg: &ModelConfig, model: MoeModel, out: &str)
                  -> Result<()> {
    obs::set_enabled(true);
    obs::clear();

    let max_batch = 4usize;
    let clients = 4usize;
    let prompt_len = (cfg.max_seq / 2).max(32);
    let max_new = 16usize.min(cfg.max_seq - prompt_len - 1);
    let worst = worst_case_kv_bytes(prompt_len + max_new, 0,
                                    DEFAULT_PAGE_ROWS, cfg.n_layers,
                                    cfg.d_model);
    // generous enough to admit every session; the probe below — not
    // admission refusals — supplies the pressure
    let budget = scratch_estimate_bytes(cfg, max_batch)
        + clients as u64 * worst * 2;
    let server = Server::spawn_cfg(
        Arc::new(model), None,
        ServerConfig {
            max_batch,
            mem_budget: Some(budget),
            ..ServerConfig::default()
        });
    let gov = server.governor().clone();

    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let prompt: Vec<u32> = (0..prompt_len)
                .map(|t| 1 + ((t * 13 + i * 31) % 101) as u32)
                .collect();
            server.submit(GenerateRequest::greedy(prompt, max_new)
                .with_stop(StopCondition::MaxLen))
        })
        .collect();

    // once KV starts landing, squeeze the budget so the ladder climbs
    // to rung 3 (KV down-quantization) while the sessions decode
    let base = scratch_estimate_bytes(cfg, max_batch);
    let t0 = std::time::Instant::now();
    while gov.bytes_reserved() <= base
        && t0.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    let target = (gov.budget_bytes() as f64 * 0.97) as u64;
    let mut probe: Vec<MemReservation> = Vec::new();
    let probe_deadline = std::time::Instant::now() + Duration::from_secs(5);
    while gov.bytes_reserved() < target
        && std::time::Instant::now() < probe_deadline
    {
        let mut chunk = target.saturating_sub(gov.bytes_reserved());
        while chunk > 1024 {
            if let Some(r) = gov.try_reserve(chunk) {
                probe.push(r);
                break;
            }
            chunk /= 2;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(300));
    drop(probe);
    for h in handles {
        let _ = h.wait();
    }

    let downq = server.metrics.kv_pages_downquantized.load(Relaxed);
    server.shutdown();
    let events = obs::snapshot(None);
    let traced_downq = events.iter()
        .filter(|e| e.name == "kv_pages_downquantized")
        .count();
    let json = obs::chrome::render(&events, "niah_governed");
    std::fs::write(out, &json)?;
    println!(
        "\ngoverned trace: {} events -> {out} \
         (kv_pages_downquantized: {traced_downq} traced, {downq} counted)",
        events.len()
    );
    if traced_downq == 0 {
        println!("  note: pressure never reached rung 3 on this run; \
                  re-run or raise --samples for longer contexts");
    }
    obs::set_enabled(false);
    obs::clear();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let samples = args.usize_or("samples", 15)?;
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    let fp = MoeModel::load_f32(&cfg, wf)?;

    let lengths: Vec<usize> = vec![64, 128, 192, cfg.max_seq];
    let depths = vec![0.1, 0.3, 0.5, 0.7, 0.9];

    let g = eval_niah_grid(&fp, &lengths, &depths, samples, 4242, None);
    print_grid("FP32", &lengths, &depths, &g);

    let wb = Workbench::build(fp, WorkbenchConfig { fast_eps: true, ..Default::default() })?;
    let mut compressed = None;
    for &b in &[2 * cfg.n_experts, 5 * cfg.n_experts / 2] {
        let (m, alloc) = wb.compress(Allocator::Pmq, b, PmqHyper::default())?;
        let g = eval_niah_grid(&m, &lengths, &depths, samples, 4242, None);
        print_grid(&format!("PMQ {:.2}-bit", alloc.avg_bits()),
                   &lengths, &depths, &g);
        compressed = Some(m);
    }

    // long-context serving on the compressed model, traced end to end
    if let Some(m) = compressed {
        let out = args.get_or("trace-out", "niah_trace.json");
        governed_trace(&cfg, m, &out)?;
    }
    Ok(())
}
