//! Fig. 9 driver: Needle-in-a-Haystack retrieval heatmap across
//! (context length × needle depth) for FP32 vs MC-compressed models.
//!
//!   cargo run --release --example niah_heatmap [-- --samples 20]

use anyhow::Result;
use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::eval::eval_niah_grid;
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::util::cli::Args;

fn print_grid(name: &str, lengths: &[usize], depths: &[f64], g: &[Vec<f64>]) {
    println!("\nFig.9 — NIAH accuracy, {name} (green=1.0)");
    print!("{:>6}", "len\\d");
    for d in depths {
        print!("{d:>6.1}");
    }
    println!();
    for (i, row) in g.iter().enumerate() {
        print!("{:>6}", lengths[i]);
        for v in row {
            print!("{:>6.2}", v);
        }
        println!();
    }
    let avg: f64 = g.iter().flatten().sum::<f64>() / (g.len() * g[0].len()) as f64;
    println!("  mean retrieval: {:.1}%", avg * 100.0);
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let samples = args.usize_or("samples", 15)?;
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    let fp = MoeModel::load_f32(&cfg, wf)?;

    let lengths: Vec<usize> = vec![64, 128, 192, cfg.max_seq];
    let depths = vec![0.1, 0.3, 0.5, 0.7, 0.9];

    let g = eval_niah_grid(&fp, &lengths, &depths, samples, 4242, None);
    print_grid("FP32", &lengths, &depths, &g);

    let wb = Workbench::build(fp, WorkbenchConfig { fast_eps: true, ..Default::default() })?;
    for &b in &[2 * cfg.n_experts, 5 * cfg.n_experts / 2] {
        let (m, alloc) = wb.compress(Allocator::Pmq, b, PmqHyper::default())?;
        let g = eval_niah_grid(&m, &lengths, &depths, samples, 4242, None);
        print_grid(&format!("PMQ {:.2}-bit", alloc.avg_bits()),
                   &lengths, &depths, &g);
    }
    Ok(())
}
