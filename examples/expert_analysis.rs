//! Fig. 3 + Fig. 10 driver: expert significance heatmaps (general vs
//! arithmetic calibration) and PMQ bit-allocation visualization.
//!
//!   cargo run --release --example expert_analysis [-- --alloc]

use anyhow::Result;
use mc_moe::config::{artifacts_dir, ModelConfig};
use mc_moe::data::{calibration_set, Split};
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{calibrate, Workbench, WorkbenchConfig};
use mc_moe::util::cli::Args;

fn heat(v: f64, max: f64) -> char {
    let ramp = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let idx = ((v / max.max(1e-9)) * 9.0).round().clamp(0.0, 9.0) as usize;
    ramp[idx]
}

fn print_heatmap(title: &str, data: &[Vec<f64>]) {
    let max = data.iter().flatten().cloned().fold(0.0, f64::max);
    println!("\n{title} (rows=layers, cols=experts, max={max:.3})");
    for (l, row) in data.iter().enumerate() {
        let cells: String = row.iter().map(|&v| heat(v, max)).collect();
        let vals: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
        println!("  L{l:02} |{cells}|  {}", vals.join(" "));
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    let fp = MoeModel::load_f32(&cfg, wf)?;

    // Fig. 3: general-split significance
    let wb = Workbench::build(fp.clone(), WorkbenchConfig::default())?;
    let to64 = |v: &Vec<Vec<f32>>| -> Vec<Vec<f64>> {
        v.iter().map(|r| r.iter().map(|&x| x as f64).collect()).collect()
    };
    print_heatmap("Fig.3a — expert-drop output F-norm (C4-analogue calib)",
                  &to64(&wb.sig.drop_fnorm));
    print_heatmap("Fig.3b — activation weights w_i", &wb.sig.weight);
    print_heatmap("Fig.3c — activation frequencies phi_i", &wb.sig.phi);

    // Fig. 3 bottom: task-specific (MATH-analogue) calibration
    let arith = calibration_set(31, 4, cfg.max_seq, Split::Arith);
    let cal_a = calibrate(&fp, &arith);
    print_heatmap("Fig.3d — frequencies on ARITH split (task-specific)",
                  &cal_a.phi());

    if args.flag("alloc") || true {
        // Fig. 10: allocations across budgets
        println!("\nFig.10 — PMQ allocation (digit = bits assigned)");
        for &b in &[3 * cfg.n_experts / 2, 2 * cfg.n_experts,
                    5 * cfg.n_experts / 2] {
            let (_, alloc) = wb.compress(Allocator::Pmq, b, PmqHyper::default())?;
            println!("avg {:.2} bits:", alloc.avg_bits());
            for (l, row) in alloc.bits.iter().enumerate() {
                let s: String = row.iter().map(|b| b.to_string()).collect();
                println!("  L{l:02} {s}");
            }
        }
    }
    // persist the raw numbers for plotting
    std::fs::write("expert_analysis.json", wb.sig.to_json().to_string())?;
    println!("\nwrote expert_analysis.json");
    Ok(())
}
