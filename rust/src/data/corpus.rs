//! Corpus assembly: contiguous token streams per split (twin of
//! `datagen.pack_stream`). Splits:
//!   * General — 70% task grammars uniformly + 30% Markov text (C4 analogue;
//!     the PMQ calibration set)
//!   * Arith   — modadd-only (MATH analogue; Fig. 3's task-specific calib)
//!   * Text    — Markov channel only (WikiText2-PPL analogue)

use crate::config::{BOS, EOS};
use crate::util::rng::Rng;

use super::tasks::task_sequence;
use super::text::TextChannel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    General,
    Arith,
    Text,
}

impl Split {
    pub fn parse(s: &str) -> Option<Split> {
        match s {
            "general" => Some(Split::General),
            "arith" => Some(Split::Arith),
            "text" => Some(Split::Text),
            _ => None,
        }
    }
}

/// Emit a contiguous stream of exactly `n_tokens` tokens.
pub fn pack_stream(rng: &mut Rng, text: &TextChannel, n_tokens: usize,
                   split: Split) -> Vec<u32> {
    let mut out = Vec::with_capacity(n_tokens + 64);
    while out.len() < n_tokens {
        match split {
            Split::Text => {
                out.push(BOS);
                out.extend(text.sample(rng, 48));
                out.push(EOS);
            }
            Split::Arith => out.extend(task_sequence(rng, 3)),
            Split::General => {
                if rng.f64() < 0.3 {
                    out.push(BOS);
                    out.extend(text.sample(rng, 48));
                    out.push(EOS);
                } else {
                    let task = rng.below(8);
                    out.extend(task_sequence(rng, task));
                }
            }
        }
    }
    out.truncate(n_tokens);
    out
}

/// Fixed-length calibration sequences (the paper's "128 sets of random
/// sequences, each 2048 tokens long" becomes n_seqs x seq_len here).
pub fn calibration_set(seed: u64, n_seqs: usize, seq_len: usize,
                       split: Split) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    let text = TextChannel::new();
    (0..n_seqs)
        .map(|_| pack_stream(&mut rng, &text, seq_len, split))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_exact_length() {
        let mut rng = Rng::new(0);
        let text = TextChannel::new();
        for split in [Split::General, Split::Arith, Split::Text] {
            let s = pack_stream(&mut rng, &text, 1000, split);
            assert_eq!(s.len(), 1000);
            assert!(s.iter().all(|&t| t < 256));
        }
    }

    #[test]
    fn arith_split_is_modadd_only() {
        let mut rng = Rng::new(1);
        let text = TextChannel::new();
        let s = pack_stream(&mut rng, &text, 500, Split::Arith);
        // every BOS is followed by the modadd task tag (5 + 3)
        for (i, &t) in s.iter().enumerate() {
            if t == BOS && i + 1 < s.len() {
                assert_eq!(s[i + 1], 8);
            }
        }
    }

    #[test]
    fn calibration_set_deterministic() {
        let a = calibration_set(7, 4, 128, Split::General);
        let b = calibration_set(7, 4, 128, Split::General);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].len(), 128);
    }

    #[test]
    fn splits_differ() {
        let a = calibration_set(7, 2, 256, Split::General);
        let b = calibration_set(7, 2, 256, Split::Text);
        assert_ne!(a, b);
    }
}
