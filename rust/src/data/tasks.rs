//! The 8 task grammars (rust twin of `datagen.py`) plus the eval-form
//! generators that turn each grammar into an LM-Eval-style multiple-
//! choice sample (prompt + 4 choice continuations, exactly one gold).
//!
//! Task -> paper-benchmark analogue mapping lives in
//! `config::TASK_ANALOGUE` (DESIGN.md §2).

use crate::config::{BOS, EOS, NUM_BASE, NUM_COUNT, QRY, SEP, SYM_BASE, TASK_BASE};
use crate::util::rng::Rng;

fn num(v: u32) -> u32 {
    debug_assert!(v < NUM_COUNT);
    NUM_BASE + v
}

fn sym(v: u32) -> u32 {
    debug_assert!(v < 64);
    SYM_BASE + v
}

/// Number of task grammars (valid task ids are `0..NUM_TASKS`).
pub const NUM_TASKS: usize = 8;

/// Fallible variant of [`gen_task`] for untrusted task ids (e.g. a
/// user-supplied `--task`): `None` instead of a panic when the id is
/// out of range.
pub fn try_gen_task(rng: &mut Rng, task: usize)
                    -> Option<(Vec<u32>, Vec<u32>)> {
    Some(match task {
        0 => gen_copy(rng),
        1 => gen_reverse(rng),
        2 => gen_sortsym(rng),
        3 => gen_modadd(rng),
        4 => gen_recall(rng),
        5 => gen_majority(rng),
        6 => gen_counting(rng),
        7 => gen_induction(rng),
        _ => return None,
    })
}

/// (prompt, answer) in raw tokens, formats identical to datagen.py.
/// Panics on `task >= NUM_TASKS` — internal callers pass ids they
/// derived from `NUM_TASKS`; boundary code (CLI/HTTP) validates first
/// or uses [`try_gen_task`].
pub fn gen_task(rng: &mut Rng, task: usize) -> (Vec<u32>, Vec<u32>) {
    try_gen_task(rng, task)
        .unwrap_or_else(|| panic!("unknown task {task} (0..{NUM_TASKS})"))
}

fn gen_copy(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let seq: Vec<u32> = (0..8).map(|_| sym(rng.below(16) as u32)).collect();
    (seq.clone(), seq)
}

fn gen_reverse(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let seq: Vec<u32> = (0..8).map(|_| sym(rng.below(16) as u32)).collect();
    let mut rev = seq.clone();
    rev.reverse();
    (seq, rev)
}

fn gen_sortsym(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let vals: Vec<u32> = (0..8).map(|_| rng.below(16) as u32).collect();
    let mut sorted = vals.clone();
    sorted.sort_unstable();
    (
        vals.into_iter().map(sym).collect(),
        sorted.into_iter().map(sym).collect(),
    )
}

fn gen_modadd(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let a = rng.below(NUM_COUNT as usize) as u32;
    let b = rng.below(NUM_COUNT as usize) as u32;
    (vec![num(a), num(b)], vec![num((a + b) % NUM_COUNT)])
}

fn gen_recall(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let n = 4;
    let keys = rng.choose_distinct(32, n);
    let vals: Vec<u32> = (0..n).map(|_| 32 + rng.below(32) as u32).collect();
    let mut prompt = Vec::new();
    for (k, v) in keys.iter().zip(&vals) {
        prompt.push(sym(*k as u32));
        prompt.push(sym(*v));
    }
    let q = rng.below(n);
    prompt.push(QRY);
    prompt.push(sym(keys[q] as u32));
    (prompt, vec![sym(vals[q])])
}

fn gen_majority(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let n = 9;
    let choices = rng.choose_distinct(8, 2);
    let k = rng.range(n / 2 + 1, n);
    let mut seq: Vec<u32> = Vec::with_capacity(n);
    for _ in 0..k {
        seq.push(choices[0] as u32);
    }
    for _ in 0..n - k {
        seq.push(choices[1] as u32);
    }
    rng.shuffle(&mut seq);
    (
        seq.into_iter().map(sym).collect(),
        vec![sym(choices[0] as u32)],
    )
}

fn gen_counting(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let n = 10;
    let target = rng.below(8) as u32;
    let seq: Vec<u32> = (0..n).map(|_| rng.below(8) as u32).collect();
    let cnt = seq.iter().filter(|&&s| s == target).count() as u32;
    let mut prompt = vec![sym(target), QRY];
    prompt.extend(seq.into_iter().map(sym));
    (prompt, vec![num(cnt)])
}

fn gen_induction(rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let ab = rng.choose_distinct(16, 2);
    let (a, b) = (ab[0] as u32, ab[1] as u32);
    let mut prompt = vec![sym(a), sym(b)];
    for _ in 0..6 {
        prompt.push(sym(16 + rng.below(16) as u32));
    }
    prompt.push(sym(a));
    (prompt, vec![sym(b)])
}

/// Fallible variant of [`task_sequence`] for untrusted task ids.
pub fn try_task_sequence(rng: &mut Rng, task: usize) -> Option<Vec<u32>> {
    let (prompt, answer) = try_gen_task(rng, task)?;
    let mut seq = vec![BOS, TASK_BASE + task as u32];
    seq.extend(prompt);
    seq.push(SEP);
    seq.extend(answer);
    seq.push(EOS);
    Some(seq)
}

/// Full training-format sequence: [BOS, tag] prompt [SEP] answer [EOS].
/// Panics on an out-of-range task (see [`gen_task`]).
pub fn task_sequence(rng: &mut Rng, task: usize) -> Vec<u32> {
    try_task_sequence(rng, task)
        .unwrap_or_else(|| panic!("unknown task {task} (0..{NUM_TASKS})"))
}

// ---------------------------------------------------------------------------
// Multiple-choice eval form
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct EvalSample {
    pub task: usize,
    /// context fed to the model: [BOS, tag] prompt [SEP]
    pub prompt: Vec<u32>,
    /// candidate continuations; `gold` indexes the correct one
    pub choices: Vec<Vec<u32>>,
    pub gold: usize,
}

/// Perturb one random position of a symbol sequence (stay in-alphabet).
fn perturb(rng: &mut Rng, seq: &[u32]) -> Vec<u32> {
    let mut out = seq.to_vec();
    if out.is_empty() {
        return out;
    }
    let i = rng.below(out.len());
    let old = out[i];
    loop {
        let cand = SYM_BASE + rng.below(16) as u32;
        if cand != old {
            out[i] = cand;
            break;
        }
    }
    out
}

fn dedup_push(choices: &mut Vec<Vec<u32>>, cand: Vec<u32>) -> bool {
    if choices.iter().any(|c| *c == cand) {
        return false;
    }
    choices.push(cand);
    true
}

/// Build a 4-way multiple-choice sample for `task`.
pub fn eval_sample(rng: &mut Rng, task: usize) -> EvalSample {
    let (prompt_raw, answer) = gen_task(rng, task);
    let mut prompt = vec![BOS, TASK_BASE + task as u32];
    prompt.extend(&prompt_raw);
    prompt.push(SEP);

    let mut choices = vec![answer.clone()];
    let mut guard = 0;
    while choices.len() < 4 && guard < 200 {
        guard += 1;
        let cand: Vec<u32> = match task {
            // sequence tasks: perturbations / wrong transforms
            0 | 1 | 2 => match choices.len() {
                1 => {
                    // a structurally-plausible wrong transform
                    let mut alt = answer.clone();
                    alt.reverse();
                    if alt == answer { perturb(rng, &answer) } else { alt }
                }
                _ => perturb(rng, &answer),
            },
            // numeric tasks: off-by-one and random numbers
            3 | 6 => {
                let correct = answer[0] - NUM_BASE;
                let alt = match choices.len() {
                    1 => (correct + 1) % NUM_COUNT,
                    2 => (correct + NUM_COUNT - 1) % NUM_COUNT,
                    _ => rng.below(NUM_COUNT as usize) as u32,
                };
                vec![num(alt)]
            }
            // recall: other values present in the context
            4 => {
                let in_ctx: Vec<u32> = prompt_raw
                    .iter()
                    .copied()
                    .filter(|&t| (SYM_BASE + 32..SYM_BASE + 64).contains(&t))
                    .collect();
                let pick = in_ctx[rng.below(in_ctx.len())];
                vec![pick]
            }
            // majority/induction: other symbols from the context
            5 | 7 => {
                let in_ctx: Vec<u32> = prompt_raw
                    .iter()
                    .copied()
                    .filter(|&t| t >= SYM_BASE)
                    .collect();
                let pick = in_ctx[rng.below(in_ctx.len())];
                vec![pick]
            }
            _ => unreachable!(),
        };
        dedup_push(&mut choices, cand);
    }
    // pad with random symbols if the context had too few distinct values
    while choices.len() < 4 {
        dedup_push(&mut choices, vec![sym(rng.below(64) as u32)]);
    }
    // shuffle, track gold
    let mut order: Vec<usize> = (0..4).collect();
    rng.shuffle(&mut order);
    let gold = order.iter().position(|&i| i == 0).unwrap();
    let choices = order.into_iter().map(|i| choices[i].clone()).collect();
    EvalSample { task, prompt, choices, gold }
}

/// k-shot sample: k solved examples of the same task prepended.
pub fn fewshot_sample(rng: &mut Rng, task: usize, shots: usize) -> EvalSample {
    let mut ctx = Vec::new();
    for _ in 0..shots {
        ctx.extend(task_sequence(rng, task));
    }
    let mut s = eval_sample(rng, task);
    ctx.extend(&s.prompt);
    s.prompt = ctx;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TASK_NAMES;

    #[test]
    fn out_of_range_task_is_none_not_panic() {
        let mut rng = Rng::new(9);
        assert!(try_gen_task(&mut rng, NUM_TASKS).is_none());
        assert!(try_task_sequence(&mut rng, usize::MAX).is_none());
        for task in 0..NUM_TASKS {
            assert!(try_task_sequence(&mut rng, task).is_some());
        }
    }

    #[test]
    fn sequences_well_formed() {
        let mut rng = Rng::new(0);
        for task in 0..8 {
            for _ in 0..50 {
                let seq = task_sequence(&mut rng, task);
                assert_eq!(seq[0], BOS);
                assert_eq!(seq[1], TASK_BASE + task as u32);
                assert_eq!(*seq.last().unwrap(), EOS);
                assert!(seq.contains(&SEP));
                assert!(seq.iter().all(|&t| t < 256));
            }
        }
    }

    #[test]
    fn answers_correct_by_construction() {
        let mut rng = Rng::new(1);
        // modadd: check arithmetic
        for _ in 0..100 {
            let (p, a) = gen_task(&mut rng, 3);
            let (x, y) = (p[0] - NUM_BASE, p[1] - NUM_BASE);
            assert_eq!(a[0] - NUM_BASE, (x + y) % NUM_COUNT);
        }
        // reverse: check reversal
        for _ in 0..20 {
            let (p, a) = gen_task(&mut rng, 1);
            let mut r = p.clone();
            r.reverse();
            assert_eq!(a, r);
        }
        // counting: recount
        for _ in 0..50 {
            let (p, a) = gen_task(&mut rng, 6);
            let target = p[0];
            let cnt = p[2..].iter().filter(|&&t| t == target).count() as u32;
            assert_eq!(a[0], num(cnt));
        }
        // majority: recount
        for _ in 0..50 {
            let (p, a) = gen_task(&mut rng, 5);
            let m = a[0];
            let cm = p.iter().filter(|&&t| t == m).count();
            for &t in &p {
                if t != m {
                    assert!(p.iter().filter(|&&u| u == t).count() < cm);
                }
            }
        }
    }

    #[test]
    fn eval_samples_have_unique_gold() {
        let mut rng = Rng::new(2);
        for task in 0..8 {
            for _ in 0..50 {
                let s = eval_sample(&mut rng, task);
                assert_eq!(s.choices.len(), 4, "task {}", TASK_NAMES[task]);
                // gold choice is distinct from all distractors
                for (i, c) in s.choices.iter().enumerate() {
                    if i != s.gold {
                        assert_ne!(*c, s.choices[s.gold]);
                    }
                }
                assert!(s.prompt.ends_with(&[SEP]));
            }
        }
    }

    #[test]
    fn recall_distractors_from_context() {
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let s = eval_sample(&mut rng, 4);
            for c in &s.choices {
                assert_eq!(c.len(), 1);
            }
        }
    }

    #[test]
    fn fewshot_prepends_examples() {
        let mut rng = Rng::new(4);
        let zero = eval_sample(&mut rng, 3);
        let five = fewshot_sample(&mut rng, 3, 5);
        assert!(five.prompt.len() > zero.prompt.len() + 5 * 4);
        // prompt still ends with SEP for the live question
        assert!(five.prompt.ends_with(&[SEP]));
        // contains 5 EOS from the solved examples
        assert_eq!(five.prompt.iter().filter(|&&t| t == EOS).count(), 5);
    }
}
