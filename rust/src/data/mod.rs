//! Synthetic corpus substrate (rust twin of `python/compile/datagen.py`).
//!
//! The model is *trained* on the python generators and *evaluated* on
//! these; the grammars match exactly (the Markov text table matches bit
//! for bit), so the rust harness scores the model on-distribution.

pub mod corpus;
pub mod niah;
pub mod tasks;
pub mod text;

pub use corpus::{calibration_set, pack_stream, Split};
pub use tasks::{
    eval_sample, task_sequence, try_task_sequence, EvalSample, NUM_TASKS,
};
pub use text::TextChannel;
