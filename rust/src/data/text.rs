//! Zipfian order-1 Markov "text" channel (WikiText2/C4 analogue).
//!
//! The successor table must match `datagen.TextChannel` bit for bit:
//! both sides build it with the same LCG-driven Fisher-Yates at the
//! same fixed seed, so rust evaluates perplexity on exactly the
//! language the python trainer sampled.

use crate::config::{TXT_BASE, TXT_COUNT};
use crate::util::rng::{lcg_next, Rng};

pub const FANOUT: usize = 12;
pub const ZIPF_S: f64 = 1.2;
pub const TABLE_SEED: u64 = 0xC0FFEE;

#[derive(Debug, Clone)]
pub struct TextChannel {
    /// succ[i] = the FANOUT candidate successors of word i
    pub succ: Vec<[u16; FANOUT]>,
    /// Zipf(1.2) probabilities over successor ranks
    pub probs: [f64; FANOUT],
}

impl Default for TextChannel {
    fn default() -> Self {
        Self::new()
    }
}

impl TextChannel {
    pub fn new() -> TextChannel {
        let mut probs = [0.0; FANOUT];
        let mut total = 0.0;
        for (r, p) in probs.iter_mut().enumerate() {
            *p = 1.0 / ((r + 1) as f64).powf(ZIPF_S);
            total += *p;
        }
        for p in probs.iter_mut() {
            *p /= total;
        }
        let n = TXT_COUNT as usize;
        let mut succ = Vec::with_capacity(n);
        let mut state = TABLE_SEED;
        for _ in 0..n {
            // LCG Fisher-Yates, identical to datagen.TextChannel
            let mut perm: Vec<u16> = (0..n as u16).collect();
            for j in (1..n).rev() {
                state = lcg_next(state);
                let k = ((state >> 33) % (j as u64 + 1)) as usize;
                perm.swap(j, k);
            }
            let mut row = [0u16; FANOUT];
            row.copy_from_slice(&perm[..FANOUT]);
            succ.push(row);
        }
        TextChannel { succ, probs }
    }

    /// Sample `n` text tokens (already offset by TXT_BASE).
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<u32> {
        let mut cur = rng.below(TXT_COUNT as usize);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(TXT_BASE + cur as u32);
            let rank = rng.weighted(&self.probs);
            cur = self.succ[cur][rank] as usize;
        }
        out
    }

    /// Transition probability P(next | cur) for analytic entropy tests.
    pub fn transition_prob(&self, cur: usize, next: usize) -> f64 {
        for (rank, &s) in self.succ[cur].iter().enumerate() {
            if s as usize == next {
                return self.probs[rank];
            }
        }
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape_and_range() {
        let t = TextChannel::new();
        assert_eq!(t.succ.len(), TXT_COUNT as usize);
        for row in &t.succ {
            for &s in row {
                assert!((s as u32) < TXT_COUNT);
            }
            // successors within a row are distinct (permutation prefix)
            let mut v: Vec<u16> = row.to_vec();
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), FANOUT);
        }
    }

    #[test]
    fn deterministic_table() {
        let a = TextChannel::new();
        let b = TextChannel::new();
        assert_eq!(a.succ, b.succ);
    }

    #[test]
    fn golden_rows_match_python() {
        // Captured from datagen.TextChannel() — the cross-language
        // contract. If either side's table construction changes, this
        // breaks (and so does the model/eval distribution match).
        let t = TextChannel::new();
        assert_eq!(
            t.succ[0],
            [75, 67, 94, 40, 74, 101, 63, 7, 77, 78, 55, 53]
        );
        let sums: Vec<u64> = (0..4)
            .map(|i| t.succ[i].iter().map(|&v| v as u64).sum())
            .collect();
        assert_eq!(sums, vec![784, 580, 678, 947]);
    }

    #[test]
    fn samples_in_txt_range() {
        let t = TextChannel::new();
        let mut rng = Rng::new(9);
        for tok in t.sample(&mut rng, 500) {
            assert!((TXT_BASE..TXT_BASE + TXT_COUNT).contains(&tok));
        }
    }

    #[test]
    fn zipf_probs_normalized_and_decreasing() {
        let t = TextChannel::new();
        let sum: f64 = t.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        for w in t.probs.windows(2) {
            assert!(w[0] > w[1]);
        }
    }
}
