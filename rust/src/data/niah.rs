//! Needle-in-a-haystack generator (paper Fig. 9 / Tab. 9 "NIAH").
//!
//! A key-value "needle" (recall-task format) is planted at a controlled
//! depth inside a long Markov-text distractor context; the query asks
//! for the value at the end. Scored as 4-way multiple choice over
//! plausible values, like the paper's retrieval-accuracy heatmap.

use crate::config::{BOS, QRY, SEP, SYM_BASE, TASK_BASE};
use crate::util::rng::Rng;

use super::tasks::EvalSample;
use super::text::TextChannel;

/// Build one NIAH sample with total context length `ctx_len` and the
/// needle planted at `depth` in [0, 1].
pub fn niah_sample(rng: &mut Rng, text: &TextChannel, ctx_len: usize,
                   depth: f64) -> EvalSample {
    assert!(ctx_len >= 16, "context too short for a needle");
    let key = rng.below(32) as u32;
    let value = 32 + rng.below(32) as u32;
    let needle = [SYM_BASE + key, SYM_BASE + value];

    // [BOS, recall-tag] distractor..needle..distractor [QRY key SEP]
    let overhead = 2 + needle.len() + 3;
    let hay_len = ctx_len.saturating_sub(overhead);
    let pos = ((hay_len as f64) * depth).round() as usize;
    let mut prompt = vec![BOS, TASK_BASE + 4];
    prompt.extend(text.sample(rng, pos));
    prompt.extend(needle);
    prompt.extend(text.sample(rng, hay_len - pos));
    prompt.push(QRY);
    prompt.push(SYM_BASE + key);
    prompt.push(SEP);

    // 4 value choices: gold + 3 distinct distractors
    let mut choices = vec![vec![SYM_BASE + value]];
    while choices.len() < 4 {
        let alt = 32 + rng.below(32) as u32;
        let cand = vec![SYM_BASE + alt];
        if !choices.contains(&cand) {
            choices.push(cand);
        }
    }
    let mut order: Vec<usize> = (0..4).collect();
    rng.shuffle(&mut order);
    let gold = order.iter().position(|&i| i == 0).unwrap();
    EvalSample {
        task: 4,
        prompt,
        choices: order.into_iter().map(|i| choices[i].clone()).collect(),
        gold,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_present_at_depth() {
        let text = TextChannel::new();
        let mut rng = Rng::new(0);
        for &depth in &[0.0, 0.5, 1.0] {
            let s = niah_sample(&mut rng, &text, 128, depth);
            assert_eq!(s.prompt.len(), 128);
            // key appears twice: needle + query
            let key = s.prompt[s.prompt.len() - 2];
            let occurrences =
                s.prompt.iter().filter(|&&t| t == key).count();
            assert!(occurrences >= 2, "needle key missing");
        }
    }

    #[test]
    fn gold_value_follows_key_in_context() {
        let text = TextChannel::new();
        let mut rng = Rng::new(1);
        let s = niah_sample(&mut rng, &text, 96, 0.4);
        let key = s.prompt[s.prompt.len() - 2];
        let gold_val = s.choices[s.gold][0];
        let pos = s.prompt.iter().position(|&t| t == key).unwrap();
        assert_eq!(s.prompt[pos + 1], gold_val);
    }

    #[test]
    fn distractors_distinct() {
        let text = TextChannel::new();
        let mut rng = Rng::new(2);
        let s = niah_sample(&mut rng, &text, 64, 0.9);
        for i in 0..4 {
            for j in 0..i {
                assert_ne!(s.choices[i], s.choices[j]);
            }
        }
    }
}
