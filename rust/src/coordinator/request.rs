//! The unified request surface: every serving path — `McEngine`
//! (single request), `Batcher` (continuous batching), `Server`
//! (threaded) — consumes the same `GenerateRequest` and produces the
//! same `Completion`, streamed incrementally as `StreamEvent`s over a
//! per-request channel. A `RequestHandle` is the client side of that
//! channel: iterate streamed tokens, `wait()` for the completion, or
//! `cancel()` mid-flight (DESIGN.md §3.1).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, Receiver, RecvTimeoutError, Sender, TryRecvError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::EOS;

/// How to pick the next token from the logits. `Default` is greedy
/// (argmax); any `temperature > 0` enables Gumbel-max sampling with
/// optional top-k / top-p truncation, deterministically seeded.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax; > 0.0 = sample from logits/temperature
    pub temperature: f32,
    /// keep only the k highest logits before sampling (0 = off)
    pub top_k: usize,
    /// keep the smallest prefix of the sorted distribution whose
    /// cumulative probability reaches p (1.0 = off)
    pub top_p: f32,
    /// per-request RNG seed; same seed + same logits = same tokens
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 1 }
    }
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams::default()
    }

    pub fn temperature(temp: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature: temp, seed, ..Default::default() }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// When generation ends (besides `max_new_tokens`, which always caps).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum StopCondition {
    /// stop when the model emits EOS (the classic default)
    #[default]
    Eos,
    /// stop on any token in the set (EOS only if listed)
    StopTokens(Vec<u32>),
    /// never stop early: run to max_new_tokens / KV exhaustion
    MaxLen,
}

impl StopCondition {
    /// Does emitting `token` end the request?
    pub fn hits(&self, token: u32) -> bool {
        match self {
            StopCondition::Eos => token == EOS,
            StopCondition::StopTokens(set) => set.contains(&token),
            StopCondition::MaxLen => false,
        }
    }
}

/// Admission priority: higher classes are admitted first; FIFO within
/// a class (no preemption of already-running sessions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    High = 0,
    #[default]
    Normal = 1,
    Low = 2,
}

/// The one request type every serving path consumes.
#[derive(Debug, Clone, Default)]
pub struct GenerateRequest {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    pub stop: StopCondition,
    pub priority: Priority,
    /// wall-clock budget measured from admission; `None` defers to the
    /// server's configured default (which may also be unlimited)
    pub deadline: Option<Duration>,
    /// Memory-governor grant (reservation + optional shared prefix)
    /// attached by whoever admitted the request — the HTTP front end
    /// reserves at the connection layer so over-budget requests 503
    /// before touching the batcher; paths that skip it leave `None`
    /// and the batcher reserves at admission instead. `Arc` because
    /// requests are `Clone`; the underlying reservation releases when
    /// the last holder (the retired session) drops.
    pub grant: Option<Arc<crate::coordinator::memgov::SessionGrant>>,
}

impl GenerateRequest {
    /// Greedy request with default stop/priority — the common case.
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenerateRequest {
        GenerateRequest { prompt, max_new_tokens, ..Default::default() }
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> GenerateRequest {
        self.sampling = sampling;
        self
    }

    pub fn with_stop(mut self, stop: StopCondition) -> GenerateRequest {
        self.stop = stop;
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> GenerateRequest {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> GenerateRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// Why a completion ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// a `StopCondition` token was emitted (EOS or a stop-set member)
    Stop(u32),
    /// `max_new_tokens` reached, or the KV cache ran out of rows
    MaxTokens,
    Cancelled,
    /// invalid request (empty prompt) — the engine path returns an
    /// error for the same input; the batched paths report it here
    Rejected,
    /// the request's wall-clock deadline passed (or the watchdog found
    /// the stream stalled) before generation finished; partial tokens
    /// are still delivered in the completion
    DeadlineExceeded,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    pub ttft_ns: u64,
    pub total_ns: u64,
}

/// Incremental per-request events: one `Token` per decode step as the
/// fused batcher produces it, terminated by exactly one `Done` or
/// `Cancelled`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    Token(u32),
    Done(Completion),
    Cancelled { id: u64 },
}

/// Progress/terminal bookkeeping shared between the batcher, the
/// watchdog, and whoever holds ticket clones. Every field is written
/// through `RequestTicket` methods so the invariants hold no matter
/// which thread observes them.
#[derive(Debug, Default)]
pub struct TicketState {
    /// a terminal event (`Done`/`Cancelled`) has been sent
    terminated: AtomicBool,
    /// lifetime events sent on the stream (the watchdog's liveness
    /// signal: a stream whose count stops moving is stalled)
    events: AtomicU64,
    /// the watchdog/batcher decided this request ran out of time; the
    /// retiring path reports `DeadlineExceeded` instead of `Cancelled`
    deadline_exceeded: AtomicBool,
    /// claimed by whichever thread sends the terminal event, so the
    /// batcher and the watchdog never double-send or double-count
    terminal_claimed: AtomicBool,
}

/// Server/batcher side of a request: where to stream events, the flag
/// the client's `cancel()` raises, and shared progress state the
/// watchdog reads.
#[derive(Debug, Clone)]
pub struct RequestTicket {
    pub id: u64,
    pub stream: Sender<StreamEvent>,
    pub cancel: Arc<AtomicBool>,
    pub state: Arc<TicketState>,
}

impl RequestTicket {
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Raise the cancel flag from the server side (the watchdog uses
    /// this to evict a request that blew its deadline or stalled).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Best-effort send (the client may have dropped its handle).
    pub fn send(&self, ev: StreamEvent) {
        match ev {
            StreamEvent::Done(_) | StreamEvent::Cancelled { .. } => {
                self.state.terminated.store(true, Ordering::Release);
            }
            StreamEvent::Token(_) => {}
        }
        self.state.events.fetch_add(1, Ordering::Relaxed);
        let _ = self.stream.send(ev);
    }

    /// Has a terminal event been sent on this stream?
    pub fn terminated(&self) -> bool {
        self.state.terminated.load(Ordering::Acquire)
    }

    /// Lifetime events sent (tokens + terminal).
    pub fn events(&self) -> u64 {
        self.state.events.load(Ordering::Relaxed)
    }

    /// Mark the request as out of time; the retiring path turns this
    /// into a `DeadlineExceeded` completion rather than `Cancelled`.
    pub fn set_deadline_exceeded(&self) {
        self.state.deadline_exceeded.store(true, Ordering::Relaxed);
    }

    pub fn deadline_exceeded(&self) -> bool {
        self.state.deadline_exceeded.load(Ordering::Relaxed)
    }

    /// One-shot claim of the right to send the terminal event. The
    /// batcher claims it when retiring normally; the watchdog claims
    /// it only if the batcher never got there, so exactly one terminal
    /// `Done`/`Cancelled` reaches the client.
    pub fn claim_terminal(&self) -> bool {
        self.state
            .terminal_claimed
            .compare_exchange(
                false,
                true,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }
}

/// Client side of a submitted request.
pub struct RequestHandle {
    pub id: u64,
    cancel: Arc<AtomicBool>,
    rx: Receiver<StreamEvent>,
    done: Option<Completion>,
    cancelled: bool,
    /// the server dropped the stream without a terminal event
    disconnected: bool,
}

/// Create the two halves of a request's stream.
pub fn request_channel(id: u64) -> (RequestTicket, RequestHandle) {
    let (tx, rx) = channel();
    let cancel = Arc::new(AtomicBool::new(false));
    let ticket = RequestTicket {
        id,
        stream: tx,
        cancel: cancel.clone(),
        state: Arc::new(TicketState::default()),
    };
    let handle = RequestHandle {
        id,
        cancel,
        rx,
        done: None,
        cancelled: false,
        disconnected: false,
    };
    (ticket, handle)
}

impl RequestHandle {
    /// Raise the cancel flag; the serving loop retires the session at
    /// its next step and replies with `StreamEvent::Cancelled`.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Record a terminal event's state so both the blocking and
    /// non-blocking receive paths stay in sync.
    fn note(&mut self, ev: &StreamEvent) {
        match ev {
            StreamEvent::Done(c) => self.done = Some(c.clone()),
            StreamEvent::Cancelled { .. } => self.cancelled = true,
            StreamEvent::Token(_) => {}
        }
    }

    /// Has the stream terminated (Done, Cancelled, or server gone)?
    /// Polling clients should stop once this is true.
    pub fn is_terminated(&self) -> bool {
        self.done.is_some() || self.cancelled || self.disconnected
    }

    /// Next event, blocking. `None` once the stream has terminated
    /// (after `Done`/`Cancelled` or if the server went away).
    pub fn next_event(&mut self) -> Option<StreamEvent> {
        if self.is_terminated() {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                self.note(&ev);
                Some(ev)
            }
            Err(_) => {
                self.disconnected = true;
                None
            }
        }
    }

    /// Non-blocking variant of `next_event`: `None` means "no event
    /// yet" until `is_terminated()` reports the stream is over.
    pub fn try_next_event(&mut self) -> Option<StreamEvent> {
        if self.is_terminated() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                self.note(&ev);
                Some(ev)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.disconnected = true;
                None
            }
        }
    }

    /// Blocking iterator over streamed tokens; ends at `Done` or
    /// `Cancelled` (query `completion()`/`was_cancelled()` after).
    pub fn tokens(&mut self) -> TokenIter<'_> {
        TokenIter { handle: self }
    }

    /// Drain the stream to termination; `Some(completion)` unless the
    /// request was cancelled or the server dropped the stream.
    pub fn wait(mut self) -> Option<Completion> {
        while self.next_event().is_some() {}
        // clone rather than move: `RequestHandle: Drop` forbids moving
        // a field out of `self`
        self.done.clone()
    }

    /// `wait` with a deadline: blocks until the stream terminates or
    /// `timeout` elapses. Returns the completion if the request
    /// finished; `None` on timeout, cancellation, or disconnect
    /// (`is_terminated()` distinguishes a timeout — still false —
    /// from a terminated stream). The handle stays usable, so callers
    /// can keep waiting or `cancel()` after a timeout.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Completion> {
        let deadline = Instant::now() + timeout;
        while !self.is_terminated() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(ev) => self.note(&ev),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    self.disconnected = true;
                }
            }
        }
        self.done.clone()
    }

    /// The completion, if the stream has already delivered `Done`.
    pub fn completion(&self) -> Option<&Completion> {
        self.done.as_ref()
    }

    pub fn was_cancelled(&self) -> bool {
        self.cancelled
    }
}

impl Drop for RequestHandle {
    /// A handle dropped before its stream terminated means the client
    /// walked away mid-request (or never read it): raise the cancel
    /// flag so the serving loop retires the session at its next step
    /// and frees the batch slot, instead of decoding tokens nobody
    /// will ever receive. Dropping after `Done`/`Cancelled`/disconnect
    /// is a no-op, and for an already-retired request the raised flag
    /// is never read — so this is safe on every exit path.
    fn drop(&mut self) {
        if !self.is_terminated() {
            self.cancel.store(true, Ordering::Relaxed);
        }
    }
}

pub struct TokenIter<'a> {
    handle: &'a mut RequestHandle,
}

impl Iterator for TokenIter<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        loop {
            match self.handle.next_event()? {
                StreamEvent::Token(t) => return Some(t),
                StreamEvent::Done(_) | StreamEvent::Cancelled { .. } => {
                    return None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_condition_semantics() {
        assert!(StopCondition::Eos.hits(EOS));
        assert!(!StopCondition::Eos.hits(7));
        let set = StopCondition::StopTokens(vec![7, 9]);
        assert!(set.hits(7) && set.hits(9));
        assert!(!set.hits(EOS), "EOS only stops the set if listed");
        assert!(!StopCondition::MaxLen.hits(EOS));
    }

    #[test]
    fn handle_streams_tokens_then_done() {
        let (ticket, mut handle) = request_channel(3);
        ticket.send(StreamEvent::Token(10));
        ticket.send(StreamEvent::Token(11));
        ticket.send(StreamEvent::Done(Completion {
            id: 3,
            tokens: vec![10, 11],
            finish: FinishReason::MaxTokens,
            ttft_ns: 1,
            total_ns: 2,
        }));
        let toks: Vec<u32> = handle.tokens().collect();
        assert_eq!(toks, vec![10, 11]);
        assert_eq!(handle.completion().unwrap().tokens, vec![10, 11]);
        assert!(!handle.was_cancelled());
    }

    #[test]
    fn handle_wait_sees_cancellation() {
        let (ticket, handle) = request_channel(4);
        handle.cancel();
        assert!(ticket.cancelled());
        ticket.send(StreamEvent::Cancelled { id: 4 });
        assert!(handle.wait().is_none());
    }

    #[test]
    fn dropped_server_terminates_stream() {
        let (ticket, mut handle) = request_channel(9);
        ticket.send(StreamEvent::Token(1));
        drop(ticket);
        // buffered events still drain, then the drop is detected
        assert!(matches!(handle.try_next_event(),
                         Some(StreamEvent::Token(1))));
        assert!(handle.try_next_event().is_none());
        assert!(handle.is_terminated());
        assert!(handle.completion().is_none());
    }

    #[test]
    fn dropping_live_handle_raises_cancel() {
        let (ticket, handle) = request_channel(12);
        assert!(!ticket.cancelled());
        drop(handle);
        assert!(ticket.cancelled(), "abandoned handle must cancel");
    }

    #[test]
    fn dropping_finished_handle_does_not_cancel() {
        let (ticket, mut handle) = request_channel(13);
        ticket.send(StreamEvent::Done(Completion {
            id: 13,
            tokens: vec![],
            finish: FinishReason::MaxTokens,
            ttft_ns: 1,
            total_ns: 1,
        }));
        while handle.next_event().is_some() {}
        drop(handle);
        assert!(!ticket.cancelled(), "clean finish must not flag cancel");
    }

    #[test]
    fn priority_orders() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
    }

    #[test]
    fn ticket_state_tracks_progress_and_terminal() {
        let (ticket, mut handle) = request_channel(21);
        assert_eq!(ticket.events(), 0);
        assert!(!ticket.terminated());
        ticket.send(StreamEvent::Token(5));
        assert_eq!(ticket.events(), 1);
        assert!(!ticket.terminated());
        // the terminal claim is one-shot across clones
        let clone = ticket.clone();
        assert!(ticket.claim_terminal());
        assert!(!clone.claim_terminal(), "second claimant must lose");
        ticket.send(StreamEvent::Done(Completion {
            id: 21,
            tokens: vec![5],
            finish: FinishReason::DeadlineExceeded,
            ttft_ns: 1,
            total_ns: 2,
        }));
        assert!(ticket.terminated());
        assert_eq!(ticket.events(), 2);
        // server-side cancel raises the same flag the client uses
        ticket.set_deadline_exceeded();
        assert!(clone.deadline_exceeded(), "state is shared via Arc");
        clone.cancel();
        assert!(ticket.cancelled());
        let done = handle.wait_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert_eq!(done.tokens, vec![5]);
    }
}
