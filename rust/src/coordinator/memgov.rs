//! The memory governor (DESIGN.md §8): one byte ceiling for every
//! large allocation class — KV pages, the expert residency budget,
//! scratch arenas — with reservation-based admission and a reversible
//! degradation ladder instead of OOM.
//!
//! **Reservation protocol.** Admission calls [`MemoryGovernor::
//! admit_session`] *before* a session is built: the worst-case page
//! footprint of `prompt + max_new_tokens` (minus any shared prefix) is
//! reserved atomically against the ceiling, or the request is refused
//! with the bytes it would have needed (the serve tier maps that to
//! `503` + backlog-scaled `Retry-After`). The reservation is RAII
//! ([`MemReservation`]): dropping the grant — session retired, request
//! failed, client vanished — returns every byte, so
//! `bytes_reserved` exactly re-balances after each session
//! (`tests/memgov.rs` property-checks this invariant).
//!
//! **Prefix sharing (CoW).** Published prompt prefixes are keyed by
//! `kvcache::prefix_hash` and verified by token equality; a hit means
//! the new session attaches the shared read-only rows and only
//! reserves pages for its private tail. Idle prefixes (refcount 1 —
//! the registry's own) are evicted at rung 3.
//!
//! **Degradation ladder** (pressure = reserved/budget, 0.05
//! hysteresis on the way down; every rung has a counter and reverses
//! when pressure lifts):
//!
//! | rung | threshold | action |
//! |------|-----------|--------|
//! | 1 | 0.50 | pause speculative expert prefetch |
//! | 2 | 0.70 | halve the effective expert-cache budget |
//! | 3 | 0.85 | evict idle shared prefixes; down-quantize low-importance KV pages (Eq.-6 maps) |
//! | 4 | 0.95 | defer admission of `Priority::Low` sessions |
//!
//! Fault injection: `MC_FAULTS` `oom=P` makes [`try_reserve`]
//! deterministically fail, so the whole refusal path is testable
//! without actually exhausting memory.
//!
//! [`try_reserve`]: MemoryGovernor::try_reserve

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::config::ModelConfig;
use crate::moe::exec::kvcache::{prefix_hash, SharedPrefix, DEFAULT_PAGE_ROWS};
use crate::moe::model::MoeModel;
use crate::tensor::Mat;
use crate::util::faults;

use super::metrics::Metrics;

/// Rung-up pressure thresholds; rung r engages at `RUNG_UP[r-1]`.
pub const RUNG_UP: [f64; 4] = [0.50, 0.70, 0.85, 0.95];
/// A rung disengages only once pressure falls this far below its
/// threshold (no flapping at the boundary).
pub const RUNG_HYSTERESIS: f64 = 0.05;

#[derive(Debug, Clone)]
pub struct MemGovConfig {
    /// The ceiling every reservation counts against.
    pub budget_bytes: u64,
    /// Rows per KV page (sessions must use the same granularity).
    pub page_rows: usize,
    /// Fraction of eligible (cold, fully-written) pages the rung-3
    /// action down-quantizes per application.
    pub downq_frac: f64,
    /// Prompts shorter than this are not worth publishing as shared
    /// prefixes.
    pub min_prefix_rows: usize,
    /// Rows behind the decode head rung 3 never touches (recent
    /// context dominates next-token quality).
    pub protect_recent_rows: usize,
}

impl Default for MemGovConfig {
    fn default() -> MemGovConfig {
        MemGovConfig {
            budget_bytes: u64::MAX,
            page_rows: DEFAULT_PAGE_ROWS,
            downq_frac: 0.5,
            min_prefix_rows: 8,
            protect_recent_rows: 16,
        }
    }
}

/// The atomically-shared accounting core. Split from the governor so
/// [`MemReservation`]s can hold it without creating an Arc cycle
/// through the prefix registry.
#[derive(Debug)]
struct Ledger {
    budget: u64,
    reserved: AtomicU64,
    metrics: Arc<Metrics>,
}

impl Ledger {
    fn release(&self, bytes: u64) {
        let prev = self.reserved.fetch_sub(bytes, Relaxed);
        debug_assert!(prev >= bytes, "over-release: {prev} - {bytes}");
        Metrics::set_gauge(&self.metrics.mem_bytes_reserved,
                           prev.saturating_sub(bytes));
    }
}

/// RAII hold on `bytes` of the governed budget; dropping it releases
/// the full remaining amount. [`MemReservation::shrink`] returns part
/// early (e.g. bytes actually freed by down-quantizing KV pages).
#[derive(Debug)]
pub struct MemReservation {
    ledger: Arc<Ledger>,
    bytes: AtomicU64,
}

impl MemReservation {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Relaxed)
    }

    /// Give back `by` bytes of this reservation (saturating).
    pub fn shrink(&self, by: u64) {
        let mut cur = self.bytes.load(Relaxed);
        loop {
            let freed = by.min(cur);
            if freed == 0 {
                return;
            }
            match self.bytes.compare_exchange(cur, cur - freed, Relaxed,
                                              Relaxed) {
                Ok(_) => {
                    self.ledger.release(freed);
                    return;
                }
                Err(now) => cur = now,
            }
        }
    }
}

impl Drop for MemReservation {
    fn drop(&mut self) {
        let left = self.bytes.swap(0, Relaxed);
        if left > 0 {
            self.ledger.release(left);
        }
    }
}

/// What admission hands the decode path: the session's byte
/// reservation plus the shared prefix it may attach.
#[derive(Debug)]
pub struct SessionGrant {
    pub reservation: MemReservation,
    pub prefix: Option<Arc<SharedPrefix>>,
}

#[derive(Debug)]
pub struct MemoryGovernor {
    pub cfg: MemGovConfig,
    ledger: Arc<Ledger>,
    /// bytes reserved before any session: expert residency budget +
    /// scratch-arena estimate (never released)
    baseline: u64,
    rung: AtomicU64,
    n_layers: usize,
    d_model: usize,
    max_seq: usize,
    metrics: Arc<Metrics>,
    prefixes: Mutex<HashMap<u64, (Arc<SharedPrefix>, MemReservation)>>,
}

impl MemoryGovernor {
    /// Build a governor for `model_cfg` with an explicit ceiling.
    /// `static_bytes` is the non-KV baseline (expert budget + scratch
    /// estimate) reserved up front for the process lifetime.
    pub fn new(cfg: MemGovConfig, model_cfg: &ModelConfig,
               static_bytes: u64, metrics: Arc<Metrics>)
               -> Arc<MemoryGovernor> {
        let ledger = Arc::new(Ledger {
            budget: cfg.budget_bytes,
            reserved: AtomicU64::new(static_bytes),
            metrics: metrics.clone(),
        });
        Metrics::set_gauge(&metrics.mem_budget_bytes, cfg.budget_bytes);
        Metrics::set_gauge(&metrics.mem_bytes_reserved, static_bytes);
        Arc::new(MemoryGovernor {
            cfg,
            ledger,
            baseline: static_bytes,
            rung: AtomicU64::new(0),
            n_layers: model_cfg.n_layers,
            d_model: model_cfg.d_model,
            max_seq: model_cfg.max_seq,
            metrics,
            prefixes: Mutex::new(HashMap::new()),
        })
    }

    /// The serving default: ceiling from `memmodel`-style worst-case
    /// arithmetic with enough slack that an unconstrained run never
    /// climbs past rung 0 — default behavior stays bit-identical to
    /// the ungoverned stack. `budget_override` (`--mem-budget-mb` or
    /// `MC_MEM_BUDGET_MB`) replaces the derived ceiling.
    pub fn for_model(model_cfg: &ModelConfig, expert_budget: Option<u64>,
                     max_batch: usize, budget_override: Option<u64>,
                     metrics: Arc<Metrics>) -> Arc<MemoryGovernor> {
        let mut cfg = MemGovConfig::default();
        let static_bytes = expert_budget.unwrap_or(0)
            + scratch_estimate_bytes(model_cfg, max_batch);
        let worst_kv = worst_case_kv_bytes(
            model_cfg.max_seq, 0, cfg.page_rows, model_cfg.n_layers,
            model_cfg.d_model);
        cfg.budget_bytes = budget_override.unwrap_or_else(|| {
            // 4x headroom over a full batch of max_seq sessions keeps
            // derived-default pressure under the first rung
            4 * (static_bytes + max_batch as u64 * worst_kv) + (1 << 20)
        });
        MemoryGovernor::new(cfg, model_cfg, static_bytes, metrics)
    }

    pub fn budget_bytes(&self) -> u64 {
        self.ledger.budget
    }

    pub fn bytes_reserved(&self) -> u64 {
        self.ledger.reserved.load(Relaxed)
    }

    /// The static (non-session) floor `bytes_reserved` returns to
    /// once every session retires.
    pub fn baseline_bytes(&self) -> u64 {
        self.baseline
    }

    pub fn pressure(&self) -> f64 {
        self.bytes_reserved() as f64 / self.ledger.budget.max(1) as f64
    }

    pub fn rung(&self) -> u64 {
        self.rung.load(Relaxed)
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Reserve `bytes` against the ceiling, or refuse (over budget, or
    /// an injected `oom=P` fault draw).
    pub fn try_reserve(&self, bytes: u64) -> Option<MemReservation> {
        if let Some(fp) = faults::plan() {
            if fp.oom_now() {
                Metrics::inc(&self.metrics.mem_oom_injected, 1);
                return None;
            }
        }
        let mut cur = self.ledger.reserved.load(Relaxed);
        loop {
            let next = cur.checked_add(bytes)?;
            if next > self.ledger.budget {
                return None;
            }
            match self.ledger.reserved.compare_exchange(cur, next, Relaxed,
                                                        Relaxed) {
                Ok(_) => {
                    Metrics::set_gauge(&self.metrics.mem_bytes_reserved, next);
                    return Some(MemReservation {
                        ledger: self.ledger.clone(),
                        bytes: AtomicU64::new(bytes),
                    });
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Worst-case private KV bytes for a session decoding
    /// `prompt_len + max_new` tokens with `prefix_rows` shared.
    pub fn worst_case_session_bytes(&self, prompt_len: usize, max_new: usize,
                                    prefix_rows: usize) -> u64 {
        let total = (prompt_len + max_new).min(self.max_seq);
        worst_case_kv_bytes(total, prefix_rows, self.cfg.page_rows,
                            self.n_layers, self.d_model)
    }

    /// Memory admission for one request: find a shared prefix for
    /// `prompt[..len-1]`, reserve the worst-case private footprint,
    /// and hand back the grant — or `Err(needed_bytes)` when the
    /// ceiling refuses (mapped to 503 + Retry-After by the serve
    /// tier, or to a deferred queue slot by the batcher).
    pub fn admit_session(&self, prompt: &[u32], max_new: usize)
                         -> Result<SessionGrant, u64> {
        let head = &prompt[..prompt.len().saturating_sub(1)];
        let prefix = self.lookup_prefix(head);
        let rows = prefix.as_ref().map(|p| p.rows).unwrap_or(0);
        let needed = self.worst_case_session_bytes(prompt.len(), max_new,
                                                   rows);
        match self.try_reserve(needed) {
            Some(reservation) => {
                if prefix.is_some() {
                    Metrics::inc(&self.metrics.kv_prefix_hits, 1);
                }
                Ok(SessionGrant { reservation, prefix })
            }
            None => {
                Metrics::inc(&self.metrics.mem_admission_rejected, 1);
                Err(needed)
            }
        }
    }

    /// Exact-match prefix lookup (hash key, token-equality verified).
    pub fn lookup_prefix(&self, head: &[u32]) -> Option<Arc<SharedPrefix>> {
        if head.len() < self.cfg.min_prefix_rows {
            return None;
        }
        let g = self.prefixes.lock().unwrap();
        g.get(&prefix_hash(head))
            .filter(|(p, _)| p.tokens == head)
            .map(|(p, _)| p.clone())
    }

    /// Whether publishing `head` would add a new shared prefix (long
    /// enough, not already registered) — callers check before paying
    /// the KV-row export copy.
    pub fn wants_prefix(&self, head: &[u32]) -> bool {
        head.len() >= self.cfg.min_prefix_rows
            && self.lookup_prefix(head).is_none()
    }

    /// Publish a computed prompt prefix for CoW reuse. Reserves the
    /// prefix's own bytes; skipped (false) when the budget has no
    /// room, the prefix is too short, or another session won the race.
    pub fn publish_prefix(&self, tokens: &[u32], k: Vec<Mat>, v: Vec<Mat>,
                          importance: Vec<f32>) -> bool {
        if tokens.len() < self.cfg.min_prefix_rows {
            return false;
        }
        let rows = tokens.len();
        let bytes =
            2 * (rows * self.d_model * 4 * self.n_layers) as u64;
        let Some(reservation) = self.try_reserve(bytes) else {
            return false;
        };
        let key = prefix_hash(tokens);
        let mut g = self.prefixes.lock().unwrap();
        if g.contains_key(&key) {
            return false; // racer won; reservation drops here
        }
        let prefix = Arc::new(SharedPrefix {
            tokens: tokens.to_vec(),
            k,
            v,
            rows,
            importance,
        });
        g.insert(key, (prefix, reservation));
        Metrics::inc(&self.metrics.kv_prefix_published, 1);
        true
    }

    pub fn prefix_count(&self) -> usize {
        self.prefixes.lock().unwrap().len()
    }

    /// Re-evaluate pressure and walk the ladder: engage every rung
    /// whose threshold is met, disengage (with hysteresis) those no
    /// longer needed, firing the reversible side effects through
    /// `model.resolver`. Returns the active rung. Callers (the fused
    /// batcher step, the engine between requests) invoke this
    /// periodically; rung-3 KV down-quantization is applied by the
    /// batcher to its live sessions when `tick` reports rung >= 3.
    pub fn tick(&self, model: &MoeModel) -> u64 {
        let pressure = self.pressure();
        let cur = self.rung.load(Relaxed);
        let engage = RUNG_UP
            .iter()
            .rposition(|&thr| pressure >= thr)
            .map(|i| i as u64 + 1)
            .unwrap_or(0);
        let mut next = cur;
        if engage > cur {
            next = engage;
        } else {
            while next > 0
                && pressure < RUNG_UP[next as usize - 1] - RUNG_HYSTERESIS
            {
                next -= 1;
            }
        }
        if next != cur {
            self.apply_rungs(cur, next, model);
            self.rung.store(next, Relaxed);
            crate::obs::instant(
                crate::obs::Cat::Mem, "pressure_rung",
                crate::obs::args3("from", cur, "to", next,
                                  "pressure_u",
                                  crate::obs::micro(pressure)));
        }
        Metrics::set_gauge(&self.metrics.mem_pressure_rung, next);
        next
    }

    fn apply_rungs(&self, from: u64, to: u64, model: &MoeModel) {
        if to > from {
            for r in from + 1..=to {
                match r {
                    1 => {
                        model.resolver.pause_prefetch(true);
                        Metrics::inc(&self.metrics.mem_prefetch_pauses, 1);
                    }
                    2 => {
                        model.resolver.shrink_budget(true);
                        Metrics::inc(&self.metrics.mem_budget_shrinks, 1);
                    }
                    3 => self.evict_idle_prefixes(),
                    _ => {} // rung 4: admission defers Low (batcher)
                }
            }
        } else {
            for r in (to + 1..=from).rev() {
                match r {
                    1 => model.resolver.pause_prefetch(false),
                    2 => model.resolver.shrink_budget(false),
                    _ => {} // rung 3/4 actions are admission/data-side
                }
            }
        }
    }

    /// Drop shared prefixes nobody references (registry refcount only)
    /// and return their bytes to the ledger.
    pub fn evict_idle_prefixes(&self) -> usize {
        let mut g = self.prefixes.lock().unwrap();
        let before = g.len();
        let page_rows = self.cfg.page_rows;
        let n_layers = self.n_layers;
        let mut pages_evicted = 0u64;
        g.retain(|_, (p, _)| {
            if Arc::strong_count(p) > 1 {
                return true;
            }
            pages_evicted += (p.rows.div_ceil(page_rows) * n_layers) as u64;
            false // the paired reservation drops with the entry
        });
        if pages_evicted > 0 {
            Metrics::inc(&self.metrics.kv_pages_evicted, pages_evicted);
        }
        before - g.len()
    }
}

/// Worst-case private page bytes for `total_rows` of context with
/// `prefix_rows` shared: whole pages of `page_rows` rows, K + V f32,
/// every layer.
pub fn worst_case_kv_bytes(total_rows: usize, prefix_rows: usize,
                           page_rows: usize, n_layers: usize, d: usize)
                           -> u64 {
    let tail = total_rows.saturating_sub(prefix_rows);
    let pages = tail.div_ceil(page_rows.max(1));
    (pages * page_rows * d * 4 * 2 * n_layers) as u64
}

/// Rough per-process scratch-arena bill: per batch slot, the
/// attention scratch (transposed K panel + score row) plus the
/// session's projection/logits buffers. An estimate, not an exact
/// meter — it exists so the baseline reservation scales with the
/// shapes the way `memmodel::peak_bytes_with` does.
pub fn scratch_estimate_bytes(cfg: &ModelConfig, max_batch: usize) -> u64 {
    let per = cfg.head_dim() * cfg.max_seq   // kht
        + cfg.max_seq                        // score row
        + 12 * cfg.d_model                   // projection buffers
        + cfg.vocab_size;                    // logits
    (max_batch.max(1) * per * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn gov(budget: u64) -> Arc<MemoryGovernor> {
        let cfg = MemGovConfig {
            budget_bytes: budget,
            min_prefix_rows: 2,
            ..MemGovConfig::default()
        };
        MemoryGovernor::new(cfg, &ModelConfig::test_tiny(), 0,
                            Arc::new(Metrics::new()))
    }

    #[test]
    fn reserve_release_rebalances_exactly() {
        let g = gov(1000);
        assert_eq!(g.bytes_reserved(), 0);
        let a = g.try_reserve(400).unwrap();
        let b = g.try_reserve(600).unwrap();
        assert_eq!(g.bytes_reserved(), 1000);
        assert!(g.try_reserve(1).is_none(), "ceiling is hard");
        drop(a);
        assert_eq!(g.bytes_reserved(), 600);
        b.shrink(100);
        assert_eq!(g.bytes_reserved(), 500);
        b.shrink(10_000); // saturates at what's held
        assert_eq!(g.bytes_reserved(), 0);
        drop(b); // double release must not underflow
        assert_eq!(g.bytes_reserved(), 0);
    }

    #[test]
    fn worst_case_rounds_to_whole_pages() {
        // 65 tail rows at 64-row pages -> 2 pages
        let b = worst_case_kv_bytes(65, 0, 64, 2, 32);
        assert_eq!(b, (2 * 64 * 32 * 4 * 2 * 2) as u64);
        // fully covered by the prefix -> zero private pages
        assert_eq!(worst_case_kv_bytes(10, 10, 64, 2, 32), 0);
        assert_eq!(worst_case_kv_bytes(10, 64, 64, 2, 32), 0);
    }

    #[test]
    fn admission_accounts_prefix_rows() {
        let g = gov(1 << 30);
        let prompt: Vec<u32> = (1..=20).collect();
        let grant = g.admit_session(&prompt, 12).unwrap();
        let full = g.worst_case_session_bytes(20, 12, 0);
        assert_eq!(grant.reservation.bytes(), full);
        assert!(grant.prefix.is_none());
        // publish the head, then an identical prompt rides the prefix
        let head = &prompt[..19];
        let cfg = ModelConfig::test_tiny();
        let mats = || (0..cfg.n_layers)
            .map(|_| Mat::zeros(19, cfg.d_model))
            .collect::<Vec<_>>();
        assert!(g.wants_prefix(head));
        assert!(g.publish_prefix(head, mats(), mats(), vec![0.0; 19]));
        assert!(!g.wants_prefix(head), "already published");
        let shared = g.admit_session(&prompt, 12).unwrap();
        assert!(shared.prefix.is_some());
        assert_eq!(shared.reservation.bytes(),
                   g.worst_case_session_bytes(20, 12, 19));
        assert!(shared.reservation.bytes() < full);
    }

    #[test]
    fn prefix_eviction_frees_only_idle_entries() {
        let g = gov(1 << 30);
        let cfg = ModelConfig::test_tiny();
        let mats = |rows: usize| (0..cfg.n_layers)
            .map(|_| Mat::zeros(rows, cfg.d_model))
            .collect::<Vec<_>>();
        let head: Vec<u32> = (1..=10).collect();
        assert!(g.publish_prefix(&head, mats(10), mats(10), vec![0.0; 10]));
        let floor = g.baseline_bytes();
        assert!(g.bytes_reserved() > floor, "prefix bytes are accounted");
        // held by a session: survives eviction
        let held = g.lookup_prefix(&head).unwrap();
        assert_eq!(g.evict_idle_prefixes(), 0);
        assert_eq!(g.prefix_count(), 1);
        drop(held);
        assert_eq!(g.evict_idle_prefixes(), 1);
        assert_eq!(g.prefix_count(), 0);
        assert_eq!(g.bytes_reserved(), floor,
                   "evicted prefix returns its bytes");
    }
}
