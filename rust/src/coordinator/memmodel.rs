//! Device memory model + bandwidth-bound throughput estimator.
//!
//! The paper's Tab. 4/14 report loading memory, peak memory and token
//! throughput on A100/3090 GPUs. Those quantities are arithmetic over
//! tensor sizes and bit-widths — identical math here, applied to our
//! models, plus measured CPU wall-clock for the ratios (Tab. 13).

use crate::moe::model::MoeModel;

#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub name: &'static str,
    pub mem_bytes: u64,
    /// HBM/DDR bandwidth (bytes/s) — decode is bandwidth-bound
    pub bw_bytes_per_s: f64,
}

pub const PLATFORMS: [Platform; 3] = [
    Platform { name: "A100-80G", mem_bytes: 80 << 30, bw_bytes_per_s: 2.0e12 },
    Platform { name: "RTX3090-24G", mem_bytes: 24 << 30, bw_bytes_per_s: 0.936e12 },
    Platform { name: "CPU-host", mem_bytes: 16 << 30, bw_bytes_per_s: 40.0e9 },
];

/// Weights-only loading memory (paper "Loading Memory" / "Params").
pub fn loading_bytes(model: &MoeModel) -> u64 {
    model.storage_bytes() as u64
}

/// Peak serving memory: weights + KV cache + activation workspace.
pub fn peak_bytes(model: &MoeModel, batch: usize, seq: usize) -> u64 {
    let cfg = &model.cfg;
    let kv = 2 * batch * seq * cfg.d_model * cfg.n_layers * 4;
    // activation workspace: hidden + logits + attention scores per seq
    let act = batch
        * (seq * cfg.d_model * 4 + seq * cfg.vocab_size
           + cfg.n_heads * seq * seq)
        * 4;
    loading_bytes(model) + (kv + act) as u64
}

/// Average *activated* parameter bytes per token (paper "Act Params"):
/// attention + gate + embeddings + top-k expert shares, scaled by the
/// measured ODP keep-ratio.
pub fn activated_bytes_per_token(model: &MoeModel, keep_ratio: f64) -> f64 {
    let cfg = &model.cfg;
    let mut non_expert = (model.tok_emb.cols       // one embedding row
        + model.pos_emb.cols
        + model.lm_head.data.len()
        + model.final_norm.len()) as f64
        * 4.0;
    let mut expert_bytes_mean = 0.0f64;
    for l in &model.layers {
        non_expert += (l.attn_norm.len() + l.ffn_norm.len() + l.gate.data.len()) as f64 * 4.0;
        non_expert += (l.wq.storage_bytes()
            + l.wk.storage_bytes()
            + l.wv.storage_bytes()
            + l.wo.storage_bytes()) as f64;
        let mean_expert: f64 = l
            .experts
            .iter()
            .map(|e| e.storage_bytes() as f64)
            .sum::<f64>()
            / l.experts.len() as f64;
        expert_bytes_mean += mean_expert * cfg.top_k as f64 * keep_ratio;
    }
    non_expert + expert_bytes_mean
}

/// Bandwidth-bound decode throughput estimate: every generated token
/// must stream its activated weights once.
pub fn tokens_per_sec_estimate(model: &MoeModel, platform: &Platform,
                               keep_ratio: f64) -> f64 {
    platform.bw_bytes_per_s / activated_bytes_per_token(model, keep_ratio)
}

/// Does the model fit on the platform (with headroom fraction)?
pub fn fits(model: &MoeModel, platform: &Platform, batch: usize,
            seq: usize) -> bool {
    peak_bytes(model, batch, seq) <= (platform.mem_bytes as f64 * 0.95) as u64
}

pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn peak_exceeds_loading() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 0);
        assert!(peak_bytes(&m, 4, 64) > loading_bytes(&m));
    }

    #[test]
    fn activated_less_than_total() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 1);
        let act = activated_bytes_per_token(&m, 1.0);
        assert!(act < loading_bytes(&m) as f64);
        // pruning reduces activated bytes
        assert!(activated_bytes_per_token(&m, 0.85) < act);
    }

    #[test]
    fn throughput_scales_with_bandwidth() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 2);
        let a = tokens_per_sec_estimate(&m, &PLATFORMS[0], 1.0);
        let c = tokens_per_sec_estimate(&m, &PLATFORMS[2], 1.0);
        assert!(a > c * 10.0);
    }

    #[test]
    fn fits_logic() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 3);
        assert!(fits(&m, &PLATFORMS[0], 1, 64));
        let tiny_dev = Platform { name: "tiny", mem_bytes: 1 << 18, bw_bytes_per_s: 1e9 };
        assert!(!fits(&m, &tiny_dev, 1, 64));
    }
}
