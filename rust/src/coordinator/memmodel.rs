//! Device memory model + bandwidth-bound throughput estimator.
//!
//! The paper's Tab. 4/14 report loading memory, peak memory and token
//! throughput on A100/3090 GPUs. Those quantities are arithmetic over
//! tensor sizes and bit-widths — identical math here, applied to our
//! models, plus measured CPU wall-clock for the ratios (Tab. 13).
//!
//! Element sizes are not hardcoded in the formulas: [`MemParams`]
//! derives them from the model (the engine's KV cache and activations
//! are f32 `Mat`s today — `size_of::<f32>()` — and would change here,
//! in one place, if a half-precision KV pass landed) and carries the
//! expert-residency budget, so the peak/loading math of a
//! budget-capped deployment (DESIGN.md §5) reflects what is actually
//! resident rather than the full expert set.

use crate::moe::model::MoeModel;

#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub name: &'static str,
    pub mem_bytes: u64,
    /// HBM/DDR bandwidth (bytes/s) — decode is bandwidth-bound
    pub bw_bytes_per_s: f64,
}

pub const PLATFORMS: [Platform; 3] = [
    Platform { name: "A100-80G", mem_bytes: 80 << 30, bw_bytes_per_s: 2.0e12 },
    Platform { name: "RTX3090-24G", mem_bytes: 24 << 30, bw_bytes_per_s: 0.936e12 },
    Platform { name: "CPU-host", mem_bytes: 16 << 30, bw_bytes_per_s: 40.0e9 },
];

/// Element sizes + residency budget the memory math runs over.
#[derive(Debug, Clone, Copy)]
pub struct MemParams {
    /// bytes per KV-cache element
    pub kv_elem_bytes: usize,
    /// bytes per activation-workspace element
    pub act_elem_bytes: usize,
    /// expert-residency byte budget (None = fully resident)
    pub expert_budget: Option<u64>,
}

impl MemParams {
    /// Derive from the model: the engine materializes KV rows and
    /// activations as f32 (`LayerKv`/scratch `Mat`s), and a
    /// cache-resolved model contributes its configured byte budget.
    pub fn for_model(model: &MoeModel) -> MemParams {
        MemParams {
            kv_elem_bytes: std::mem::size_of::<f32>(),
            act_elem_bytes: std::mem::size_of::<f32>(),
            expert_budget: model.resolver.budget_bytes(),
        }
    }

    /// What-if element size for a half/quarter-precision KV cache
    /// (the Tab. 14 sensitivity axis).
    pub fn with_kv_elem_bytes(self, bytes: usize) -> MemParams {
        MemParams { kv_elem_bytes: bytes, ..self }
    }

    pub fn with_expert_budget(self, budget: Option<u64>) -> MemParams {
        MemParams { expert_budget: budget, ..self }
    }
}

/// Weights-only loading memory (paper "Loading Memory" / "Params").
pub fn loading_bytes(model: &MoeModel) -> u64 {
    model.storage_bytes() as u64
}

/// Weight bytes resident under an expert budget as *configured*: the
/// full non-expert stack plus at most `budget` bytes of experts.
/// (Transient demand-pin overshoot is modeled by [`peak_bytes_with`],
/// which floors the expert term at a step's pinned working set.)
pub fn resident_weight_bytes(model: &MoeModel, budget: Option<u64>) -> u64 {
    let experts = model.expert_storage_bytes() as u64;
    let non_expert = loading_bytes(model) - experts;
    non_expert + budget.map_or(experts, |b| experts.min(b))
}

/// Peak serving memory under explicit element sizes and budget:
/// resident weights + KV cache + activation workspace. The expert
/// term is floored at one fused step's worst-case *pinned* working
/// set (`min(batch·top_k, n_experts)` experts of one layer): the
/// cache deliberately overshoots the budget rather than evict a
/// pinned expert mid-dispatch (DESIGN.md §5), so a budget below that
/// floor does not actually lower the peak.
pub fn peak_bytes_with(model: &MoeModel, batch: usize, seq: usize,
                       p: &MemParams) -> u64 {
    let cfg = &model.cfg;
    let kv = (2 * batch * seq * cfg.d_model * cfg.n_layers) as u64
        * p.kv_elem_bytes as u64;
    // activation workspace: 4 hidden-sized buffers + logits +
    // attention scores per sequence
    let act = (batch
        * (4 * seq * cfg.d_model + seq * cfg.vocab_size
           + cfg.n_heads * seq * seq)) as u64
        * p.act_elem_bytes as u64;
    let experts_total = model.expert_storage_bytes() as u64;
    let non_expert = loading_bytes(model) - experts_total;
    let resident_experts = match p.expert_budget {
        None => experts_total,
        Some(b) => {
            let slots = (cfg.n_layers * cfg.n_experts).max(1) as u64;
            let mean = experts_total / slots;
            let pinned_worst =
                (batch * cfg.top_k).min(cfg.n_experts) as u64 * mean;
            experts_total
                .min(b)
                .max(pinned_worst.min(experts_total))
        }
    };
    non_expert + resident_experts + kv + act
}

/// Peak serving memory with parameters derived from the model itself.
pub fn peak_bytes(model: &MoeModel, batch: usize, seq: usize) -> u64 {
    peak_bytes_with(model, batch, seq, &MemParams::for_model(model))
}

/// Average *activated* parameter bytes per token (paper "Act Params"):
/// attention + gate + embeddings + top-k expert shares, scaled by the
/// measured ODP keep-ratio.
pub fn activated_bytes_per_token(model: &MoeModel, keep_ratio: f64) -> f64 {
    let cfg = &model.cfg;
    let mut non_expert = (model.tok_emb.cols       // one embedding row
        + model.pos_emb.cols
        + model.lm_head.data.len()
        + model.final_norm.len()) as f64
        * 4.0;
    // cache-resolved layers have empty expert vecs; their per-expert
    // mean comes from the store directory instead
    let store_mean = model.resolver.expert_bytes().map(|total| {
        total as f64 / (cfg.n_layers * cfg.n_experts) as f64
    });
    let mut expert_bytes_mean = 0.0f64;
    for l in &model.layers {
        non_expert += (l.attn_norm.len() + l.ffn_norm.len() + l.gate.data.len()) as f64 * 4.0;
        non_expert += (l.wq.storage_bytes()
            + l.wk.storage_bytes()
            + l.wv.storage_bytes()
            + l.wo.storage_bytes()) as f64;
        let mean_expert: f64 = match (&store_mean, l.experts.is_empty()) {
            (Some(m), true) => *m,
            _ => {
                l.experts
                    .iter()
                    .map(|e| e.storage_bytes() as f64)
                    .sum::<f64>()
                    / l.experts.len().max(1) as f64
            }
        };
        expert_bytes_mean += mean_expert * cfg.top_k as f64 * keep_ratio;
    }
    non_expert + expert_bytes_mean
}

/// Bandwidth-bound decode throughput estimate: every generated token
/// must stream its activated weights once.
pub fn tokens_per_sec_estimate(model: &MoeModel, platform: &Platform,
                               keep_ratio: f64) -> f64 {
    platform.bw_bytes_per_s / activated_bytes_per_token(model, keep_ratio)
}

/// Does the model fit on the platform (with headroom fraction)?
pub fn fits(model: &MoeModel, platform: &Platform, batch: usize,
            seq: usize) -> bool {
    peak_bytes(model, batch, seq) <= (platform.mem_bytes as f64 * 0.95) as u64
}

pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn peak_exceeds_loading() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 0);
        assert!(peak_bytes(&m, 4, 64) > loading_bytes(&m));
    }

    #[test]
    fn activated_less_than_total() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 1);
        let act = activated_bytes_per_token(&m, 1.0);
        assert!(act < loading_bytes(&m) as f64);
        // pruning reduces activated bytes
        assert!(activated_bytes_per_token(&m, 0.85) < act);
    }

    #[test]
    fn throughput_scales_with_bandwidth() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 2);
        let a = tokens_per_sec_estimate(&m, &PLATFORMS[0], 1.0);
        let c = tokens_per_sec_estimate(&m, &PLATFORMS[2], 1.0);
        assert!(a > c * 10.0);
    }

    #[test]
    fn fits_logic() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 3);
        assert!(fits(&m, &PLATFORMS[0], 1, 64));
        let tiny_dev = Platform { name: "tiny", mem_bytes: 1 << 18, bw_bytes_per_s: 1e9 };
        assert!(!fits(&m, &tiny_dev, 1, 64));
    }

    #[test]
    fn expert_budget_caps_peak() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 4);
        let experts = m.expert_storage_bytes() as u64;
        let p_full = MemParams::for_model(&m);
        let p_half = p_full.with_expert_budget(Some(experts / 2));
        let full = peak_bytes_with(&m, 2, 32, &p_full);
        let half = peak_bytes_with(&m, 2, 32, &p_half);
        assert_eq!(full - half, experts - experts / 2,
                   "budget removes exactly the over-budget expert bytes");
        // a budget above the expert total changes nothing
        let p_over = p_full.with_expert_budget(Some(experts * 2));
        assert_eq!(peak_bytes_with(&m, 2, 32, &p_over), full);
    }

    #[test]
    fn tiny_budget_floors_at_pinned_working_set() {
        // the cache pins a step's routed experts past the budget, so
        // peak cannot drop below that working set
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 6);
        let experts = m.expert_storage_bytes() as u64;
        let mean = experts / (cfg.n_layers * cfg.n_experts) as u64;
        let (b, s) = (2usize, 32usize);
        // batch * top_k = 4 = n_experts -> one full layer stays pinned
        let floor = (b * cfg.top_k).min(cfg.n_experts) as u64 * mean;
        let base = peak_bytes_with(&m, b, s, &MemParams::for_model(&m));
        let p1 = MemParams::for_model(&m).with_expert_budget(Some(1));
        let tiny = peak_bytes_with(&m, b, s, &p1);
        assert_eq!(base - tiny, experts - floor,
                   "a 1-byte budget still pins the step's working set");
    }

    #[test]
    fn kv_elem_bytes_scale_kv_term() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 5);
        let p4 = MemParams::for_model(&m);
        let p2 = p4.with_kv_elem_bytes(2);
        let (b, s) = (2usize, 32usize);
        let kv_f32 = (2 * b * s * cfg.d_model * cfg.n_layers * 4) as u64;
        let diff = peak_bytes_with(&m, b, s, &p4) - peak_bytes_with(&m, b, s, &p2);
        assert_eq!(diff, kv_f32 / 2, "halving KV bytes halves the KV term");
    }
}
