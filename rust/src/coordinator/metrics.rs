//! Serving metrics: counters, gauges, and bounded latency records,
//! printable as a prometheus-style text block.
//!
//! Latency samples (TTFT / per-token) live in fixed-capacity rings so
//! a long-lived server's memory stays O(1) no matter how many
//! requests it has served; summary statistics are over the most
//! recent `RING_CAP` samples (a sliding window, which is also what an
//! operator wants from a live gauge).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Retained latency samples per series.
pub const RING_CAP: usize = 4096;

/// Cumulative-histogram bucket bounds (ms) for TTFT / TPOT. Chosen to
/// straddle interactive SLOs: sub-ms decode steps land in the first
/// buckets, multi-second stragglers in the last, `+Inf` is implicit.
pub const HIST_BOUNDS_MS: [f64; 12] =
    [1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
     2500.0, 5000.0];

/// Lock-free cumulative histogram: per-bucket atomic counts (rendered
/// cumulatively per the exposition format), a running sum, and a
/// lifetime count. Unlike [`LatencyRing`]'s sliding window, these
/// never reset — which is what makes `_bucket` series aggregable
/// across instances and scrape intervals.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BOUNDS_MS.len()],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_ns(&self, ns: u64) {
        let ms = ns as f64 / 1e6;
        if let Some(i) = HIST_BOUNDS_MS.iter().position(|&b| ms <= b) {
            self.buckets[i].fetch_add(1, Ordering::Relaxed);
        }
        // over-the-top samples only appear in the implicit +Inf bucket
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Cumulative count at each bound (same order as
    /// [`HIST_BOUNDS_MS`]); the `+Inf` bucket is [`Histogram::count`].
    pub fn cumulative(&self) -> [u64; HIST_BOUNDS_MS.len()] {
        let mut acc = 0u64;
        std::array::from_fn(|i| {
            acc += self.buckets[i].load(Ordering::Relaxed);
            acc
        })
    }
}

/// Escape HELP text per the Prometheus text exposition format:
/// backslash and newline are the only characters with escapes there.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value per the text format: backslash, double-quote,
/// and newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Fixed-capacity overwrite-oldest sample buffer.
#[derive(Debug)]
pub struct LatencyRing {
    cap: usize,
    buf: Vec<u64>,
    next: usize,
    /// lifetime pushes (>= buf.len(); buf holds the most recent cap)
    total: u64,
}

impl Default for LatencyRing {
    fn default() -> LatencyRing {
        LatencyRing::with_capacity(RING_CAP)
    }
}

impl LatencyRing {
    pub fn with_capacity(cap: usize) -> LatencyRing {
        assert!(cap > 0);
        LatencyRing { cap, buf: Vec::new(), next: 0, total: 0 }
    }

    pub fn push(&mut self, v: u64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Lifetime number of pushes.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Mean of the retained window (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<u64>() as f64 / self.buf.len() as f64
    }

    /// p-th percentile (0..=100) of the retained window, linear
    /// interpolation between adjacent samples; 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_unstable();
        let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_cancelled: AtomicU64,
    /// invalid requests (empty prompt) turned away at admission
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub expert_calls: AtomicU64,
    pub experts_pruned: AtomicU64,
    /// gauge: requests waiting in the admission queue (set per step)
    pub queue_depth: AtomicU64,
    /// gauge: active decode sessions in the fused batch (set per step)
    pub batch_occupancy: AtomicU64,
    /// time-to-first-token samples (ns), last `RING_CAP` retained
    pub ttft_ns: Mutex<LatencyRing>,
    /// per-token decode latencies (ns), last `RING_CAP` retained
    pub tpot_ns: Mutex<LatencyRing>,
    /// lifetime TTFT histogram (`mc_ttft_ms_bucket`)
    pub ttft_hist: Histogram,
    /// lifetime per-token-latency histogram (`mc_tpot_ms_bucket`)
    pub tpot_hist: Histogram,
    // --- expert residency (offload::ExpertCache, DESIGN.md §5) ---
    /// demand accesses served from the cache
    pub expert_cache_hits: AtomicU64,
    /// demand accesses that had to load from the store
    pub expert_cache_misses: AtomicU64,
    /// experts dropped by the clock sweep to meet the byte budget
    pub expert_cache_evictions: AtomicU64,
    /// speculative loads the prefetcher actually performed
    pub expert_prefetch_issued: AtomicU64,
    /// prefetched experts later demanded before eviction
    pub expert_prefetch_hits: AtomicU64,
    /// gauge: expert bytes currently resident in the cache
    pub bytes_resident: AtomicU64,
    /// demand-miss load stalls (ns), last `RING_CAP` retained
    pub miss_stall_ns: Mutex<LatencyRing>,
    // --- fault tolerance (offload retry + degraded dispatch, DESIGN.md §7) ---
    /// store fetch attempts retried after a transient failure
    pub expert_load_retries: AtomicU64,
    /// fetches that exhausted their retry budget (expert quarantined)
    pub expert_load_failures: AtomicU64,
    /// (layer, expert) pairs placed in quarantine after failures
    pub experts_quarantined: AtomicU64,
    /// layer dispatches that ran with a reduced expert set
    pub degraded_dispatches: AtomicU64,
    /// requests terminated for exceeding their deadline or stalling
    pub deadline_exceeded: AtomicU64,
    /// worker panics caught and converted to error responses
    pub panics_recovered: AtomicU64,
    /// info: kernel backend ISA the engine selected at startup
    /// (empty until [`Metrics::set_kernel_backend`]; bench JSONs copy
    /// it so every number records which backend produced it)
    pub kernel_backend: Mutex<String>,
    // --- HTTP front end (serve::HttpServer, DESIGN.md §6) ---
    /// connections handed to the pool
    pub http_conns_accepted: AtomicU64,
    /// connections answered 503 at the `--max-conns` cap
    pub http_conns_rejected: AtomicU64,
    /// gauge: connections currently queued or being handled
    pub http_conns_active: AtomicU64,
    /// unparseable / unroutable requests (400/404/408/413/431)
    pub http_bad_requests: AtomicU64,
    /// generate requests answered 429 by queue-depth load shedding
    pub requests_shed: AtomicU64,
    /// generate requests answered 429 at the per-tenant stream cap
    pub requests_tenant_limited: AtomicU64,
    /// SSE clients that vanished mid-stream (disconnect → cancel)
    pub client_disconnects: AtomicU64,
    /// gauge: admitted generate streams currently live
    pub streams_inflight: AtomicU64,
    /// gauge: duration of the most recent graceful drain (ns)
    pub last_drain_ns: AtomicU64,
    // --- memory governor (coordinator::memgov, DESIGN.md §8) ---
    /// sessions whose prompt head matched a published shared prefix
    pub kv_prefix_hits: AtomicU64,
    /// prompt prefixes published for copy-on-write reuse
    pub kv_prefix_published: AtomicU64,
    /// idle shared-prefix pages reclaimed at rung 3
    pub kv_pages_evicted: AtomicU64,
    /// KV pages down-quantized to f16 under pressure (rung 3)
    pub kv_pages_downquantized: AtomicU64,
    /// requests refused because the byte ceiling had no room
    pub mem_admission_rejected: AtomicU64,
    /// rung-1 engagements: speculative expert prefetch paused
    pub mem_prefetch_pauses: AtomicU64,
    /// rung-2 engagements: expert-cache budget halved
    pub mem_budget_shrinks: AtomicU64,
    /// rung-4 deferrals of Priority::Low admissions
    pub mem_sessions_deferred: AtomicU64,
    /// reservations failed by an injected `oom=P` fault
    pub mem_oom_injected: AtomicU64,
    /// gauge: bytes currently reserved against the memory budget
    pub mem_bytes_reserved: AtomicU64,
    /// gauge: the configured memory budget ceiling (bytes)
    pub mem_budget_bytes: AtomicU64,
    /// gauge: active degradation-ladder rung (0 = unconstrained)
    pub mem_pressure_rung: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn set_gauge(gauge: &AtomicU64, v: u64) {
        gauge.store(v, Ordering::Relaxed);
    }

    pub fn record_ttft(&self, ns: u64) {
        self.ttft_ns.lock().unwrap().push(ns);
        self.ttft_hist.record_ns(ns);
    }

    pub fn record_tpot(&self, ns: u64) {
        self.tpot_ns.lock().unwrap().push(ns);
        self.tpot_hist.record_ns(ns);
    }

    pub fn record_miss_stall(&self, ns: u64) {
        self.miss_stall_ns.lock().unwrap().push(ns);
    }

    /// Record which kernel backend the engine selected (engine/server
    /// startup calls this right after `kernels::log_selection()`).
    pub fn set_kernel_backend(&self, isa: &str) {
        *self.kernel_backend.lock().unwrap() = isa.to_string();
    }

    /// The recorded backend name, falling back to whatever the
    /// process-wide dispatch table resolved to (covers callers that
    /// render metrics without going through an engine).
    pub fn kernel_backend_name(&self) -> String {
        let s = self.kernel_backend.lock().unwrap().clone();
        if s.is_empty() {
            crate::kernels::active().isa.name().to_string()
        } else {
            s
        }
    }

    /// Fraction of expert demand accesses served without a store load.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.expert_cache_hits.load(Ordering::Relaxed);
        let misses = self.expert_cache_misses.load(Ordering::Relaxed);
        if hits + misses == 0 {
            return 0.0;
        }
        hits as f64 / (hits + misses) as f64
    }

    /// Fraction of issued prefetches that were later demanded.
    pub fn prefetch_hit_rate(&self) -> f64 {
        let issued = self.expert_prefetch_issued.load(Ordering::Relaxed);
        if issued == 0 {
            return 0.0;
        }
        self.expert_prefetch_hits.load(Ordering::Relaxed) as f64 / issued as f64
    }

    /// One-line expert-cache report (the CLI and examples all render
    /// this instead of hand-assembling the counters).
    pub fn cache_summary(&self) -> String {
        format!(
            "{} hits / {} misses ({:.1}% hit) | prefetch {}/{} hit | \
             {} evictions | miss stall {:.3}ms mean | resident {:.2} MB",
            self.expert_cache_hits.load(Ordering::Relaxed),
            self.expert_cache_misses.load(Ordering::Relaxed),
            100.0 * self.cache_hit_rate(),
            self.expert_prefetch_hits.load(Ordering::Relaxed),
            self.expert_prefetch_issued.load(Ordering::Relaxed),
            self.expert_cache_evictions.load(Ordering::Relaxed),
            self.miss_stall_ns.lock().unwrap().mean() / 1e6,
            self.bytes_resident.load(Ordering::Relaxed) as f64 / 1e6,
        )
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let mean_ns = self.tpot_ns.lock().unwrap().mean();
        if mean_ns == 0.0 {
            return 0.0;
        }
        1e9 / mean_ns
    }

    pub fn prune_ratio(&self) -> f64 {
        let calls = self.expert_calls.load(Ordering::Relaxed);
        let pruned = self.experts_pruned.load(Ordering::Relaxed);
        if calls + pruned == 0 {
            return 0.0;
        }
        pruned as f64 / (calls + pruned) as f64
    }

    pub fn render_text(&self) -> String {
        let ttft_ms = self.ttft_ns.lock().unwrap().mean() / 1e6;
        let stall_ms = self.miss_stall_ns.lock().unwrap().mean() / 1e6;
        let backend = self.kernel_backend_name();
        let mut s = format!(
            "mc_requests_admitted {}\nmc_requests_completed {}\n\
             mc_requests_cancelled {}\nmc_requests_rejected {}\n\
             mc_tokens_generated {}\n\
             mc_tokens_per_sec {:.2}\n\
             mc_expert_calls {}\nmc_experts_pruned {}\n\
             mc_prune_ratio {:.4}\nmc_ttft_ms_mean {:.3}\n\
             mc_queue_depth {}\nmc_batch_occupancy {}\n\
             mc_expert_cache_hits {}\nmc_expert_cache_misses {}\n\
             mc_expert_cache_evictions {}\n\
             mc_expert_prefetch_issued {}\nmc_expert_prefetch_hits {}\n\
             mc_expert_cache_hit_rate {:.4}\n\
             mc_expert_prefetch_hit_rate {:.4}\n\
             mc_bytes_resident {}\nmc_miss_stall_ms_mean {:.3}\n\
             mc_expert_load_retries {}\nmc_expert_load_failures {}\n\
             mc_experts_quarantined {}\nmc_degraded_dispatches {}\n\
             mc_deadline_exceeded {}\nmc_panics_recovered {}\n\
             mc_kernel_backend{{isa=\"{}\"}} 1\n",
            self.requests_admitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.tokens_per_sec(),
            self.expert_calls.load(Ordering::Relaxed),
            self.experts_pruned.load(Ordering::Relaxed),
            self.prune_ratio(),
            ttft_ms,
            self.queue_depth.load(Ordering::Relaxed),
            self.batch_occupancy.load(Ordering::Relaxed),
            self.expert_cache_hits.load(Ordering::Relaxed),
            self.expert_cache_misses.load(Ordering::Relaxed),
            self.expert_cache_evictions.load(Ordering::Relaxed),
            self.expert_prefetch_issued.load(Ordering::Relaxed),
            self.expert_prefetch_hits.load(Ordering::Relaxed),
            self.cache_hit_rate(),
            self.prefetch_hit_rate(),
            self.bytes_resident.load(Ordering::Relaxed),
            stall_ms,
            self.expert_load_retries.load(Ordering::Relaxed),
            self.expert_load_failures.load(Ordering::Relaxed),
            self.experts_quarantined.load(Ordering::Relaxed),
            self.degraded_dispatches.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.panics_recovered.load(Ordering::Relaxed),
            backend,
        );
        let _ = write!(s,
            "mc_kv_prefix_hits {}\nmc_kv_prefix_published {}\n\
             mc_kv_pages_evicted {}\nmc_kv_pages_downquantized {}\n\
             mc_mem_admission_rejected {}\nmc_mem_prefetch_pauses {}\n\
             mc_mem_budget_shrinks {}\nmc_mem_sessions_deferred {}\n\
             mc_mem_oom_injected {}\nmc_mem_bytes_reserved {}\n\
             mc_mem_budget_bytes {}\nmc_mem_pressure_rung {}\n",
            self.kv_prefix_hits.load(Ordering::Relaxed),
            self.kv_prefix_published.load(Ordering::Relaxed),
            self.kv_pages_evicted.load(Ordering::Relaxed),
            self.kv_pages_downquantized.load(Ordering::Relaxed),
            self.mem_admission_rejected.load(Ordering::Relaxed),
            self.mem_prefetch_pauses.load(Ordering::Relaxed),
            self.mem_budget_shrinks.load(Ordering::Relaxed),
            self.mem_sessions_deferred.load(Ordering::Relaxed),
            self.mem_oom_injected.load(Ordering::Relaxed),
            self.mem_bytes_reserved.load(Ordering::Relaxed),
            self.mem_budget_bytes.load(Ordering::Relaxed),
            self.mem_pressure_rung.load(Ordering::Relaxed),
        );
        s
    }

    /// Prometheus text exposition (content type
    /// `text/plain; version=0.0.4`): every counter/gauge with `# HELP`
    /// / `# TYPE` metadata, window-quantile summaries
    /// (`mc_*_ms_window`) for the latency rings, and lifetime
    /// cumulative histograms (`mc_ttft_ms` / `mc_tpot_ms`) for cross-
    /// instance aggregation. HELP text and label values are escaped
    /// per the text-format spec. `GET /metrics` serves exactly this
    /// string, and in-process callers (CLI, benches) can render the
    /// same snapshot.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: u64| {
            let help = escape_help(help);
            let _ = write!(out,
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n");
        };
        let c = Ordering::Relaxed;
        counter("mc_requests_admitted",
                "requests admitted to the batcher",
                self.requests_admitted.load(c));
        counter("mc_requests_completed", "requests finished with Done",
                self.requests_completed.load(c));
        counter("mc_requests_cancelled", "requests cancelled mid-flight",
                self.requests_cancelled.load(c));
        counter("mc_requests_rejected", "invalid requests turned away",
                self.requests_rejected.load(c));
        counter("mc_requests_shed",
                "generate requests shed with 429 at the queue-depth limit",
                self.requests_shed.load(c));
        counter("mc_requests_tenant_limited",
                "generate requests 429'd at the per-tenant stream cap",
                self.requests_tenant_limited.load(c));
        counter("mc_tokens_generated", "tokens produced by decode steps",
                self.tokens_generated.load(c));
        counter("mc_expert_calls", "expert FFN invocations",
                self.expert_calls.load(c));
        counter("mc_experts_pruned", "expert calls skipped by ODP",
                self.experts_pruned.load(c));
        counter("mc_expert_cache_hits", "expert demand hits",
                self.expert_cache_hits.load(c));
        counter("mc_expert_cache_misses", "expert demand misses",
                self.expert_cache_misses.load(c));
        counter("mc_expert_cache_evictions", "experts evicted for budget",
                self.expert_cache_evictions.load(c));
        counter("mc_expert_prefetch_issued", "speculative expert loads",
                self.expert_prefetch_issued.load(c));
        counter("mc_expert_prefetch_hits", "prefetches later demanded",
                self.expert_prefetch_hits.load(c));
        counter("mc_http_conns_accepted", "connections handed to the pool",
                self.http_conns_accepted.load(c));
        counter("mc_http_conns_rejected",
                "connections 503'd at the connection cap",
                self.http_conns_rejected.load(c));
        counter("mc_http_bad_requests",
                "unparseable or unroutable HTTP requests",
                self.http_bad_requests.load(c));
        counter("mc_client_disconnects",
                "SSE clients that vanished mid-stream",
                self.client_disconnects.load(c));
        counter("mc_expert_load_retries",
                "store fetch attempts retried after transient failure",
                self.expert_load_retries.load(c));
        counter("mc_expert_load_failures",
                "fetches that exhausted their retry budget",
                self.expert_load_failures.load(c));
        counter("mc_experts_quarantined",
                "(layer, expert) pairs quarantined after failures",
                self.experts_quarantined.load(c));
        counter("mc_degraded_dispatches",
                "layer dispatches run with a reduced expert set",
                self.degraded_dispatches.load(c));
        counter("mc_deadline_exceeded",
                "requests terminated for deadline or stall",
                self.deadline_exceeded.load(c));
        counter("mc_panics_recovered",
                "worker panics caught and turned into error responses",
                self.panics_recovered.load(c));
        counter("mc_kv_prefix_hits",
                "sessions attached to a published shared prefix",
                self.kv_prefix_hits.load(c));
        counter("mc_kv_prefix_published",
                "prompt prefixes published for copy-on-write reuse",
                self.kv_prefix_published.load(c));
        counter("mc_kv_pages_evicted",
                "idle shared-prefix pages reclaimed under pressure",
                self.kv_pages_evicted.load(c));
        counter("mc_kv_pages_downquantized",
                "KV pages down-quantized to f16 under pressure",
                self.kv_pages_downquantized.load(c));
        counter("mc_mem_admission_rejected",
                "requests refused at the memory byte ceiling",
                self.mem_admission_rejected.load(c));
        counter("mc_mem_prefetch_pauses",
                "rung-1 engagements pausing expert prefetch",
                self.mem_prefetch_pauses.load(c));
        counter("mc_mem_budget_shrinks",
                "rung-2 engagements halving the expert-cache budget",
                self.mem_budget_shrinks.load(c));
        counter("mc_mem_sessions_deferred",
                "rung-4 deferrals of low-priority admissions",
                self.mem_sessions_deferred.load(c));
        counter("mc_mem_oom_injected",
                "reservations failed by an injected oom fault",
                self.mem_oom_injected.load(c));

        let mut gauge = |name: &str, help: &str, v: f64| {
            let help = escape_help(help);
            let _ = write!(out,
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n");
        };
        gauge("mc_queue_depth", "requests waiting in the admission queue",
              self.queue_depth.load(c) as f64);
        gauge("mc_batch_occupancy", "active sessions in the fused batch",
              self.batch_occupancy.load(c) as f64);
        gauge("mc_streams_inflight", "admitted generate streams live now",
              self.streams_inflight.load(c) as f64);
        gauge("mc_http_conns_active", "connections queued or in handling",
              self.http_conns_active.load(c) as f64);
        gauge("mc_bytes_resident", "expert bytes resident in the cache",
              self.bytes_resident.load(c) as f64);
        gauge("mc_last_drain_ms", "duration of the most recent drain",
              self.last_drain_ns.load(c) as f64 / 1e6);
        gauge("mc_tokens_per_sec", "decode throughput over the tpot window",
              self.tokens_per_sec());
        gauge("mc_prune_ratio", "fraction of expert calls pruned",
              self.prune_ratio());
        gauge("mc_expert_cache_hit_rate", "demand hit fraction",
              self.cache_hit_rate());
        gauge("mc_expert_prefetch_hit_rate", "prefetch usefulness fraction",
              self.prefetch_hit_rate());
        gauge("mc_mem_bytes_reserved",
              "bytes reserved against the memory budget",
              self.mem_bytes_reserved.load(c) as f64);
        gauge("mc_mem_budget_bytes", "configured memory budget ceiling",
              self.mem_budget_bytes.load(c) as f64);
        gauge("mc_mem_pressure_rung",
              "active degradation-ladder rung (0 = unconstrained)",
              self.mem_pressure_rung.load(c) as f64);

        let mut summary = |name: &str, help: &str, ring: &LatencyRing| {
            let help = escape_help(help);
            let _ = write!(out,
                "# HELP {name} {help}\n# TYPE {name} summary\n\
                 {name}{{quantile=\"0.5\"}} {:.3}\n\
                 {name}{{quantile=\"0.99\"}} {:.3}\n\
                 {name}_count {}\n",
                ring.percentile(50.0) / 1e6,
                ring.percentile(99.0) / 1e6,
                ring.total());
        };
        summary("mc_ttft_ms_window",
                "time to first token (window quantiles, ms)",
                &self.ttft_ns.lock().unwrap());
        summary("mc_tpot_ms_window",
                "per-token decode latency (window, ms)",
                &self.tpot_ns.lock().unwrap());
        summary("mc_miss_stall_ms", "expert demand-miss stalls (window, ms)",
                &self.miss_stall_ns.lock().unwrap());

        // Lifetime cumulative histograms: unlike the *_window
        // summaries above these aggregate across instances and
        // scrape intervals (histogram_quantile over rate of buckets).
        let mut histogram = |name: &str, help: &str, h: &Histogram| {
            let help = escape_help(help);
            let _ = write!(out,
                "# HELP {name} {help}\n# TYPE {name} histogram\n");
            for (le, cum) in HIST_BOUNDS_MS.iter().zip(h.cumulative()) {
                let _ = write!(out,
                    "{name}_bucket{{le=\"{le}\"}} {cum}\n");
            }
            let _ = write!(out,
                "{name}_bucket{{le=\"+Inf\"}} {}\n\
                 {name}_sum {:.3}\n{name}_count {}\n",
                h.count(), h.sum_ms(), h.count());
        };
        histogram("mc_ttft_ms", "time to first token (lifetime, ms)",
                  &self.ttft_hist);
        histogram("mc_tpot_ms", "per-token decode latency (lifetime, ms)",
                  &self.tpot_hist);

        let _ = write!(out,
            "# HELP mc_kernel_backend selected SIMD kernel backend\n\
             # TYPE mc_kernel_backend gauge\n\
             mc_kernel_backend{{isa=\"{}\"}} 1\n",
            escape_label(&self.kernel_backend_name()));
        let _ = write!(out,
            "# HELP mc_build_info build metadata as labels \
             (value is always 1)\n\
             # TYPE mc_build_info gauge\n\
             mc_build_info{{version=\"{}\",kernel_isa=\"{}\"}} 1\n",
            escape_label(env!("CARGO_PKG_VERSION")),
            escape_label(&self.kernel_backend_name()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_render() {
        let m = Metrics::new();
        Metrics::inc(&m.requests_admitted, 2);
        Metrics::inc(&m.expert_calls, 90);
        Metrics::inc(&m.experts_pruned, 10);
        Metrics::set_gauge(&m.queue_depth, 3);
        Metrics::set_gauge(&m.batch_occupancy, 4);
        m.record_ttft(2_000_000);
        m.record_tpot(1_000_000);
        assert!((m.prune_ratio() - 0.1).abs() < 1e-9);
        assert!((m.tokens_per_sec() - 1000.0).abs() < 1e-6);
        let text = m.render_text();
        assert!(text.contains("mc_requests_admitted 2"));
        assert!(text.contains("mc_prune_ratio 0.1000"));
        assert!(text.contains("mc_queue_depth 3"));
        assert!(text.contains("mc_batch_occupancy 4"));
        // falls back to the process-wide dispatch table when unset
        assert!(text.contains("mc_kernel_backend{isa=\""), "{text}");
        m.set_kernel_backend("scalar");
        assert!(m.render_text().contains("mc_kernel_backend{isa=\"scalar\"} 1"));
        assert_eq!(m.kernel_backend_name(), "scalar");
    }

    #[test]
    fn ring_is_bounded_and_windows() {
        let mut r = LatencyRing::with_capacity(4);
        for v in 1..=10u64 {
            r.push(v);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        // retains the last 4 pushes {7,8,9,10}
        assert!((r.mean() - 8.5).abs() < 1e-12);
    }

    #[test]
    fn offload_counters_and_rates() {
        let m = Metrics::new();
        Metrics::inc(&m.expert_cache_hits, 9);
        Metrics::inc(&m.expert_cache_misses, 1);
        Metrics::inc(&m.expert_prefetch_issued, 4);
        Metrics::inc(&m.expert_prefetch_hits, 3);
        Metrics::set_gauge(&m.bytes_resident, 1234);
        m.record_miss_stall(2_000_000);
        assert!((m.cache_hit_rate() - 0.9).abs() < 1e-9);
        assert!((m.prefetch_hit_rate() - 0.75).abs() < 1e-9);
        let text = m.render_text();
        assert!(text.contains("mc_expert_cache_hits 9"));
        assert!(text.contains("mc_expert_cache_hit_rate 0.9000"));
        assert!(text.contains("mc_bytes_resident 1234"));
        assert!(text.contains("mc_miss_stall_ms_mean 2.000"));
        let line = m.cache_summary();
        assert!(line.contains("9 hits / 1 misses"), "{line}");
        assert!(line.contains("prefetch 3/4 hit"), "{line}");
    }

    #[test]
    fn ring_percentiles_interpolate() {
        let mut r = LatencyRing::with_capacity(8);
        assert_eq!(r.percentile(99.0), 0.0, "empty ring");
        for v in [10u64, 20, 30, 40] {
            r.push(v);
        }
        assert!((r.percentile(0.0) - 10.0).abs() < 1e-9);
        assert!((r.percentile(50.0) - 25.0).abs() < 1e-9);
        assert!((r.percentile(100.0) - 40.0).abs() < 1e-9);
        // order-independent: the window is sorted before ranking
        let mut rev = LatencyRing::with_capacity(8);
        for v in [40u64, 10, 30, 20] {
            rev.push(v);
        }
        assert_eq!(r.percentile(99.0), rev.percentile(99.0));
    }

    #[test]
    fn prometheus_exposition_has_types_and_series() {
        let m = Metrics::new();
        Metrics::inc(&m.requests_admitted, 3);
        Metrics::inc(&m.requests_shed, 2);
        Metrics::inc(&m.requests_tenant_limited, 1);
        Metrics::inc(&m.http_conns_accepted, 5);
        Metrics::set_gauge(&m.streams_inflight, 4);
        Metrics::set_gauge(&m.last_drain_ns, 7_000_000);
        m.record_ttft(2_000_000);
        m.record_ttft(4_000_000);
        m.set_kernel_backend("scalar");
        Metrics::inc(&m.expert_load_retries, 6);
        Metrics::inc(&m.expert_load_failures, 2);
        Metrics::inc(&m.experts_quarantined, 2);
        Metrics::inc(&m.degraded_dispatches, 9);
        Metrics::inc(&m.deadline_exceeded, 1);
        Metrics::inc(&m.panics_recovered, 1);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE mc_requests_admitted counter"));
        assert!(text.contains("mc_requests_admitted 3"));
        assert!(text.contains("mc_requests_shed 2"));
        assert!(text.contains("mc_requests_tenant_limited 1"));
        assert!(text.contains("mc_http_conns_accepted 5"));
        assert!(text.contains("# TYPE mc_streams_inflight gauge"));
        assert!(text.contains("mc_streams_inflight 4"));
        assert!(text.contains("mc_last_drain_ms 7"));
        assert!(text.contains("# TYPE mc_ttft_ms_window summary"));
        assert!(text.contains("mc_ttft_ms_window{quantile=\"0.5\"} 3.000"));
        assert!(text.contains("mc_ttft_ms_window_count 2"));
        // lifetime histogram rides alongside the window summary
        assert!(text.contains("# TYPE mc_ttft_ms histogram"));
        assert!(text.contains("mc_ttft_ms_bucket{le=\"2.5\"} 1"), "{text}");
        assert!(text.contains("mc_ttft_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("mc_ttft_ms_sum 6.000"));
        assert!(text.contains("mc_ttft_ms_count 2"));
        assert!(text.contains("# TYPE mc_tpot_ms histogram"));
        assert!(text.contains("mc_build_info{version=\""), "{text}");
        assert!(text.contains("kernel_isa=\"scalar\"} 1"), "{text}");
        assert!(text.contains("# TYPE mc_expert_load_retries counter"));
        assert!(text.contains("mc_expert_load_retries 6"));
        assert!(text.contains("mc_expert_load_failures 2"));
        assert!(text.contains("mc_experts_quarantined 2"));
        assert!(text.contains("mc_degraded_dispatches 9"));
        assert!(text.contains("mc_deadline_exceeded 1"));
        assert!(text.contains("mc_panics_recovered 1"));
        assert!(text.contains("mc_kernel_backend{isa=\"scalar\"} 1"));
        // every HELP has a matching TYPE
        assert_eq!(text.matches("# HELP").count(),
                   text.matches("# TYPE").count());
    }

    #[test]
    fn memory_governor_series_render() {
        let m = Metrics::new();
        Metrics::inc(&m.kv_prefix_hits, 3);
        Metrics::inc(&m.kv_pages_downquantized, 7);
        Metrics::inc(&m.mem_admission_rejected, 2);
        Metrics::inc(&m.mem_oom_injected, 1);
        Metrics::set_gauge(&m.mem_bytes_reserved, 4096);
        Metrics::set_gauge(&m.mem_budget_bytes, 8192);
        Metrics::set_gauge(&m.mem_pressure_rung, 2);
        let text = m.render_text();
        assert!(text.contains("mc_kv_prefix_hits 3"), "{text}");
        assert!(text.contains("mc_kv_pages_downquantized 7"));
        assert!(text.contains("mc_mem_bytes_reserved 4096"));
        assert!(text.contains("mc_mem_pressure_rung 2"));
        let prom = m.render_prometheus();
        assert!(prom.contains("# TYPE mc_kv_prefix_hits counter"));
        assert!(prom.contains("mc_mem_admission_rejected 2"));
        assert!(prom.contains("mc_mem_oom_injected 1"));
        assert!(prom.contains("# TYPE mc_mem_pressure_rung gauge"));
        assert!(prom.contains("mc_mem_budget_bytes 8192"));
        assert_eq!(prom.matches("# HELP").count(),
                   prom.matches("# TYPE").count());
    }

    #[test]
    fn metrics_latency_storage_is_bounded() {
        let m = Metrics::new();
        for i in 0..(RING_CAP as u64 + 100) {
            m.record_tpot(i);
        }
        let tpot = m.tpot_ns.lock().unwrap();
        assert_eq!(tpot.len(), RING_CAP);
        assert_eq!(tpot.total(), RING_CAP as u64 + 100);
    }

    #[test]
    fn histogram_buckets_cumulate_and_bound_overflow() {
        let h = Histogram::default();
        h.record_ns(500_000); // 0.5ms   -> le="1"
        h.record_ns(2_000_000); // 2ms   -> le="2.5"
        h.record_ns(2_500_000); // 2.5ms -> le="2.5" (boundary inclusive)
        h.record_ns(9_000_000_000); // 9s -> +Inf only
        let cum = h.cumulative();
        assert_eq!(cum[0], 1);
        assert_eq!(cum[1], 3);
        assert_eq!(cum[HIST_BOUNDS_MS.len() - 1], 3, "+Inf excluded");
        assert_eq!(h.count(), 4);
        assert!((h.sum_ms() - 9005.0).abs() < 1e-6);
        // cumulative counts never decrease across bounds
        assert!(cum.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn help_and_label_escaping() {
        assert_eq!(escape_help("a\\b\nc"), "a\\\\b\\nc");
        assert_eq!(escape_label("v\"q\\x\ny"), "v\\\"q\\\\x\\ny");
        let m = Metrics::new();
        m.set_kernel_backend("we\"ird\\isa");
        let text = m.render_prometheus();
        assert!(text.contains("mc_kernel_backend{isa=\"we\\\"ird\\\\isa\"} 1"),
                "{text}");
        assert!(text.contains("kernel_isa=\"we\\\"ird\\\\isa\"} 1"), "{text}");
    }

    /// Promlint-style exposition validation: the whole rendered block
    /// must satisfy the text-format grammar — legal metric names,
    /// HELP/TYPE declared once per family and before its samples,
    /// every sample attributable to a declared family (modulo the
    /// summary/histogram `_bucket`/`_sum`/`_count` suffixes), and
    /// histogram buckets cumulative with a closing `+Inf`.
    #[test]
    fn prometheus_exposition_passes_promlint_rules() {
        let m = Metrics::new();
        m.record_ttft(3_000_000);
        m.record_tpot(700_000);
        m.record_miss_stall(50_000);
        m.set_kernel_backend("scalar");
        Metrics::inc(&m.requests_admitted, 1);
        let text = m.render_prometheus();

        let name_ok = |n: &str| {
            !n.is_empty()
                && n.chars().next().is_some_and(|c| {
                    c.is_ascii_alphabetic() || c == '_' || c == ':'
                })
                && n.chars().all(|c| {
                    c.is_ascii_alphanumeric() || c == '_' || c == ':'
                })
        };

        let mut families: Vec<(String, String)> = Vec::new(); // (name, type)
        let mut helped: Vec<String> = Vec::new();
        let mut last_bucket: Option<(String, u64)> = None;
        for line in text.lines() {
            assert!(!line.trim().is_empty(), "no blank lines in exposition");
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split(' ').next().unwrap().to_string();
                assert!(name_ok(&name), "bad family name {name:?}");
                assert!(!helped.contains(&name), "duplicate HELP {name}");
                helped.push(name);
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let name = it.next().unwrap().to_string();
                let ty = it.next().unwrap().to_string();
                assert!(["counter", "gauge", "summary", "histogram"]
                            .contains(&ty.as_str()),
                        "unknown type {ty}");
                assert_eq!(helped.last(), Some(&name),
                           "TYPE must follow its own HELP: {name}");
                assert!(!families.iter().any(|(n, _)| *n == name),
                        "duplicate TYPE {name}");
                families.push((name, ty));
                continue;
            }
            assert!(!line.starts_with('#'), "unknown comment: {line}");
            // sample line: name{labels} value
            let name_end = line.find(['{', ' ']).expect("sample has value");
            let sample = &line[..name_end];
            assert!(name_ok(sample), "bad sample name {sample:?}");
            let (fam, ty) = families
                .iter()
                .rev()
                .find(|(n, ty)| {
                    sample == n
                        || (["summary", "histogram"].contains(&ty.as_str())
                            && (sample == format!("{n}_sum")
                                || sample == format!("{n}_count")))
                        || (ty == "histogram"
                            && sample == format!("{n}_bucket"))
                })
                .unwrap_or_else(|| panic!("orphan sample {sample}"));
            let value: f64 = line
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap_or_else(|_| panic!("unparseable value: {line}"));
            if ty == "histogram" && sample == format!("{fam}_bucket") {
                // cumulative within one family, closed by +Inf
                let cum = value as u64;
                if let Some((prev_fam, prev)) = &last_bucket {
                    if prev_fam == fam {
                        assert!(*prev <= cum,
                                "buckets must cumulate in {fam}");
                    }
                }
                last_bucket = Some((fam.clone(), cum));
                if line.contains("le=\"+Inf\"") {
                    last_bucket = None;
                }
            }
        }
        assert_eq!(helped.len(), families.len(), "every HELP has a TYPE");
        assert!(last_bucket.is_none(), "every histogram ends with +Inf");
        for (n, ty) in &families {
            assert!(n.starts_with("mc_"), "family {n} missing mc_ prefix");
            if ty == "histogram" {
                assert!(text.contains(&format!("{n}_bucket{{le=\"+Inf\"}}")));
            }
        }
    }
}
