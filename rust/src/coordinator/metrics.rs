//! Serving metrics: counters, gauges, and bounded latency records,
//! printable as a prometheus-style text block.
//!
//! Latency samples (TTFT / per-token) live in fixed-capacity rings so
//! a long-lived server's memory stays O(1) no matter how many
//! requests it has served; summary statistics are over the most
//! recent `RING_CAP` samples (a sliding window, which is also what an
//! operator wants from a live gauge).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Retained latency samples per series.
pub const RING_CAP: usize = 4096;

/// Fixed-capacity overwrite-oldest sample buffer.
#[derive(Debug)]
pub struct LatencyRing {
    cap: usize,
    buf: Vec<u64>,
    next: usize,
    /// lifetime pushes (>= buf.len(); buf holds the most recent cap)
    total: u64,
}

impl Default for LatencyRing {
    fn default() -> LatencyRing {
        LatencyRing::with_capacity(RING_CAP)
    }
}

impl LatencyRing {
    pub fn with_capacity(cap: usize) -> LatencyRing {
        assert!(cap > 0);
        LatencyRing { cap, buf: Vec::new(), next: 0, total: 0 }
    }

    pub fn push(&mut self, v: u64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Lifetime number of pushes.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Mean of the retained window (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<u64>() as f64 / self.buf.len() as f64
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_cancelled: AtomicU64,
    /// invalid requests (empty prompt) turned away at admission
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub expert_calls: AtomicU64,
    pub experts_pruned: AtomicU64,
    /// gauge: requests waiting in the admission queue (set per step)
    pub queue_depth: AtomicU64,
    /// gauge: active decode sessions in the fused batch (set per step)
    pub batch_occupancy: AtomicU64,
    /// time-to-first-token samples (ns), last `RING_CAP` retained
    pub ttft_ns: Mutex<LatencyRing>,
    /// per-token decode latencies (ns), last `RING_CAP` retained
    pub tpot_ns: Mutex<LatencyRing>,
    // --- expert residency (offload::ExpertCache, DESIGN.md §5) ---
    /// demand accesses served from the cache
    pub expert_cache_hits: AtomicU64,
    /// demand accesses that had to load from the store
    pub expert_cache_misses: AtomicU64,
    /// experts dropped by the clock sweep to meet the byte budget
    pub expert_cache_evictions: AtomicU64,
    /// speculative loads the prefetcher actually performed
    pub expert_prefetch_issued: AtomicU64,
    /// prefetched experts later demanded before eviction
    pub expert_prefetch_hits: AtomicU64,
    /// gauge: expert bytes currently resident in the cache
    pub bytes_resident: AtomicU64,
    /// demand-miss load stalls (ns), last `RING_CAP` retained
    pub miss_stall_ns: Mutex<LatencyRing>,
    /// info: kernel backend ISA the engine selected at startup
    /// (empty until [`Metrics::set_kernel_backend`]; bench JSONs copy
    /// it so every number records which backend produced it)
    pub kernel_backend: Mutex<String>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn set_gauge(gauge: &AtomicU64, v: u64) {
        gauge.store(v, Ordering::Relaxed);
    }

    pub fn record_ttft(&self, ns: u64) {
        self.ttft_ns.lock().unwrap().push(ns);
    }

    pub fn record_tpot(&self, ns: u64) {
        self.tpot_ns.lock().unwrap().push(ns);
    }

    pub fn record_miss_stall(&self, ns: u64) {
        self.miss_stall_ns.lock().unwrap().push(ns);
    }

    /// Record which kernel backend the engine selected (engine/server
    /// startup calls this right after `kernels::log_selection()`).
    pub fn set_kernel_backend(&self, isa: &str) {
        *self.kernel_backend.lock().unwrap() = isa.to_string();
    }

    /// The recorded backend name, falling back to whatever the
    /// process-wide dispatch table resolved to (covers callers that
    /// render metrics without going through an engine).
    pub fn kernel_backend_name(&self) -> String {
        let s = self.kernel_backend.lock().unwrap().clone();
        if s.is_empty() {
            crate::kernels::active().isa.name().to_string()
        } else {
            s
        }
    }

    /// Fraction of expert demand accesses served without a store load.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.expert_cache_hits.load(Ordering::Relaxed);
        let misses = self.expert_cache_misses.load(Ordering::Relaxed);
        if hits + misses == 0 {
            return 0.0;
        }
        hits as f64 / (hits + misses) as f64
    }

    /// Fraction of issued prefetches that were later demanded.
    pub fn prefetch_hit_rate(&self) -> f64 {
        let issued = self.expert_prefetch_issued.load(Ordering::Relaxed);
        if issued == 0 {
            return 0.0;
        }
        self.expert_prefetch_hits.load(Ordering::Relaxed) as f64 / issued as f64
    }

    /// One-line expert-cache report (the CLI and examples all render
    /// this instead of hand-assembling the counters).
    pub fn cache_summary(&self) -> String {
        format!(
            "{} hits / {} misses ({:.1}% hit) | prefetch {}/{} hit | \
             {} evictions | miss stall {:.3}ms mean | resident {:.2} MB",
            self.expert_cache_hits.load(Ordering::Relaxed),
            self.expert_cache_misses.load(Ordering::Relaxed),
            100.0 * self.cache_hit_rate(),
            self.expert_prefetch_hits.load(Ordering::Relaxed),
            self.expert_prefetch_issued.load(Ordering::Relaxed),
            self.expert_cache_evictions.load(Ordering::Relaxed),
            self.miss_stall_ns.lock().unwrap().mean() / 1e6,
            self.bytes_resident.load(Ordering::Relaxed) as f64 / 1e6,
        )
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let mean_ns = self.tpot_ns.lock().unwrap().mean();
        if mean_ns == 0.0 {
            return 0.0;
        }
        1e9 / mean_ns
    }

    pub fn prune_ratio(&self) -> f64 {
        let calls = self.expert_calls.load(Ordering::Relaxed);
        let pruned = self.experts_pruned.load(Ordering::Relaxed);
        if calls + pruned == 0 {
            return 0.0;
        }
        pruned as f64 / (calls + pruned) as f64
    }

    pub fn render_text(&self) -> String {
        let ttft_ms = self.ttft_ns.lock().unwrap().mean() / 1e6;
        let stall_ms = self.miss_stall_ns.lock().unwrap().mean() / 1e6;
        let backend = self.kernel_backend_name();
        format!(
            "mc_requests_admitted {}\nmc_requests_completed {}\n\
             mc_requests_cancelled {}\nmc_requests_rejected {}\n\
             mc_tokens_generated {}\n\
             mc_tokens_per_sec {:.2}\n\
             mc_expert_calls {}\nmc_experts_pruned {}\n\
             mc_prune_ratio {:.4}\nmc_ttft_ms_mean {:.3}\n\
             mc_queue_depth {}\nmc_batch_occupancy {}\n\
             mc_expert_cache_hits {}\nmc_expert_cache_misses {}\n\
             mc_expert_cache_evictions {}\n\
             mc_expert_prefetch_issued {}\nmc_expert_prefetch_hits {}\n\
             mc_expert_cache_hit_rate {:.4}\n\
             mc_expert_prefetch_hit_rate {:.4}\n\
             mc_bytes_resident {}\nmc_miss_stall_ms_mean {:.3}\n\
             mc_kernel_backend{{isa=\"{}\"}} 1\n",
            self.requests_admitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.tokens_per_sec(),
            self.expert_calls.load(Ordering::Relaxed),
            self.experts_pruned.load(Ordering::Relaxed),
            self.prune_ratio(),
            ttft_ms,
            self.queue_depth.load(Ordering::Relaxed),
            self.batch_occupancy.load(Ordering::Relaxed),
            self.expert_cache_hits.load(Ordering::Relaxed),
            self.expert_cache_misses.load(Ordering::Relaxed),
            self.expert_cache_evictions.load(Ordering::Relaxed),
            self.expert_prefetch_issued.load(Ordering::Relaxed),
            self.expert_prefetch_hits.load(Ordering::Relaxed),
            self.cache_hit_rate(),
            self.prefetch_hit_rate(),
            self.bytes_resident.load(Ordering::Relaxed),
            stall_ms,
            backend,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_render() {
        let m = Metrics::new();
        Metrics::inc(&m.requests_admitted, 2);
        Metrics::inc(&m.expert_calls, 90);
        Metrics::inc(&m.experts_pruned, 10);
        Metrics::set_gauge(&m.queue_depth, 3);
        Metrics::set_gauge(&m.batch_occupancy, 4);
        m.record_ttft(2_000_000);
        m.record_tpot(1_000_000);
        assert!((m.prune_ratio() - 0.1).abs() < 1e-9);
        assert!((m.tokens_per_sec() - 1000.0).abs() < 1e-6);
        let text = m.render_text();
        assert!(text.contains("mc_requests_admitted 2"));
        assert!(text.contains("mc_prune_ratio 0.1000"));
        assert!(text.contains("mc_queue_depth 3"));
        assert!(text.contains("mc_batch_occupancy 4"));
        // falls back to the process-wide dispatch table when unset
        assert!(text.contains("mc_kernel_backend{isa=\""), "{text}");
        m.set_kernel_backend("scalar");
        assert!(m.render_text().contains("mc_kernel_backend{isa=\"scalar\"} 1"));
        assert_eq!(m.kernel_backend_name(), "scalar");
    }

    #[test]
    fn ring_is_bounded_and_windows() {
        let mut r = LatencyRing::with_capacity(4);
        for v in 1..=10u64 {
            r.push(v);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        // retains the last 4 pushes {7,8,9,10}
        assert!((r.mean() - 8.5).abs() < 1e-12);
    }

    #[test]
    fn offload_counters_and_rates() {
        let m = Metrics::new();
        Metrics::inc(&m.expert_cache_hits, 9);
        Metrics::inc(&m.expert_cache_misses, 1);
        Metrics::inc(&m.expert_prefetch_issued, 4);
        Metrics::inc(&m.expert_prefetch_hits, 3);
        Metrics::set_gauge(&m.bytes_resident, 1234);
        m.record_miss_stall(2_000_000);
        assert!((m.cache_hit_rate() - 0.9).abs() < 1e-9);
        assert!((m.prefetch_hit_rate() - 0.75).abs() < 1e-9);
        let text = m.render_text();
        assert!(text.contains("mc_expert_cache_hits 9"));
        assert!(text.contains("mc_expert_cache_hit_rate 0.9000"));
        assert!(text.contains("mc_bytes_resident 1234"));
        assert!(text.contains("mc_miss_stall_ms_mean 2.000"));
        let line = m.cache_summary();
        assert!(line.contains("9 hits / 1 misses"), "{line}");
        assert!(line.contains("prefetch 3/4 hit"), "{line}");
    }

    #[test]
    fn metrics_latency_storage_is_bounded() {
        let m = Metrics::new();
        for i in 0..(RING_CAP as u64 + 100) {
            m.record_tpot(i);
        }
        let tpot = m.tpot_ns.lock().unwrap();
        assert_eq!(tpot.len(), RING_CAP);
        assert_eq!(tpot.total(), RING_CAP as u64 + 100);
    }
}
