//! Serving metrics: counters + latency records, printable as a
//! prometheus-style text block or JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub expert_calls: AtomicU64,
    pub experts_pruned: AtomicU64,
    /// time-to-first-token samples (ns)
    pub ttft_ns: Mutex<Vec<u64>>,
    /// per-token decode latencies (ns)
    pub tpot_ns: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn record_ttft(&self, ns: u64) {
        self.ttft_ns.lock().unwrap().push(ns);
    }

    pub fn record_tpot(&self, ns: u64) {
        self.tpot_ns.lock().unwrap().push(ns);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let tpot = self.tpot_ns.lock().unwrap();
        if tpot.is_empty() {
            return 0.0;
        }
        let mean_ns = tpot.iter().sum::<u64>() as f64 / tpot.len() as f64;
        1e9 / mean_ns
    }

    pub fn prune_ratio(&self) -> f64 {
        let calls = self.expert_calls.load(Ordering::Relaxed);
        let pruned = self.experts_pruned.load(Ordering::Relaxed);
        if calls + pruned == 0 {
            return 0.0;
        }
        pruned as f64 / (calls + pruned) as f64
    }

    pub fn render_text(&self) -> String {
        let ttft = self.ttft_ns.lock().unwrap();
        let ttft_ms = if ttft.is_empty() {
            0.0
        } else {
            ttft.iter().sum::<u64>() as f64 / ttft.len() as f64 / 1e6
        };
        format!(
            "mc_requests_admitted {}\nmc_requests_completed {}\n\
             mc_tokens_generated {}\nmc_tokens_per_sec {:.2}\n\
             mc_expert_calls {}\nmc_experts_pruned {}\n\
             mc_prune_ratio {:.4}\nmc_ttft_ms_mean {:.3}\n",
            self.requests_admitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.tokens_generated.load(Ordering::Relaxed),
            self.tokens_per_sec(),
            self.expert_calls.load(Ordering::Relaxed),
            self.experts_pruned.load(Ordering::Relaxed),
            self.prune_ratio(),
            ttft_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_render() {
        let m = Metrics::new();
        Metrics::inc(&m.requests_admitted, 2);
        Metrics::inc(&m.expert_calls, 90);
        Metrics::inc(&m.experts_pruned, 10);
        m.record_ttft(2_000_000);
        m.record_tpot(1_000_000);
        assert!((m.prune_ratio() - 0.1).abs() < 1e-9);
        assert!((m.tokens_per_sec() - 1000.0).abs() < 1e-6);
        let text = m.render_text();
        assert!(text.contains("mc_requests_admitted 2"));
        assert!(text.contains("mc_prune_ratio 0.1000"));
    }
}
