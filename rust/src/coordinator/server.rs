//! Threaded request server: a worker thread owns the batcher and
//! drives continuous batching; clients submit `GenerateRequest`s over
//! an mpsc channel and get back a `RequestHandle` whose stream
//! delivers every token the fused step produces, then a terminal
//! `Done`/`Cancelled` event. `RequestHandle::cancel()` raises a flag
//! the batcher reaps at its next step, retiring the session and
//! freeing its batch slot for the queue. (The offline image has no
//! tokio; std threads + channels own the event loop, which at 1 core
//! is the honest architecture anyway.)

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::moe::model::MoeModel;

use super::batcher::Batcher;
use super::decode::DecodeOdp;
use super::memgov::MemoryGovernor;
use super::metrics::Metrics;
use super::request::{
    request_channel, Completion, FinishReason, GenerateRequest,
    RequestHandle, RequestTicket, StreamEvent,
};

enum Msg {
    Submit(GenerateRequest, RequestTicket),
    Shutdown,
}

/// Server tuning knobs (DESIGN.md §7). `Server::spawn` keeps the
/// historical 3-arg signature with everything else at `Default`.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// deadline for requests that don't carry their own (None = no
    /// limit, the historical behavior)
    pub default_deadline: Option<Duration>,
    /// how long a stream may go without emitting any event before the
    /// watchdog declares it stalled and cancels it
    pub stall_budget: Duration,
    /// watchdog scan interval
    pub watchdog_poll: Duration,
    /// memory-governor byte ceiling (`--mem-budget-mb`); `None` falls
    /// back to `MC_MEM_BUDGET_MB`, then to the derived worst-case
    /// default that keeps unconstrained runs below the first rung
    /// (DESIGN.md §8)
    pub mem_budget: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_batch: 4,
            default_deadline: None,
            stall_budget: Duration::from_secs(30),
            watchdog_poll: Duration::from_millis(5),
            mem_budget: None,
        }
    }
}

/// One watchdog-tracked request.
struct Watch {
    ticket: RequestTicket,
    /// absolute expiry (submission + effective deadline)
    deadline: Option<Instant>,
    last_events: u64,
    last_progress: Instant,
    /// when the watchdog raised the cancel flag; after a grace period
    /// with no terminal event from the batcher, the watchdog sends the
    /// terminal itself so the client can never wedge
    cancelled_at: Option<Instant>,
}

/// How long after a watchdog cancel the batcher gets to deliver the
/// terminal event before the watchdog force-terminates the stream.
const TERMINAL_GRACE: Duration = Duration::from_millis(500);

pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    watchdog: Option<JoinHandle<()>>,
    watchdog_stop: Arc<AtomicBool>,
    watches: Arc<Mutex<Vec<Watch>>>,
    default_deadline: Option<Duration>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    /// memory governor shared with the worker's batcher; front ends
    /// reserve session footprints here before submitting (503 path)
    governor: Arc<MemoryGovernor>,
    /// submitted-but-unfinished estimate: bumped on `submit`, snapped
    /// to `batcher.pending()` every worker iteration. Front ends use
    /// it as a queue-pressure signal without waiting a step.
    pending_hint: Arc<AtomicU64>,
    /// the model the worker's batcher decodes with, retained so serve
    /// introspection (`/debug/experts`) can join live routing heat
    /// with the resolver's residency/quarantine state
    model: Arc<MoeModel>,
}

impl Server {
    pub fn spawn(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
                 max_batch: usize) -> Server {
        Server::spawn_cfg(model, odp,
                          ServerConfig { max_batch, ..Default::default() })
    }

    pub fn spawn_cfg(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
                     cfg: ServerConfig) -> Server {
        // pin + announce the kernel dispatch table before the worker
        // thread takes its first request (one banner per process)
        let kops = crate::kernels::log_selection();
        // adopt a cache-resolved model's Metrics (hit/miss/stall land
        // in the same snapshot the batcher's counters do)
        let metrics = model
            .resolver
            .metrics()
            .unwrap_or_else(|| Arc::new(Metrics::new()));
        metrics.set_kernel_backend(kops.isa.name());
        let m2 = metrics.clone();
        let pending_hint = Arc::new(AtomicU64::new(0));
        let hint = pending_hint.clone();
        let default_deadline = cfg.default_deadline;
        // every byte-sized allocation class — expert residency budget,
        // fused-step scratch arenas, per-session KV pages — accounts
        // against this one ceiling (DESIGN.md §8)
        let budget_override = cfg.mem_budget.or_else(|| {
            std::env::var("MC_MEM_BUDGET_MB")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .map(|mb| mb << 20)
        });
        let governor = MemoryGovernor::for_model(
            &model.cfg,
            model.resolver.budget_bytes(),
            cfg.max_batch,
            budget_override,
            metrics.clone(),
        );
        let gov2 = governor.clone();
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let retained_model = model.clone();
        let worker = std::thread::spawn(move || {
            let mut batcher = Batcher::new(model, odp, cfg.max_batch);
            batcher.set_default_deadline(default_deadline);
            batcher.set_governor(gov2);
            let mut shutdown = false;
            loop {
                // drain the mailbox (block only when idle)
                if batcher.pending() == 0 {
                    match rx.recv() {
                        Ok(Msg::Submit(req, ticket)) => {
                            batcher.submit_with_ticket(req, ticket);
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                }
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Submit(req, ticket) => {
                            batcher.submit_with_ticket(req, ticket);
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                // the step streams tokens and terminal events to each
                // request's own channel; completions need no routing
                batcher.step(&m2);
                hint.store(batcher.pending() as u64, Ordering::Relaxed);
                if shutdown && batcher.pending() == 0 {
                    break;
                }
            }
            hint.store(0, Ordering::Relaxed);
        });

        // watchdog: scans tracked requests for blown deadlines and
        // stalled streams. It never touches the batcher directly —
        // it raises the ticket's cancel/deadline flags (the batcher
        // reaps them next step) and only force-terminates a stream
        // itself if the batcher is too wedged to do so (DESIGN.md §7).
        let watches: Arc<Mutex<Vec<Watch>>> = Arc::new(Mutex::new(Vec::new()));
        let watchdog_stop = Arc::new(AtomicBool::new(false));
        let (w2, stop2, m3) =
            (watches.clone(), watchdog_stop.clone(), metrics.clone());
        let (stall, poll) = (cfg.stall_budget, cfg.watchdog_poll);
        let watchdog = std::thread::Builder::new()
            .name("mc-watchdog".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(poll);
                    let now = Instant::now();
                    let mut ws = w2.lock().unwrap();
                    ws.retain_mut(|w| {
                        if w.ticket.terminated() {
                            return false;
                        }
                        let ev = w.ticket.events();
                        if ev != w.last_events {
                            w.last_events = ev;
                            w.last_progress = now;
                        }
                        match w.cancelled_at {
                            None => {
                                let blown = w
                                    .deadline
                                    .is_some_and(|d| now >= d)
                                    || now.duration_since(w.last_progress)
                                        >= stall;
                                if blown {
                                    w.ticket.set_deadline_exceeded();
                                    w.ticket.cancel();
                                    w.cancelled_at = Some(now);
                                }
                                true
                            }
                            Some(t) => {
                                if now.duration_since(t) < TERMINAL_GRACE {
                                    return true;
                                }
                                // the batcher never delivered a
                                // terminal: unwedge the client here
                                if w.ticket.claim_terminal() {
                                    Metrics::inc(&m3.deadline_exceeded, 1);
                                    w.ticket.send(StreamEvent::Done(
                                        Completion {
                                            id: w.ticket.id,
                                            tokens: Vec::new(),
                                            finish:
                                                FinishReason::DeadlineExceeded,
                                            ttft_ns: 0,
                                            total_ns: 0,
                                        },
                                    ));
                                }
                                false
                            }
                        }
                    });
                }
            })
            .expect("spawn mc-watchdog");

        Server {
            tx,
            worker: Some(worker),
            watchdog: Some(watchdog),
            watchdog_stop,
            watches,
            default_deadline,
            next_id: AtomicU64::new(1),
            metrics,
            governor,
            pending_hint,
            model: retained_model,
        }
    }

    /// The memory governor shared with the batcher: front ends consult
    /// it for admission (worst-case reservation before `submit`) and
    /// expose its pressure/rung gauges.
    pub fn governor(&self) -> &Arc<MemoryGovernor> {
        &self.governor
    }

    /// The served model (read-only; the worker thread owns decode).
    /// Serve-tier introspection reads its resolver and config.
    pub fn model(&self) -> &Arc<MoeModel> {
        &self.model
    }

    /// Submit a request; the handle streams `Token` events as the
    /// fused batcher produces them, supports `cancel()` mid-flight,
    /// and terminates with `Done(Completion)` or `Cancelled`.
    pub fn submit(&self, req: GenerateRequest) -> RequestHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, handle) = request_channel(id);
        self.pending_hint.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        self.watches.lock().unwrap().push(Watch {
            ticket: ticket.clone(),
            deadline: req
                .deadline
                .or(self.default_deadline)
                .map(|d| now + d),
            last_events: 0,
            last_progress: now,
            cancelled_at: None,
        });
        let _ = self.tx.send(Msg::Submit(req, ticket));
        handle
    }

    /// Submitted-but-unfinished request estimate (see field docs);
    /// eventually consistent with the batcher's own `pending()`.
    pub fn pending_hint(&self) -> usize {
        self.pending_hint.load(Ordering::Relaxed) as usize
    }

    /// Convenience: greedy request with default stop/priority.
    pub fn submit_greedy(&self, prompt: Vec<u32>, max_new_tokens: usize)
                         -> RequestHandle {
        self.submit(GenerateRequest::greedy(prompt, max_new_tokens))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stop_watchdog();
    }

    fn stop_watchdog(&mut self) {
        self.watchdog_stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.watchdog.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.stop_watchdog();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::request::StreamEvent;
    use crate::moe::model::tests::random_model;

    /// Generous server-enforced deadline for tests: instead of each
    /// client hand-rolling a `wait_timeout(30s)`, the server's own
    /// deadline machinery bounds every request, so a wedged test fails
    /// with `DeadlineExceeded` rather than hanging the suite.
    fn test_cfg(max_batch: usize) -> ServerConfig {
        ServerConfig {
            max_batch,
            default_deadline: Some(Duration::from_secs(30)),
            ..Default::default()
        }
    }

    #[test]
    fn serves_concurrent_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 0));
        let server = Server::spawn_cfg(model, None, test_cfg(4));
        let handles: Vec<_> = (0..6)
            .map(|i| server.submit_greedy(vec![1, 5, 80 + i, 3], 5))
            .collect();
        for h in handles {
            let done = h.wait().expect("completion");
            assert_ne!(done.finish, FinishReason::DeadlineExceeded);
            assert!(!done.tokens.is_empty());
        }
        assert_eq!(
            server.metrics.requests_completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn streams_tokens_before_done() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 1));
        let server = Server::spawn(model, None, 2);
        let mut h = server.submit_greedy(vec![1, 5, 80, 3], 5);
        let mut streamed = Vec::new();
        let mut done = None;
        let mut cancelled = false;
        while let Some(ev) = h.next_event() {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(c) => done = Some(c),
                StreamEvent::Cancelled { .. } => cancelled = true,
            }
        }
        assert!(!cancelled, "request must not be cancelled");
        let done = done.expect("terminal Done event");
        assert!(!streamed.is_empty());
        assert_eq!(streamed, done.tokens,
                   "stream delivers exactly the completion's tokens");
        server.shutdown();
    }

    #[test]
    fn shutdown_without_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 1));
        let server = Server::spawn(model, None, 2);
        server.shutdown();
    }

    #[test]
    fn per_request_deadline_terminates_stream() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 2));
        let server = Server::spawn_cfg(model, None, test_cfg(2));
        // zero budget: expired on arrival, so the outcome can't race
        // decode speed — the stream must still terminate cleanly
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 512)
            .with_deadline(Duration::ZERO);
        let done = server
            .submit(req)
            .wait()
            .expect("deadline produces a terminal Done, never a hang");
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert!(
            server.metrics.deadline_exceeded.load(Ordering::Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn watchdog_unwedges_client_when_batcher_never_answers() {
        // a server whose worker is already gone simulates a wedged
        // batcher: the watchdog must deliver the terminal event itself
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 3));
        let cfg = ServerConfig {
            max_batch: 1,
            default_deadline: Some(Duration::from_millis(10)),
            stall_budget: Duration::from_millis(10),
            watchdog_poll: Duration::from_millis(1),
            mem_budget: None,
        };
        let mut server = Server::spawn_cfg(model, None, cfg);
        // kill the worker under the watchdog's feet
        let _ = server.tx.send(Msg::Shutdown);
        if let Some(w) = server.worker.take() {
            let _ = w.join();
        }
        let id = server.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, handle) = request_channel(id);
        let now = Instant::now();
        server.watches.lock().unwrap().push(Watch {
            ticket,
            deadline: Some(now + Duration::from_millis(10)),
            last_events: 0,
            last_progress: now,
            cancelled_at: None,
        });
        let done = handle.wait().expect("watchdog-sent terminal Done");
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert_eq!(
            server.metrics.deadline_exceeded.load(Ordering::Relaxed), 1);
        drop(server);
    }
}
