//! Threaded request server: a worker thread owns the batcher and
//! drives continuous batching; clients submit `GenerateRequest`s over
//! an mpsc channel and get back a `RequestHandle` whose stream
//! delivers every token the fused step produces, then a terminal
//! `Done`/`Cancelled` event. `RequestHandle::cancel()` raises a flag
//! the batcher reaps at its next step, retiring the session and
//! freeing its batch slot for the queue. (The offline image has no
//! tokio; std threads + channels own the event loop, which at 1 core
//! is the honest architecture anyway.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::moe::model::MoeModel;

use super::batcher::Batcher;
use super::decode::DecodeOdp;
use super::metrics::Metrics;
use super::request::{
    request_channel, GenerateRequest, RequestHandle, RequestTicket,
};

enum Msg {
    Submit(GenerateRequest, RequestTicket),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    /// submitted-but-unfinished estimate: bumped on `submit`, snapped
    /// to `batcher.pending()` every worker iteration. Front ends use
    /// it as a queue-pressure signal without waiting a step.
    pending_hint: Arc<AtomicU64>,
}

impl Server {
    pub fn spawn(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
                 max_batch: usize) -> Server {
        // pin + announce the kernel dispatch table before the worker
        // thread takes its first request (one banner per process)
        let kops = crate::kernels::log_selection();
        // adopt a cache-resolved model's Metrics (hit/miss/stall land
        // in the same snapshot the batcher's counters do)
        let metrics = model
            .resolver
            .metrics()
            .unwrap_or_else(|| Arc::new(Metrics::new()));
        metrics.set_kernel_backend(kops.isa.name());
        let m2 = metrics.clone();
        let pending_hint = Arc::new(AtomicU64::new(0));
        let hint = pending_hint.clone();
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let worker = std::thread::spawn(move || {
            let mut batcher = Batcher::new(model, odp, max_batch);
            let mut shutdown = false;
            loop {
                // drain the mailbox (block only when idle)
                if batcher.pending() == 0 {
                    match rx.recv() {
                        Ok(Msg::Submit(req, ticket)) => {
                            batcher.submit_with_ticket(req, ticket);
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                }
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Submit(req, ticket) => {
                            batcher.submit_with_ticket(req, ticket);
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                // the step streams tokens and terminal events to each
                // request's own channel; completions need no routing
                batcher.step(&m2);
                hint.store(batcher.pending() as u64, Ordering::Relaxed);
                if shutdown && batcher.pending() == 0 {
                    break;
                }
            }
            hint.store(0, Ordering::Relaxed);
        });
        Server {
            tx,
            worker: Some(worker),
            next_id: AtomicU64::new(1),
            metrics,
            pending_hint,
        }
    }

    /// Submit a request; the handle streams `Token` events as the
    /// fused batcher produces them, supports `cancel()` mid-flight,
    /// and terminates with `Done(Completion)` or `Cancelled`.
    pub fn submit(&self, req: GenerateRequest) -> RequestHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, handle) = request_channel(id);
        self.pending_hint.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Msg::Submit(req, ticket));
        handle
    }

    /// Submitted-but-unfinished request estimate (see field docs);
    /// eventually consistent with the batcher's own `pending()`.
    pub fn pending_hint(&self) -> usize {
        self.pending_hint.load(Ordering::Relaxed) as usize
    }

    /// Convenience: greedy request with default stop/priority.
    pub fn submit_greedy(&self, prompt: Vec<u32>, max_new_tokens: usize)
                         -> RequestHandle {
        self.submit(GenerateRequest::greedy(prompt, max_new_tokens))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::request::StreamEvent;
    use crate::moe::model::tests::random_model;

    #[test]
    fn serves_concurrent_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 0));
        let server = Server::spawn(model, None, 4);
        let handles: Vec<_> = (0..6)
            .map(|i| server.submit_greedy(vec![1, 5, 80 + i, 3], 5))
            .collect();
        for mut h in handles {
            let done = h
                .wait_timeout(std::time::Duration::from_secs(30))
                .expect("completion");
            assert!(!done.tokens.is_empty());
        }
        assert_eq!(
            server.metrics.requests_completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn streams_tokens_before_done() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 1));
        let server = Server::spawn(model, None, 2);
        let mut h = server.submit_greedy(vec![1, 5, 80, 3], 5);
        let mut streamed = Vec::new();
        let mut done = None;
        while let Some(ev) = h.next_event() {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(c) => done = Some(c),
                StreamEvent::Cancelled { .. } => panic!("not cancelled"),
            }
        }
        let done = done.expect("terminal Done event");
        assert!(!streamed.is_empty());
        assert_eq!(streamed, done.tokens,
                   "stream delivers exactly the completion's tokens");
        server.shutdown();
    }

    #[test]
    fn shutdown_without_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 1));
        let server = Server::spawn(model, None, 2);
        server.shutdown();
    }
}
