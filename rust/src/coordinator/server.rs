//! Threaded request server: a worker thread owns the batcher and
//! drives continuous batching; clients submit requests over an mpsc
//! channel and receive completions on per-request channels. (The
//! offline image has no tokio; std threads + channels own the event
//! loop, which at 1 core is the honest architecture anyway.)

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::moe::model::MoeModel;

use super::batcher::{Batcher, Completion, Request};
use super::decode::DecodeOdp;
use super::metrics::Metrics;

enum Msg {
    Submit(Request, Sender<Completion>),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Server {
    pub fn spawn(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
                 max_batch: usize) -> Server {
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let worker = std::thread::spawn(move || {
            let mut batcher = Batcher::new(model, odp, max_batch);
            let mut reply: BTreeMap<u64, Sender<Completion>> = BTreeMap::new();
            let mut shutdown = false;
            loop {
                // drain the mailbox (block only when idle)
                if batcher.pending() == 0 {
                    match rx.recv() {
                        Ok(Msg::Submit(req, ch)) => {
                            reply.insert(req.id, ch);
                            batcher.submit(req);
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                }
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Submit(req, ch) => {
                            reply.insert(req.id, ch);
                            batcher.submit(req);
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                for done in batcher.step(&m2) {
                    if let Some(ch) = reply.remove(&done.id) {
                        let _ = ch.send(done);
                    }
                }
                if shutdown && batcher.pending() == 0 {
                    break;
                }
            }
        });
        Server { tx, worker: Some(worker), next_id: AtomicU64::new(1), metrics }
    }

    /// Submit a prompt; returns a receiver for the completion.
    pub fn submit(&self, prompt: Vec<u32>, max_new_tokens: usize)
                  -> Receiver<Completion> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, prompt, max_new_tokens, temperature: None };
        let _ = self.tx.send(Msg::Submit(req, tx));
        rx
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn serves_concurrent_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 0));
        let server = Server::spawn(model, None, 4);
        let rxs: Vec<_> = (0..6)
            .map(|i| server.submit(vec![1, 5, 80 + i, 3], 5))
            .collect();
        for rx in rxs {
            let done = rx.recv_timeout(std::time::Duration::from_secs(30))
                .expect("completion");
            assert!(!done.tokens.is_empty());
        }
        assert_eq!(
            server.metrics.requests_completed.load(Ordering::Relaxed), 6);
        server.shutdown();
    }

    #[test]
    fn shutdown_without_requests() {
        let model = Arc::new(random_model(&ModelConfig::test_tiny(), 1));
        let server = Server::spawn(model, None, 2);
        server.shutdown();
    }
}
