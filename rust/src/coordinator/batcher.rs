//! Continuous batcher: admits queued requests into a bounded set of
//! active decode sessions and advances them with a FUSED step —
//! vLLM-style iteration-level scheduling where every active session
//! contributes its current token to one batched pass, and each expert
//! is dispatched at most once per layer per iteration across all
//! sessions (`decode::step_many`, DESIGN.md §3). Prompt admission uses
//! the batched single-shot prefill.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::config::EOS;
use crate::moe::model::MoeModel;
use crate::util::stats::argmax;

use super::decode::{step_many, DecodeOdp, DecodeSession};
use super::metrics::Metrics;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// greedy if None, else top-1 of logits/temperature sampling seed
    pub temperature: Option<(f32, u64)>,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub ttft_ns: u64,
    pub total_ns: u64,
}

struct Active {
    req: Request,
    session: DecodeSession,
    generated: Vec<u32>,
    started: Instant,
    first_token_ns: Option<u64>,
    rng_state: u64,
}

pub struct Batcher {
    model: Arc<MoeModel>,
    odp: Option<DecodeOdp>,
    pub max_batch: usize,
    queue: VecDeque<Request>,
    active: Vec<Active>,
    pub done: Vec<Completion>,
}

impl Batcher {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
               max_batch: usize) -> Batcher {
        Batcher {
            model,
            odp,
            max_batch,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub fn occupancy(&self) -> usize {
        self.active.len()
    }

    /// Admit + advance every active session by one token (one fused
    /// pass). Returns completions retired this step.
    pub fn step(&mut self, metrics: &Metrics) -> Vec<Completion> {
        // admission (continuous batching: fill free slots every step)
        while self.active.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            Metrics::inc(&metrics.requests_admitted, 1);
            let mut session =
                DecodeSession::new(self.model.clone(), self.odp.clone());
            let started = Instant::now();
            // single-shot batched prefill of the prompt minus its last
            // token; the final prompt token is the first fused decode
            // step below
            let (head, tail) = req.prompt.split_at(req.prompt.len() - 1);
            if !head.is_empty() {
                session.prefill(head);
            }
            let seed = req.temperature.map(|(_, s)| s).unwrap_or(1);
            self.active.push(Active {
                rng_state: seed,
                req: Request { prompt: tail.to_vec(), ..req },
                session,
                generated: Vec::new(),
                started,
                first_token_ns: None,
            });
        }
        if self.active.is_empty() {
            return Vec::new();
        }

        // one fused decode step across every active session
        let inputs: Vec<u32> = self
            .active
            .iter()
            .map(|a| *a.generated.last().unwrap_or(&a.req.prompt[0]))
            .collect();
        let t0 = Instant::now();
        let logits = {
            let mut sessions: Vec<&mut DecodeSession> =
                self.active.iter_mut().map(|a| &mut a.session).collect();
            step_many(&mut sessions, &inputs)
        };
        let step_ns = t0.elapsed().as_nanos() as u64;
        // the fused pass produced one token per session
        let per_token_ns = (step_ns / self.active.len() as u64).max(1);

        // sampling + retirement per session (descending index so
        // swap_remove never disturbs rows not yet processed)
        let mut retired = Vec::new();
        for i in (0..self.active.len()).rev() {
            let a = &mut self.active[i];
            metrics.record_tpot(per_token_ns);
            let next = match a.req.temperature {
                None => argmax(&logits[i]) as u32,
                Some((temp, _)) => {
                    // Gumbel-max sampling with a per-request LCG
                    a.rng_state = crate::util::rng::lcg_next(a.rng_state);
                    let mut rng = crate::util::rng::Rng::new(a.rng_state);
                    let scaled: Vec<f32> =
                        logits[i].iter().map(|l| l / temp).collect();
                    let noisy: Vec<f32> = scaled
                        .iter()
                        .map(|&l| l - (-(rng.f64().max(1e-12).ln())).ln() as f32)
                        .collect();
                    argmax(&noisy) as u32
                }
            };
            if a.first_token_ns.is_none() {
                let ns = a.started.elapsed().as_nanos() as u64;
                a.first_token_ns = Some(ns);
                metrics.record_ttft(ns);
            }
            a.generated.push(next);
            Metrics::inc(&metrics.tokens_generated, 1);
            let finished = a.generated.len() >= a.req.max_new_tokens
                || next == EOS
                || a.session.remaining() == 0;
            if finished {
                let a = self.active.swap_remove(i);
                Metrics::inc(&metrics.requests_completed, 1);
                Metrics::inc(&metrics.expert_calls,
                             a.session.stats.expert_calls as u64);
                Metrics::inc(&metrics.experts_pruned,
                             a.session.stats.pruned_total() as u64);
                retired.push(Completion {
                    id: a.req.id,
                    tokens: a.generated,
                    ttft_ns: a.first_token_ns.unwrap_or(0),
                    total_ns: a.started.elapsed().as_nanos() as u64,
                });
            }
        }
        self.done.extend(retired.clone());
        retired
    }

    /// Drive to completion; returns all completions.
    pub fn run_to_completion(&mut self, metrics: &Metrics) -> Vec<Completion> {
        let mut all = Vec::new();
        while self.pending() > 0 {
            all.extend(self.step(metrics));
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    fn engine() -> Arc<MoeModel> {
        Arc::new(random_model(&ModelConfig::test_tiny(), 0))
    }

    fn req(id: u64, n: usize) -> Request {
        Request {
            id,
            prompt: vec![1, 5, 80 + id as u32 % 8, 3],
            max_new_tokens: n,
            temperature: None,
        }
    }

    #[test]
    fn completes_all_requests() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        for i in 0..5 {
            b.submit(req(i, 4));
        }
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), 5);
        for c in &done {
            assert!(!c.tokens.is_empty() && c.tokens.len() <= 4);
            assert!(c.ttft_ns > 0);
        }
        assert_eq!(metrics.requests_completed.load(
            std::sync::atomic::Ordering::Relaxed), 5);
    }

    #[test]
    fn respects_max_batch() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        for i in 0..6 {
            b.submit(req(i, 8));
        }
        b.step(&metrics);
        assert_eq!(b.occupancy(), 2);
    }

    #[test]
    fn greedy_is_deterministic() {
        let m1 = Metrics::new();
        let mut b1 = Batcher::new(engine(), None, 1);
        b1.submit(req(0, 6));
        let d1 = b1.run_to_completion(&m1);
        let m2 = Metrics::new();
        let mut b2 = Batcher::new(engine(), None, 1);
        b2.submit(req(0, 6));
        let d2 = b2.run_to_completion(&m2);
        assert_eq!(d1[0].tokens, d2[0].tokens);
    }

    #[test]
    fn fused_batch_matches_solo_decode() {
        // batch width must not change any session's greedy tokens
        let solo: Vec<Vec<u32>> = (0..4u64)
            .map(|i| {
                let m = Metrics::new();
                let mut b = Batcher::new(engine(), None, 1);
                b.submit(req(i, 6));
                b.run_to_completion(&m)[0].tokens.clone()
            })
            .collect();
        let m = Metrics::new();
        let mut b = Batcher::new(engine(), None, 4);
        for i in 0..4 {
            b.submit(req(i, 6));
        }
        let done = b.run_to_completion(&m);
        for c in done {
            assert_eq!(c.tokens, solo[c.id as usize], "request {}", c.id);
        }
    }

    #[test]
    fn sampling_differs_from_greedy() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        b.submit(Request { temperature: Some((5.0, 7)), ..req(0, 8) });
        b.submit(req(1, 8));
        let done = b.run_to_completion(&metrics);
        let a = done.iter().find(|c| c.id == 0).unwrap();
        let g = done.iter().find(|c| c.id == 1).unwrap();
        assert_ne!(a.tokens, g.tokens);
    }
}
