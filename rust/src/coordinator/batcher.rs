//! Continuous batcher: admits queued requests into a bounded set of
//! active decode sessions and advances them with a FUSED step —
//! vLLM-style iteration-level scheduling where every active session
//! contributes its current token to one batched pass, and each expert
//! is dispatched at most once per layer per iteration across all
//! sessions (`decode::step_many`, DESIGN.md §3). Prompt admission uses
//! the batched single-shot prefill.
//!
//! The batcher consumes the unified `GenerateRequest` surface: every
//! emitted token streams to the request's `RequestTicket` channel the
//! step it is produced, sampling runs through the shared `Sampler`,
//! stop conditions follow `StopCondition`, admission honors
//! `Priority` (FIFO within a class), and a raised cancel flag retires
//! the session at the next step — freeing its batch slot for the
//! queue (DESIGN.md §3.1).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::moe::model::MoeModel;
use crate::obs::{self, Cat};
use crate::util::pool::WorkerPool;

use super::decode::{step_many_into, DecodeOdp, DecodeSession, StepScratch};
use super::memgov::MemoryGovernor;
use super::metrics::Metrics;
use super::request::{
    request_channel, Completion, FinishReason, GenerateRequest, Priority,
    RequestHandle, RequestTicket, StreamEvent,
};
use super::sampling::Sampler;

struct Active {
    req: GenerateRequest,
    ticket: RequestTicket,
    session: DecodeSession,
    sampler: Sampler,
    generated: Vec<u32>,
    started: Instant,
    first_token_ns: Option<u64>,
    /// absolute expiry (submission time + effective deadline)
    deadline: Option<Instant>,
}

pub struct Batcher {
    model: Arc<MoeModel>,
    odp: Option<DecodeOdp>,
    pub max_batch: usize,
    /// submission order; admission scans for the best priority class
    /// (the `Instant` is submission time, for deadline accounting)
    queue: Vec<(GenerateRequest, RequestTicket, Instant)>,
    active: Vec<Active>,
    next_id: u64,
    /// applied to requests that carry no deadline of their own
    default_deadline: Option<Duration>,
    /// fused-step scratch arena, reused every iteration so the
    /// steady-state decode loop never allocates (DESIGN.md §4)
    scratch: StepScratch,
    /// reused fused-step input-token buffer
    inputs: Vec<u32>,
    /// memory governor: byte-ceiling admission, shared-prefix reuse,
    /// and the pressure-degradation ladder (DESIGN.md §8). `None`
    /// leaves the historical ungoverned behavior untouched.
    governor: Option<Arc<MemoryGovernor>>,
}

impl Batcher {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>,
               max_batch: usize) -> Batcher {
        // start the worker pool now so its spawn cost is paid at
        // construction, not on the first request
        let _ = WorkerPool::global();
        Batcher {
            model,
            odp,
            max_batch,
            queue: Vec::new(),
            active: Vec::new(),
            next_id: 1,
            default_deadline: None,
            scratch: StepScratch::new(),
            inputs: Vec::new(),
            governor: None,
        }
    }

    /// Deadline applied to requests that don't carry their own
    /// (`None` = unlimited, the historical behavior).
    pub fn set_default_deadline(&mut self, deadline: Option<Duration>) {
        self.default_deadline = deadline;
    }

    /// Route admission and the fused step through a memory governor:
    /// requests that arrive without a grant reserve their worst-case
    /// KV footprint here (over-budget requests stay queued), admitted
    /// sessions attach/publish shared prompt prefixes, and each step
    /// walks the pressure ladder (DESIGN.md §8).
    pub fn set_governor(&mut self, gov: Arc<MemoryGovernor>) {
        self.governor = Some(gov);
    }

    /// Enqueue a request; the returned handle streams its events.
    pub fn submit(&mut self, req: GenerateRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (ticket, handle) = request_channel(id);
        self.queue.push((req, ticket, Instant::now()));
        handle
    }

    /// Enqueue with a caller-built ticket (the server constructs the
    /// handle on the client thread and ships the ticket here).
    pub fn submit_with_ticket(&mut self, req: GenerateRequest,
                              ticket: RequestTicket) {
        self.next_id = self.next_id.max(ticket.id + 1);
        self.queue.push((req, ticket, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub fn occupancy(&self) -> usize {
        self.active.len()
    }

    fn retire(a: Active, finish: FinishReason, metrics: &Metrics)
              -> Completion {
        Metrics::inc(&metrics.expert_calls,
                     a.session.stats.expert_calls as u64);
        Metrics::inc(&metrics.experts_pruned,
                     a.session.stats.pruned_total() as u64);
        Completion {
            id: a.ticket.id,
            tokens: a.generated,
            finish,
            ttft_ns: a.first_token_ns.unwrap_or(0),
            total_ns: a.started.elapsed().as_nanos() as u64,
        }
    }

    /// Expire requests whose wall-clock deadline passed (or whose
    /// ticket the watchdog already flagged): queued entries terminate
    /// without ever running; active sessions retire with whatever
    /// tokens they produced. Both streams end in a terminal
    /// `Done(DeadlineExceeded)`.
    fn reap_deadlines(&mut self, metrics: &Metrics) {
        let now = Instant::now();
        let default = self.default_deadline;
        self.queue.retain(|(req, ticket, enqueued)| {
            let expired = ticket.deadline_exceeded()
                || req
                    .deadline
                    .or(default)
                    .is_some_and(|d| now >= *enqueued + d);
            if !expired {
                return true;
            }
            if ticket.claim_terminal() {
                Metrics::inc(&metrics.deadline_exceeded, 1);
                obs::instant(Cat::Queue, "deadline_expired_queued",
                             obs::args1("req", ticket.id));
                obs::dump_now("deadline");
                ticket.send(StreamEvent::Done(Completion {
                    id: ticket.id,
                    tokens: Vec::new(),
                    finish: FinishReason::DeadlineExceeded,
                    ttft_ns: 0,
                    total_ns: now.duration_since(*enqueued).as_nanos() as u64,
                }));
            }
            false
        });
        for i in (0..self.active.len()).rev() {
            let a = &self.active[i];
            let expired = a.ticket.deadline_exceeded()
                || a.deadline.is_some_and(|d| now >= d);
            if !expired {
                continue;
            }
            let a = self.active.swap_remove(i);
            let ticket = a.ticket.clone();
            let done =
                Self::retire(a, FinishReason::DeadlineExceeded, metrics);
            if ticket.claim_terminal() {
                Metrics::inc(&metrics.deadline_exceeded, 1);
                obs::instant(Cat::Decode, "deadline_expired_active",
                             obs::args2("req", ticket.id,
                                        "tokens", done.tokens.len() as u64));
                obs::dump_now("deadline");
                ticket.send(StreamEvent::Done(done));
            }
        }
    }

    /// Reap raised cancel flags: queued requests are dropped, active
    /// sessions are retired (their batch slot frees for admission
    /// below). Streams get a terminal `Cancelled` event.
    fn reap_cancelled(&mut self, metrics: &Metrics) {
        self.queue.retain(|(_, ticket, _)| {
            if ticket.cancelled() {
                Metrics::inc(&metrics.requests_cancelled, 1);
                if ticket.claim_terminal() {
                    ticket.send(StreamEvent::Cancelled { id: ticket.id });
                }
                false
            } else {
                true
            }
        });
        for i in (0..self.active.len()).rev() {
            if self.active[i].ticket.cancelled() {
                let a = self.active.swap_remove(i);
                Metrics::inc(&metrics.requests_cancelled, 1);
                let ticket = a.ticket.clone();
                Self::retire(a, FinishReason::Cancelled, metrics);
                if ticket.claim_terminal() {
                    ticket.send(StreamEvent::Cancelled { id: ticket.id });
                }
            }
        }
    }

    /// Fill free batch slots from the queue, best priority class
    /// first, FIFO within a class. Degenerate requests never need a
    /// slot, so they complete (or are rejected) immediately even when
    /// the batch is saturated; their completions are returned so
    /// `step`/`run_to_completion` report them like any other.
    fn admit(&mut self, metrics: &Metrics) -> Vec<Completion> {
        let mut degenerate = Vec::new();
        // resolve every degenerate queue entry first, slot-free. Empty
        // prompt is invalid input (the engine path errors on it) and
        // reports Rejected without counting as completed;
        // max_new_tokens == 0 is a legitimate no-op, MaxTokens (as on
        // the engine path).
        let mut i = 0;
        while i < self.queue.len() {
            let req = &self.queue[i].0;
            if !req.prompt.is_empty() && req.max_new_tokens > 0 {
                i += 1;
                continue;
            }
            let (req, ticket, _) = self.queue.remove(i);
            Metrics::inc(&metrics.requests_admitted, 1);
            let finish = if req.prompt.is_empty() {
                Metrics::inc(&metrics.requests_rejected, 1);
                FinishReason::Rejected
            } else {
                Metrics::inc(&metrics.requests_completed, 1);
                FinishReason::MaxTokens
            };
            let done = Completion {
                id: ticket.id,
                tokens: Vec::new(),
                finish,
                ttft_ns: 0,
                total_ns: 0,
            };
            if ticket.claim_terminal() {
                ticket.send(StreamEvent::Done(done.clone()));
            }
            degenerate.push(done);
        }
        while self.active.len() < self.max_batch && !self.queue.is_empty() {
            let best = (0..self.queue.len())
                .min_by_key(|&i| self.queue[i].0.priority)
                .unwrap();
            // memory admission (before dequeuing, so a refusal leaves
            // the request queued rather than dropped): rung 4 defers
            // every Low-priority request outright; otherwise a request
            // without a grant reserves its worst-case footprint here.
            // Either refusal stops admission for this step — retrying
            // next step is the backpressure.
            let mut grant = None;
            if let Some(gov) = &self.governor {
                let req = &self.queue[best].0;
                if req.grant.is_none() {
                    if gov.rung() >= 4 && req.priority == Priority::Low {
                        Metrics::inc(&metrics.mem_sessions_deferred, 1);
                        break;
                    }
                    match gov.admit_session(&req.prompt,
                                            req.max_new_tokens) {
                        Ok(g) => grant = Some(Arc::new(g)),
                        Err(_needed) => break,
                    }
                }
            }
            let (mut req, ticket, enqueued) = self.queue.remove(best);
            if grant.is_some() {
                req.grant = grant;
            }
            Metrics::inc(&metrics.requests_admitted, 1);
            if obs::enabled() {
                // cross-thread stage: submission happened on the serve
                // thread, so reconstruct the start from the queue age
                let waited = enqueued.elapsed().as_nanos() as u64;
                obs::complete(Cat::Queue, "queue_wait",
                              obs::now_ns().saturating_sub(waited),
                              obs::args1("req", ticket.id));
            }
            let deadline = req
                .deadline
                .or(self.default_deadline)
                .map(|d| enqueued + d);
            let mut session =
                DecodeSession::new(self.model.clone(), self.odp.clone());
            let started = Instant::now();
            // single-shot batched prefill of the prompt minus its last
            // token; the final prompt token is the first fused decode
            // step below. Under a governor the session tracks per-token
            // importance (the Eq. 6 map steers page down-quantization)
            // and a granted shared prefix replaces its covered rows.
            let (head, tail) = req.prompt.split_at(req.prompt.len() - 1);
            if self.governor.is_some() {
                session.enable_importance();
            }
            if let Some(p) =
                req.grant.as_ref().and_then(|g| g.prefix.clone())
            {
                session.attach_prefix(p);
            }
            if session.pos < head.len() {
                let _sp = obs::span(Cat::Prefill, "prefill")
                    .arg("req", ticket.id)
                    .arg("tokens", (head.len() - session.pos) as u64);
                session.prefill(&head[session.pos..]);
            }
            if let Some(gov) = &self.governor {
                if req.grant.as_ref().map_or(true, |g| g.prefix.is_none())
                    && gov.wants_prefix(head)
                {
                    let (k, v, imp) = session.export_prefix(head.len());
                    gov.publish_prefix(head, k, v, imp);
                }
            }
            let sampler = Sampler::new(req.sampling.clone());
            self.active.push(Active {
                req: GenerateRequest { prompt: tail.to_vec(), ..req },
                ticket,
                session,
                sampler,
                generated: Vec::new(),
                started,
                first_token_ns: None,
                deadline,
            });
        }
        degenerate
    }

    /// Reap cancellations, admit from the queue, then advance every
    /// active session by one token (one fused pass). Each produced
    /// token streams to its request's channel immediately. Returns
    /// completions retired this step.
    pub fn step(&mut self, metrics: &Metrics) -> Vec<Completion> {
        self.reap_deadlines(metrics);
        self.reap_cancelled(metrics);
        // walk the pressure ladder before admission so rung changes
        // (including rung-4 Low-priority deferral) see this step's
        // reservations. Rung 3 down-quantizes cold low-importance KV
        // pages of every active session and returns the freed bytes to
        // the ledger, so pressure can actually recede.
        if let Some(gov) = &self.governor {
            let rung = gov.tick(&self.model);
            if rung >= 3 {
                for a in &mut self.active {
                    let before = a.session.quantized_pages();
                    let saved = a.session.kv_compress(
                        gov.cfg.downq_frac, gov.cfg.protect_recent_rows);
                    if saved > 0 {
                        let pages =
                            (a.session.quantized_pages() - before) as u64;
                        Metrics::inc(&metrics.kv_pages_downquantized,
                                     pages);
                        obs::instant(Cat::Mem, "kv_pages_downquantized",
                                     obs::args3("req", a.ticket.id,
                                                "pages", pages,
                                                "saved_bytes",
                                                saved as u64));
                        if let Some(g) = &a.req.grant {
                            g.reservation.shrink(saved as u64);
                        }
                    }
                }
            }
        }
        let mut retired = self.admit(metrics);
        Metrics::set_gauge(&metrics.queue_depth, self.queue.len() as u64);
        Metrics::set_gauge(&metrics.batch_occupancy, self.active.len() as u64);
        if self.active.is_empty() {
            return retired;
        }

        // one fused decode step across every active session
        self.inputs.clear();
        self.inputs.extend(
            self.active
                .iter()
                .map(|a| *a.generated.last().unwrap_or(&a.req.prompt[0])),
        );
        let t0 = Instant::now();
        let logits = {
            let mut sessions: Vec<&mut DecodeSession> =
                self.active.iter_mut().map(|a| &mut a.session).collect();
            step_many_into(&mut sessions, &self.inputs, &mut self.scratch)
        };
        let step_ns = t0.elapsed().as_nanos() as u64;
        if obs::enabled() {
            obs::complete(Cat::Decode, "decode_step",
                          obs::now_ns().saturating_sub(step_ns),
                          obs::args1("batch", self.active.len() as u64));
        }
        // the fused pass produced one token per session
        let per_token_ns = (step_ns / self.active.len() as u64).max(1);

        // sampling + streaming + retirement per session (descending
        // index so swap_remove never disturbs rows not yet processed)
        for i in (0..self.active.len()).rev() {
            let a = &mut self.active[i];
            metrics.record_tpot(per_token_ns);
            let next = a.sampler.next_token(logits.row(i));
            obs::instant(Cat::Sample, "token_sampled",
                         obs::args2("req", a.ticket.id,
                                    "token", next as u64));
            if a.first_token_ns.is_none() {
                let ns = a.started.elapsed().as_nanos() as u64;
                a.first_token_ns = Some(ns);
                metrics.record_ttft(ns);
                obs::instant(Cat::Serve, "first_token",
                             obs::args2("req", a.ticket.id,
                                        "ttft_us", ns / 1_000));
            }
            a.generated.push(next);
            Metrics::inc(&metrics.tokens_generated, 1);
            a.ticket.send(StreamEvent::Token(next));
            let finish = if a.req.stop.hits(next) {
                Some(FinishReason::Stop(next))
            } else if a.generated.len() >= a.req.max_new_tokens
                || a.session.remaining() == 0
            {
                Some(FinishReason::MaxTokens)
            } else {
                None
            };
            if let Some(finish) = finish {
                let a = self.active.swap_remove(i);
                Metrics::inc(&metrics.requests_completed, 1);
                let ticket = a.ticket.clone();
                let done = Self::retire(a, finish, metrics);
                if ticket.claim_terminal() {
                    ticket.send(StreamEvent::Done(done.clone()));
                }
                retired.push(done);
            }
        }
        Metrics::set_gauge(&metrics.batch_occupancy, self.active.len() as u64);
        retired
    }

    /// Drive to completion; returns all completions (cancelled
    /// requests terminate their streams but produce no completion).
    pub fn run_to_completion(&mut self, metrics: &Metrics) -> Vec<Completion> {
        let mut all = Vec::new();
        while self.pending() > 0 {
            all.extend(self.step(metrics));
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::request::{Priority, SamplingParams, StopCondition};
    use crate::moe::model::tests::random_model;

    fn engine() -> Arc<MoeModel> {
        Arc::new(random_model(&ModelConfig::test_tiny(), 0))
    }

    fn req(tag: u64, n: usize) -> GenerateRequest {
        GenerateRequest::greedy(vec![1, 5, 80 + tag as u32 % 8, 3], n)
    }

    #[test]
    fn completes_all_requests() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        let handles: Vec<RequestHandle> =
            (0..5).map(|i| b.submit(req(i, 4))).collect();
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), 5);
        for c in &done {
            assert!(!c.tokens.is_empty() && c.tokens.len() <= 4);
            assert!(c.ttft_ns > 0);
        }
        // every handle's stream delivered the same tokens as the
        // returned completion, in order
        for h in handles {
            let id = h.id;
            let c = h.wait().expect("completion");
            let want = done.iter().find(|d| d.id == id).unwrap();
            assert_eq!(c.tokens, want.tokens);
        }
        assert_eq!(metrics.requests_completed.load(
            std::sync::atomic::Ordering::Relaxed), 5);
    }

    #[test]
    fn respects_max_batch() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        // handles must outlive the run: dropping one cancels its request
        let _handles: Vec<RequestHandle> =
            (0..6).map(|i| b.submit(req(i, 8))).collect();
        b.step(&metrics);
        assert_eq!(b.occupancy(), 2);
        assert_eq!(metrics.batch_occupancy.load(
            std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(metrics.queue_depth.load(
            std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn greedy_is_deterministic() {
        let m1 = Metrics::new();
        let mut b1 = Batcher::new(engine(), None, 1);
        let _h1 = b1.submit(req(0, 6));
        let d1 = b1.run_to_completion(&m1);
        let m2 = Metrics::new();
        let mut b2 = Batcher::new(engine(), None, 1);
        let _h2 = b2.submit(req(0, 6));
        let d2 = b2.run_to_completion(&m2);
        assert_eq!(d1[0].tokens, d2[0].tokens);
    }

    #[test]
    fn fused_batch_matches_solo_decode() {
        // batch width must not change any session's greedy tokens
        let solo: Vec<Vec<u32>> = (0..4u64)
            .map(|i| {
                let m = Metrics::new();
                let mut b = Batcher::new(engine(), None, 1);
                let _h = b.submit(req(i, 6));
                b.run_to_completion(&m)[0].tokens.clone()
            })
            .collect();
        let m = Metrics::new();
        let mut b = Batcher::new(engine(), None, 4);
        let handles: Vec<RequestHandle> =
            (0..4).map(|i| b.submit(req(i, 6))).collect();
        let ids: Vec<u64> = handles.iter().map(|h| h.id).collect();
        let done = b.run_to_completion(&m);
        for c in done {
            let slot = ids.iter().position(|&id| id == c.id).unwrap();
            assert_eq!(c.tokens, solo[slot], "request {}", c.id);
        }
    }

    #[test]
    fn sampling_differs_from_greedy() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 2);
        let sampled = b.submit(
            req(0, 8).with_sampling(SamplingParams::temperature(5.0, 7)));
        let greedy = b.submit(req(0, 8));
        b.run_to_completion(&metrics);
        let a = sampled.wait().unwrap();
        let g = greedy.wait().unwrap();
        assert_ne!(a.tokens, g.tokens);
    }

    #[test]
    fn cancelled_queued_request_never_runs() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        let _first = b.submit(req(0, 4));
        let victim = b.submit(req(1, 4));
        victim.cancel();
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), 1);
        assert!(victim.wait().is_none());
        assert_eq!(metrics.requests_cancelled.load(
            std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn cancel_mid_decode_frees_slot_and_admits_queue() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        let victim = b.submit(req(0, 64).with_stop(StopCondition::MaxLen));
        let waiting = b.submit(req(1, 3));
        b.step(&metrics); // victim occupies the only slot
        assert_eq!(b.occupancy(), 1);
        victim.cancel();
        b.step(&metrics); // slot freed, waiting admitted + first token
        assert_eq!(b.occupancy(), 1);
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, waiting.id);
        assert!(victim.wait().is_none());
        assert!(waiting.wait().is_some());
    }

    #[test]
    fn empty_prompt_rejected_zero_max_new_is_noop() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        // saturate the only slot, then submit degenerates: they must
        // resolve immediately, not wait for the slot to free
        let _occupant = b.submit(req(0, 8));
        b.step(&metrics);
        let empty = b.submit(GenerateRequest::greedy(Vec::new(), 4));
        let noop = b.submit(GenerateRequest::greedy(vec![1, 5], 0));
        let step_done = b.step(&metrics);
        assert!(step_done.iter().any(|c| c.id == empty.id),
                "rejected while the batch is full");
        assert!(step_done.iter().any(|c| c.id == noop.id));
        assert_eq!(empty.wait().unwrap().finish, FinishReason::Rejected);
        assert_eq!(noop.wait().unwrap().finish, FinishReason::MaxTokens);
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.requests_rejected.load(Ordering::Relaxed), 1);
        b.run_to_completion(&metrics);
    }

    #[test]
    fn priority_admission_order() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        // occupy the slot so later submissions queue up
        let _first = b.submit(req(0, 2));
        b.step(&metrics);
        let low = b.submit(req(1, 2).with_priority(Priority::Low));
        let high = b.submit(req(2, 2).with_priority(Priority::High));
        let done = b.run_to_completion(&metrics);
        let pos = |id| done.iter().position(|c| c.id == id).unwrap();
        assert!(pos(high.id) < pos(low.id),
                "high priority admitted before low");
    }

    #[test]
    fn expired_deadline_retires_with_partial_tokens() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        // zero budget: expires before the first step admits it
        let queued =
            b.submit(req(0, 8).with_deadline(Duration::from_millis(0)));
        b.step(&metrics);
        let done = queued.wait().expect("terminal Done event");
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert!(done.tokens.is_empty());
        // an active session expires mid-decode and keeps its partials
        let long = b.submit(
            req(1, 64)
                .with_stop(StopCondition::MaxLen)
                .with_deadline(Duration::from_millis(30)),
        );
        b.step(&metrics); // admit + first token
        assert_eq!(b.occupancy(), 1);
        std::thread::sleep(Duration::from_millis(40));
        b.step(&metrics); // reap: slot freed
        assert_eq!(b.occupancy(), 0);
        let done = long.wait().expect("terminal Done event");
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        assert!(!done.tokens.is_empty(), "partial tokens delivered");
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.deadline_exceeded.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn default_deadline_applies_when_request_has_none() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        b.set_default_deadline(Some(Duration::from_millis(0)));
        let h = b.submit(req(0, 4));
        b.step(&metrics);
        let done = h.wait().expect("terminal Done event");
        assert_eq!(done.finish, FinishReason::DeadlineExceeded);
        // a per-request deadline overrides the default
        b.set_default_deadline(Some(Duration::from_millis(0)));
        let h = b.submit(req(1, 2).with_deadline(Duration::from_secs(60)));
        let done = b.run_to_completion(&metrics);
        assert_eq!(done.len(), 1);
        assert!(h.wait().unwrap().finish != FinishReason::DeadlineExceeded);
    }

    #[test]
    fn stop_token_set_honored() {
        let metrics = Metrics::new();
        let mut b = Batcher::new(engine(), None, 1);
        // run greedy once to learn the second emitted token...
        let probe = b.submit(req(0, 4).with_stop(StopCondition::MaxLen));
        b.run_to_completion(&metrics);
        let probe_tokens = probe.wait().unwrap().tokens;
        assert_eq!(probe_tokens.len(), 4);
        // ...then make that token a stop token: generation ends at
        // its first occurrence (greedy replay is deterministic)
        let stop_at = probe_tokens[1];
        let first = probe_tokens.iter().position(|&t| t == stop_at).unwrap();
        let h = b.submit(req(0, 4)
            .with_stop(StopCondition::StopTokens(vec![stop_at])));
        let done = b.run_to_completion(&metrics);
        assert_eq!(done[0].tokens, probe_tokens[..=first].to_vec());
        assert_eq!(done[0].finish, FinishReason::Stop(stop_at));
        drop(h);
    }
}
