//! Incremental KV-cache decoding over the (quantized) native engine,
//! as a thin driver over the shared execution core `moe::exec`
//! (DESIGN.md §2): the attention kernel, routing/ODP decisions, and
//! expert dispatch are the same code the scoring forward runs, so the
//! two paths can no longer drift.
//!
//! **Zero-allocation steady state (DESIGN.md §4):** every buffer the
//! decode loop touches lives in a scratch arena owned by its driver —
//! [`SessionScratch`] per session (projection/attention/router/
//! dispatch buffers, reserved up front so the growing KV window never
//! reallocates) and [`StepScratch`] per fused-batch driver. After the
//! first step at a given batch shape, `step_many_into` performs no
//! heap allocation in the attention/dispatch/GEMM paths
//! (`tests/zero_alloc.rs` asserts this with a counting allocator).
//!
//! ODP at decode time (paper Sec. 3.3 applied autoregressively): the
//! w1/w0 ratio rule is exact; Eq.-6 token protection needs attention
//! *received from future queries*, which doesn't exist yet for the
//! token being decoded, so protection falls back to the L1-norm factor
//! of Eq. 6 alone. The threshold is the calibrated (1-protect_ratio)
//! percentile of training-distribution L1 norms (see
//! `DecodeOdp::calibrate`); divergence from the paper documented in
//! DESIGN.md §2.
//!
//! `prefill` runs the whole prompt as ONE batched full-sequence pass
//! that fills the KV cache in a single shot (not S sequential steps);
//! `step_many` advances several sessions at once, dispatching each
//! expert at most once per layer per iteration (the fused batcher
//! step, DESIGN.md §3), with per-session attention fanned out across
//! the `WorkerPool` (disjoint KV caches and output rows, so pooled
//! results are bit-exact with serial).

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::moe::exec::{attention, dispatch, router};
use crate::moe::exec::attention::AttnScratch;
use crate::moe::exec::dispatch::{DispatchMode, DispatchScratch, ExpertsRef};
use crate::moe::exec::kvcache::{
    KvPage, KvView, SharedPrefix, DEFAULT_PAGE_ROWS,
};
use crate::moe::model::{Expert, MoeModel, RunStats, RMS_EPS};
use crate::offload;
use crate::quant::QmScratch;
use crate::tensor::{
    add_inplace, matmul_reset_into, rmsnorm_into, vecmat_into, Mat,
};
use crate::util::pool::{SendPtr, WorkerPool};

pub use crate::moe::exec::router::DecodeOdp;

/// Per-session attention fan-out gate: total score+mix work
/// (Σ klen · d) below this stays serial in `step_many_into`.
const SESSION_ATTN_MIN_WORK: usize = 65_536;

/// Per-layer MoE routing introspection for the flight recorder and
/// the live `/debug/experts` heat table: mean routing entropy of the
/// gate distribution, distinct experts activated, selections dropped
/// below `top_k` (ODP pruning and degraded dispatch), and the mean
/// bit-width of the experts actually dispatched. Callers gate on
/// [`obs::enabled`] so the disabled decode path never reaches here.
fn trace_layer_routing(li: usize, probs: &Mat,
                       topk: &[Vec<(usize, f32)>], top_k: usize,
                       bits: &dyn Fn(usize) -> Option<f64>) {
    use crate::obs::{self, Cat};
    let mut entropy = 0.0f64;
    for t in 0..topk.len() {
        for &p in probs.row(t) {
            if p > 0.0 {
                entropy -= p as f64 * (p as f64).ln();
            }
        }
    }
    let mut seen = vec![false; probs.cols];
    let mut pruned = 0u64;
    for sel in topk {
        pruned += top_k.saturating_sub(sel.len()) as u64;
        for &(e, _) in sel.iter() {
            if let Some(s) = seen.get_mut(e) {
                *s = true;
            }
        }
        obs::heat::record(li, sel);
    }
    let active = seen.iter().filter(|&&s| s).count() as u64;
    let (mut bits_sum, mut bits_n) = (0.0f64, 0u32);
    for (e, &s) in seen.iter().enumerate() {
        if s {
            if let Some(b) = bits(e) {
                bits_sum += b;
                bits_n += 1;
            }
        }
    }
    let mean_entropy = entropy / topk.len().max(1) as f64;
    obs::instant(Cat::Route, "layer_routing",
                 obs::args3("layer", li as u64,
                            "entropy_u", obs::micro(mean_entropy),
                            "active_experts", active));
    obs::instant(Cat::Route, "odp_dispatch",
                 obs::args3("layer", li as u64,
                            "pruned", pruned,
                            "bits_u", obs::micro(
                                if bits_n > 0 {
                                    bits_sum / bits_n as f64
                                } else {
                                    0.0
                                })));
}

/// Mean stored bits per weight of one expert (PMQ mixed precision
/// makes this differ across experts).
fn expert_bits(e: &Expert) -> f64 {
    e.storage_bytes() as f64 * 8.0 / e.param_count().max(1) as f64
}

/// One layer's private KV storage: block-granular pages grown lazily
/// as the sequence extends (DESIGN.md §8). Rows before the session's
/// shared-prefix boundary live in the read-only [`SharedPrefix`], not
/// here; row `pos` of the session maps to local row
/// `pos - prefix_rows` in these pages.
struct LayerKv {
    pages: Vec<KvPage>,
}

impl LayerKv {
    /// Write a K/V row at page-local position `local`, allocating the
    /// covering page on first touch. With `DEFAULT_PAGE_ROWS` sized to
    /// the steady-state decode window, growth never lands inside the
    /// zero-allocation measurement window (`tests/zero_alloc.rs`).
    fn write_row(&mut self, local: usize, page_rows: usize, d: usize,
                 krow: &[f32], vrow: &[f32]) {
        let pi = local / page_rows;
        while self.pages.len() <= pi {
            self.pages.push(KvPage::new_f32(page_rows, d));
        }
        self.pages[pi].write_row(local % page_rows, d, krow, vrow);
    }
}

/// Per-session scratch arena: every intermediate of the layer stack,
/// reused across steps. Buffers are reserved for the session's
/// steady-state decode shapes at construction, so their pointers stay
/// stable from the first step on.
pub struct SessionScratch {
    pub attn: AttnScratch,
    pub attn_out: Mat,
    pub x: Mat,
    pub h: Mat,
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub proj: Mat,
    pub probs: Mat,
    pub moe_y: Mat,
    pub xf: Mat,
    pub topk: Vec<Vec<(usize, f32)>>,
    pub dispatch: DispatchScratch,
    pub qs: QmScratch,
    /// per-layer routed expert set + pinned slots (cache-resolved
    /// models only; resident decode never touches these)
    needed: Vec<usize>,
    pins: Vec<Option<Arc<Expert>>>,
}

impl SessionScratch {
    fn new(cfg: &ModelConfig) -> SessionScratch {
        let mut attn = AttnScratch::new();
        attn.reserve(cfg.head_dim(), cfg.max_seq);
        SessionScratch {
            attn,
            attn_out: Mat::zeros(0, 0),
            x: Mat::zeros(0, 0),
            h: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            probs: Mat::zeros(0, 0),
            moe_y: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            topk: Vec::new(),
            dispatch: DispatchScratch::new(),
            qs: QmScratch::new(),
            needed: Vec::new(),
            pins: Vec::new(),
        }
    }
}

pub struct DecodeSession {
    pub model: Arc<MoeModel>,
    kv: Vec<LayerKv>,
    pub pos: usize,
    pub odp: Option<DecodeOdp>,
    /// Same accounting struct as the scoring path (`RunStats`), so
    /// pruning metrics mean the same thing on both paths.
    pub stats: RunStats,
    pub scratch: SessionScratch,
    /// Read-only shared prompt prefix (CoW: this session never writes
    /// rows < `prefix.rows`; its own KV starts there).
    prefix: Option<Arc<SharedPrefix>>,
    /// Per-absolute-position token importance (Eq. 6 over the prefill
    /// window; L1-of-embedding fallback for decoded tokens). Only
    /// tracked when `enable_importance` was called.
    importance: Vec<f32>,
    collect_importance: bool,
    page_rows: usize,
}

impl DecodeSession {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>) -> DecodeSession {
        let kv = (0..model.cfg.n_layers)
            .map(|_| LayerKv { pages: Vec::new() })
            .collect();
        let stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
        let scratch = SessionScratch::new(&model.cfg);
        DecodeSession {
            model,
            kv,
            pos: 0,
            odp,
            stats,
            scratch,
            prefix: None,
            importance: Vec::new(),
            collect_importance: false,
            page_rows: DEFAULT_PAGE_ROWS,
        }
    }

    pub fn remaining(&self) -> usize {
        self.model.cfg.max_seq - self.pos
    }

    fn prefix_rows(&self) -> usize {
        self.prefix.as_ref().map(|p| p.rows).unwrap_or(0)
    }

    /// Track per-token importance (memory-governed sessions: feeds
    /// rung-3 page selection and prefix publication). Reserves the
    /// full window up front so decode-time pushes never reallocate.
    pub fn enable_importance(&mut self) {
        self.collect_importance = true;
        self.importance.reserve(self.model.cfg.max_seq);
    }

    pub fn importance(&self) -> &[f32] {
        &self.importance
    }

    /// Attach a shared prompt prefix to an empty session: attention
    /// reads rows `< prefix.rows` from the shared (read-only) mats;
    /// this session's own pages start at that boundary.
    pub fn attach_prefix(&mut self, p: Arc<SharedPrefix>) {
        assert_eq!(self.pos, 0, "prefix must attach before any append");
        assert!(self.prefix.is_none(), "prefix already attached");
        assert_eq!(p.k.len(), self.model.cfg.n_layers);
        assert!(p.rows <= self.model.cfg.max_seq);
        self.pos = p.rows;
        if self.collect_importance {
            self.importance.clear();
            self.importance.extend_from_slice(&p.importance);
            self.importance.resize(p.rows, 0.0);
        }
        self.prefix = Some(p);
    }

    /// Copy the first `rows` KV rows (per layer) out of this session's
    /// f32 pages, plus their importance — the raw material for
    /// `MemoryGovernor::publish_prefix`. The session must own those
    /// rows privately (no prefix attached) and not have down-quantized
    /// them yet.
    pub fn export_prefix(&self, rows: usize)
                         -> (Vec<Mat>, Vec<Mat>, Vec<f32>) {
        assert!(self.prefix.is_none(), "already sharing a prefix");
        assert!(rows <= self.pos, "cannot export unwritten rows");
        let d = self.model.cfg.d_model;
        let mut ks = Vec::with_capacity(self.kv.len());
        let mut vs = Vec::with_capacity(self.kv.len());
        let mut dq = vec![0.0f32; d];
        for layer in &self.kv {
            let mut k = Mat::zeros(rows, d);
            let mut v = Mat::zeros(rows, d);
            let view = KvView {
                prefix: None,
                prefix_rows: 0,
                pages: &layer.pages,
                page_rows: self.page_rows,
                d,
                layer: 0,
            };
            for r in 0..rows {
                k.row_mut(r).copy_from_slice(view.k_slice(r, 0, d, &mut dq));
                v.row_mut(r).copy_from_slice(view.v_slice(r, 0, d, &mut dq));
            }
            ks.push(k);
            vs.push(v);
        }
        let mut imp = self.importance.clone();
        imp.resize(rows, 0.0);
        imp.truncate(rows);
        (ks, vs, imp)
    }

    /// Rung-3 pressure action: down-quantize the `frac` least-important
    /// fully-written private pages to f16 (all layers), never touching
    /// the last `protect_recent` rows behind the decode head. Returns
    /// bytes freed (callers shrink their `MemReservation` by it).
    pub fn kv_compress(&mut self, frac: f64, protect_recent: usize) -> usize {
        let prefix_rows = self.prefix_rows();
        let local_rows = self.pos.saturating_sub(prefix_rows);
        let cutoff = local_rows
            .saturating_sub(protect_recent) / self.page_rows; // pages < cutoff are cold
        let mut eligible: Vec<(f32, usize)> = (0..cutoff)
            .filter(|&p| !self.kv[0].pages[p].is_quantized())
            .map(|p| {
                let a = prefix_rows + p * self.page_rows;
                let b = a + self.page_rows;
                let sum: f32 = (a..b)
                    .map(|r| self.importance.get(r).copied().unwrap_or(0.0))
                    .sum();
                (sum / self.page_rows as f32, p)
            })
            .collect();
        if eligible.is_empty() {
            return 0;
        }
        eligible.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
        let take = ((frac * eligible.len() as f64).ceil() as usize)
            .min(eligible.len());
        let mut saved = 0usize;
        for &(_, p) in &eligible[..take] {
            for layer in &mut self.kv {
                saved += layer.pages[p].quantize();
            }
        }
        saved
    }

    /// Pages currently down-quantized (layer 0; all layers move
    /// together).
    pub fn quantized_pages(&self) -> usize {
        self.kv[0].pages.iter().filter(|p| p.is_quantized()).count()
    }

    /// Rewind to an empty sequence. F32 pages are kept allocated
    /// (their rows are rewritten before they are ever read again);
    /// down-quantized pages are no longer writable and are dropped.
    pub fn reset(&mut self) {
        self.pos = 0;
        self.prefix = None;
        self.importance.clear();
        for layer in &mut self.kv {
            if layer.pages.iter().any(|p| p.is_quantized()) {
                layer.pages.clear();
            }
        }
        self.stats = RunStats::new(self.model.cfg.n_layers,
                                   self.model.cfg.n_experts);
    }

    /// Feed the whole prompt in ONE batched full-sequence pass (fills
    /// the KV cache in a single shot); returns last-position logits.
    pub fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        self.prefill_into(tokens, &mut logits);
        logits
    }

    /// `prefill` into a caller-owned logits buffer (left empty for an
    /// empty prompt).
    pub fn prefill_into(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        logits.clear();
        if !tokens.is_empty() {
            self.append(tokens, logits);
        }
    }

    /// Append one token, return next-token logits.
    pub fn step(&mut self, token: u32) -> Vec<f32> {
        let mut logits = Vec::new();
        self.step_into(token, &mut logits);
        logits
    }

    /// `step` into a caller-owned logits buffer — with a warmed buffer
    /// this is the zero-allocation single-session decode path.
    pub fn step_into(&mut self, token: u32, logits: &mut Vec<f32>) {
        self.append(&[token], logits);
    }

    /// Append `tokens` at positions `pos..pos+T` in one batched pass
    /// and write the logits of the last appended position.
    fn append(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        let model = self.model.clone();
        let cfg = &model.cfg;
        let d = cfg.d_model;
        let t_new = tokens.len();
        let pos0 = self.pos;
        assert!(t_new >= 1);
        assert!(pos0 + t_new <= cfg.max_seq, "KV cache exhausted");
        self.pos += t_new;
        self.stats.tokens_seen += t_new;
        // multi-token appends (prefill) pool attention across heads;
        // single-token decode stays serial (it is pooled across
        // sessions by `step_many_into` instead)
        let attn_pool =
            if t_new > 1 { Some(WorkerPool::global()) } else { None };
        let prefix_rows = self.prefix_rows();
        let page_rows = self.page_rows;
        // Eq.-6 maps need the full square prefill grid: only a
        // prefix-free whole-prompt prefill qualifies; decoded tokens
        // fall back to the L1-of-embedding factor alone (module docs).
        let want_map =
            self.collect_importance && pos0 == 0 && t_new > 1;

        let (kv, sc, stats, odp, prefix, importance) = (
            &mut self.kv,
            &mut self.scratch,
            &mut self.stats,
            self.odp.as_ref(),
            self.prefix.as_deref(),
            &mut self.importance,
        );

        // token + positional embedding at this session's positions
        sc.x.resize_to(t_new, d);
        for (t, &tok) in tokens.iter().enumerate() {
            model.embed_row(tok, pos0 + t, sc.x.row_mut(t));
        }
        if self.collect_importance {
            importance.resize(pos0, 0.0);
            for t in 0..t_new {
                let l1: f32 =
                    sc.x.row(t).iter().map(|v| v.abs()).sum();
                importance.push(l1 / d as f32);
            }
        }
        let mut eq6_acc = if want_map { vec![0.0f32; t_new] } else { Vec::new() };

        for (li, layer) in model.layers.iter().enumerate() {
            // attention with KV cache (shared kernel, append shape)
            rmsnorm_into(&sc.x, &layer.attn_norm, RMS_EPS, &mut sc.h);
            layer.wq.matmul_into(&sc.h, &mut sc.q, &mut sc.qs);
            layer.wk.matmul_into(&sc.h, &mut sc.k, &mut sc.qs);
            layer.wv.matmul_into(&sc.h, &mut sc.v, &mut sc.qs);
            let cache = &mut kv[li];
            for i in 0..t_new {
                cache.write_row(pos0 + i - prefix_rows, page_rows, d,
                                sc.k.row(i), sc.v.row(i));
            }
            let view = KvView {
                prefix,
                prefix_rows,
                pages: &cache.pages,
                page_rows,
                d,
                layer: li,
            };
            let a_mean = attention::causal_attention_paged_into(
                &sc.q, &view, pos0 + t_new, cfg.n_heads, want_map,
                attn_pool, &mut sc.attn, &mut sc.attn_out,
            );
            if let Some(am) = a_mean {
                // layer-averaged Eq.-6 importance of the prefill window
                let imp = attention::eq6_importance(&sc.x, &am);
                for (a, v) in eq6_acc.iter_mut().zip(&imp) {
                    *a += v / model.layers.len() as f32;
                }
            }
            layer.wo.matmul_into(&sc.attn_out, &mut sc.proj, &mut sc.qs);
            add_inplace(&mut sc.x, &sc.proj);

            // MoE with decode-time ODP (shared router + dispatch)
            rmsnorm_into(&sc.x, &layer.ffn_norm, RMS_EPS, &mut sc.h);
            router::gate_probs_into(&sc.h, &layer.gate, &mut sc.probs);
            while sc.topk.len() < t_new {
                sc.topk.push(Vec::new());
            }
            for t in 0..t_new {
                router::decode_select_into(
                    sc.probs.row(t),
                    sc.h.row(t),
                    cfg.top_k,
                    li,
                    odp,
                    stats,
                    &mut sc.topk[t],
                );
            }
            if model.resolver.is_resident() {
                dispatch::dispatch_experts_into(
                    &sc.h,
                    &sc.topk[..t_new],
                    ExpertsRef::resident(&layer.experts),
                    None,
                    DispatchMode::Auto,
                    &mut sc.dispatch,
                );
            } else {
                // pin the routed set for this dispatch; the predictor
                // prefetches layer li+1 while these FFNs execute
                offload::unique_experts(&sc.topk[..t_new], &mut sc.needed);
                let unavailable =
                    model.resolver.pin_layer(li, &sc.needed, &mut sc.pins);
                model.resolver.note_routing(li, &sc.needed);
                if unavailable > 0
                    && offload::degrade_topk(&mut sc.topk[..t_new], &sc.pins) > 0
                {
                    model.resolver.note_degraded();
                }
                dispatch::dispatch_experts_into(
                    &sc.h,
                    &sc.topk[..t_new],
                    ExpertsRef::pinned(&sc.pins),
                    None,
                    DispatchMode::Auto,
                    &mut sc.dispatch,
                );
                model.resolver.unpin_layer(li, &sc.needed);
            }
            if crate::obs::enabled() {
                let resident = model.resolver.is_resident();
                let (experts, pins) = (&layer.experts, &sc.pins);
                trace_layer_routing(li, &sc.probs, &sc.topk[..t_new],
                                    cfg.top_k, &|e| if resident {
                                        experts.get(e).map(expert_bits)
                                    } else {
                                        pins.get(e)
                                            .and_then(|p| p.as_deref())
                                            .map(expert_bits)
                                    });
            }
            dispatch::scatter_into(&sc.dispatch, t_new, d, &mut sc.moe_y);
            add_inplace(&mut sc.x, &sc.moe_y);
        }

        if want_map {
            // replace the L1-only placeholders with the layer-averaged
            // Eq.-6 importance for the prefill window (the map already
            // folds in the per-layer L1 factor)
            importance[..t_new]
                .iter_mut()
                .zip(&eq6_acc)
                .for_each(|(slot, w)| *slot = *w);
        }

        rmsnorm_into(&sc.x, &model.final_norm, RMS_EPS, &mut sc.xf);
        // only the last position's logits are the decode output
        vecmat_into(sc.xf.row(t_new - 1), &model.lm_head, logits);
    }
}

/// Per-driver scratch for the fused multi-session step: batched
/// projections, routing selections, dispatch buffers, and the logits
/// matrix `step_many_into` returns a view of. `dispatch_mode` defaults
/// to `Auto`; `benches/hotpath.rs` overrides it to compare the pool
/// against the legacy spawn-per-step baseline.
pub struct StepScratch {
    pub dispatch_mode: DispatchMode,
    pub x: Mat,
    pub h: Mat,
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub attn_out: Mat,
    pub proj: Mat,
    pub probs: Mat,
    pub moe_y: Mat,
    pub xf: Mat,
    pub logits: Mat,
    pub topk: Vec<Vec<(usize, f32)>>,
    pub dispatch: DispatchScratch,
    pub qs: QmScratch,
    positions: Vec<usize>,
    /// cache-resolved models only (see `SessionScratch`)
    needed: Vec<usize>,
    pins: Vec<Option<Arc<Expert>>>,
}

impl Default for StepScratch {
    fn default() -> StepScratch {
        StepScratch {
            dispatch_mode: DispatchMode::Auto,
            x: Mat::zeros(0, 0),
            h: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            attn_out: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            probs: Mat::zeros(0, 0),
            moe_y: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            logits: Mat::zeros(0, 0),
            topk: Vec::new(),
            dispatch: DispatchScratch::new(),
            qs: QmScratch::new(),
            positions: Vec::new(),
            needed: Vec::new(),
            pins: Vec::new(),
        }
    }
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch::default()
    }
}

/// One session's decode attention inside the fused step: append this
/// step's K/V rows to the session's cache, run single-query attention
/// with the session-owned scratch, and write the result into row `i`
/// of the shared attention output (disjoint across sessions).
fn session_attention(
    sess: &mut DecodeSession,
    li: usize,
    i: usize,
    t: usize,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    n_heads: usize,
    attn_base: SendPtr<f32>,
    d: usize,
) {
    let prefix_rows = sess.prefix_rows();
    let page_rows = sess.page_rows;
    sess.kv[li].write_row(t - prefix_rows, page_rows, d, k.row(i), v.row(i));
    let (cache, sc, prefix) =
        (&sess.kv[li], &mut sess.scratch, sess.prefix.as_deref());
    sc.q.resize_to(1, d);
    sc.q.row_mut(0).copy_from_slice(q.row(i));
    let view = KvView {
        prefix,
        prefix_rows,
        pages: &cache.pages,
        page_rows,
        d,
        layer: li,
    };
    attention::causal_attention_paged_into(
        &sc.q, &view, t + 1, n_heads, false, None, &mut sc.attn,
        &mut sc.attn_out,
    );
    // Safety: session i owns row i of the shared output exclusively.
    let orow =
        unsafe { std::slice::from_raw_parts_mut(attn_base.0.add(i * d), d) };
    orow.copy_from_slice(sc.attn_out.row(0));
}

/// Advance several sessions (sharing one model) by one token each in a
/// fused pass: attention runs per session over its own KV cache
/// (pool-parallel across sessions), while layer projections, routing,
/// and expert dispatch run once over the whole batch — each expert
/// executes at most once per layer per iteration, regardless of how
/// many sessions selected it. Returns a view of the per-session
/// next-token logits ([B, vocab], row i = session i), identical to
/// calling `step` on each session individually.
pub fn step_many_into<'a>(
    sessions: &mut [&mut DecodeSession],
    tokens: &[u32],
    sc: &'a mut StepScratch,
) -> &'a Mat {
    let b = sessions.len();
    assert_eq!(b, tokens.len(), "one token per session");
    assert!(b > 0, "empty fused step");
    let model = sessions[0].model.clone();
    for s in sessions.iter() {
        assert!(Arc::ptr_eq(&s.model, &model), "fused step needs a shared model");
        assert!(s.pos < model.cfg.max_seq, "KV cache exhausted");
    }
    let cfg = &model.cfg;
    let d = cfg.d_model;

    // each session's token embeds at that session's own position
    sc.positions.clear();
    sc.x.resize_to(b, d);
    for (i, s) in sessions.iter_mut().enumerate() {
        sc.positions.push(s.pos);
        model.embed_row(tokens[i], s.pos, sc.x.row_mut(i));
        if s.collect_importance {
            // decode-time fallback: L1 factor of Eq. 6 only (docs)
            let l1: f32 = sc.x.row(i).iter().map(|v| v.abs()).sum();
            s.importance.resize(s.pos, 0.0);
            s.importance.push(l1 / d as f32);
        }
        s.pos += 1;
        s.stats.tokens_seen += 1;
    }

    let pool = WorkerPool::global();
    let attn_work: usize = sc.positions.iter().map(|p| (p + 1) * d).sum();

    for (li, layer) in model.layers.iter().enumerate() {
        // batched projections; per-session attention over its own cache
        rmsnorm_into(&sc.x, &layer.attn_norm, RMS_EPS, &mut sc.h);
        layer.wq.matmul_into(&sc.h, &mut sc.q, &mut sc.qs);
        layer.wk.matmul_into(&sc.h, &mut sc.k, &mut sc.qs);
        layer.wv.matmul_into(&sc.h, &mut sc.v, &mut sc.qs);
        sc.attn_out.resize_to(b, d);
        {
            let attn_base = SendPtr(sc.attn_out.data.as_mut_ptr());
            let (q, k, v) = (&sc.q, &sc.k, &sc.v);
            let positions = &sc.positions;
            let nh = cfg.n_heads;
            if b >= 2
                && pool.width() > 1
                && attn_work >= SESSION_ATTN_MIN_WORK
                && !WorkerPool::on_worker()
            {
                let sptr = SendPtr(sessions.as_mut_ptr());
                pool.for_each(b, move |i| {
                    // Safety: indices are unique per region, so each
                    // task holds the only &mut to its session.
                    let sess = unsafe { &mut **sptr.0.add(i) };
                    session_attention(sess, li, i, positions[i], q, k, v, nh,
                                      attn_base, d);
                });
            } else {
                for i in 0..b {
                    session_attention(&mut *sessions[i], li, i, positions[i],
                                      q, k, v, nh, attn_base, d);
                }
            }
        }
        layer.wo.matmul_into(&sc.attn_out, &mut sc.proj, &mut sc.qs);
        add_inplace(&mut sc.x, &sc.proj);

        // fused MoE: route the whole batch, dispatch each expert once
        rmsnorm_into(&sc.x, &layer.ffn_norm, RMS_EPS, &mut sc.h);
        router::gate_probs_into(&sc.h, &layer.gate, &mut sc.probs);
        while sc.topk.len() < b {
            sc.topk.push(Vec::new());
        }
        for (i, sess) in sessions.iter_mut().enumerate() {
            router::decode_select_into(
                sc.probs.row(i),
                sc.h.row(i),
                cfg.top_k,
                li,
                sess.odp.as_ref(),
                &mut sess.stats,
                &mut sc.topk[i],
            );
        }
        if model.resolver.is_resident() {
            dispatch::dispatch_experts_into(
                &sc.h,
                &sc.topk[..b],
                ExpertsRef::resident(&layer.experts),
                None,
                sc.dispatch_mode,
                &mut sc.dispatch,
            );
        } else {
            offload::unique_experts(&sc.topk[..b], &mut sc.needed);
            let unavailable =
                model.resolver.pin_layer(li, &sc.needed, &mut sc.pins);
            model.resolver.note_routing(li, &sc.needed);
            if unavailable > 0
                && offload::degrade_topk(&mut sc.topk[..b], &sc.pins) > 0
            {
                model.resolver.note_degraded();
            }
            dispatch::dispatch_experts_into(
                &sc.h,
                &sc.topk[..b],
                ExpertsRef::pinned(&sc.pins),
                None,
                sc.dispatch_mode,
                &mut sc.dispatch,
            );
            model.resolver.unpin_layer(li, &sc.needed);
        }
        if crate::obs::enabled() {
            let resident = model.resolver.is_resident();
            let (experts, pins) = (&layer.experts, &sc.pins);
            trace_layer_routing(li, &sc.probs, &sc.topk[..b],
                                cfg.top_k, &|e| if resident {
                                    experts.get(e).map(expert_bits)
                                } else {
                                    pins.get(e)
                                        .and_then(|p| p.as_deref())
                                        .map(expert_bits)
                                });
        }
        dispatch::scatter_into(&sc.dispatch, b, d, &mut sc.moe_y);
        add_inplace(&mut sc.x, &sc.moe_y);
    }

    rmsnorm_into(&sc.x, &model.final_norm, RMS_EPS, &mut sc.xf);
    matmul_reset_into(&sc.xf, &model.lm_head, &mut sc.logits);
    &sc.logits
}

/// Allocating wrapper over [`step_many_into`] (tests and one-off
/// callers; the batcher reuses a `StepScratch` across iterations).
pub fn step_many(sessions: &mut [&mut DecodeSession], tokens: &[u32])
                 -> Vec<Vec<f32>> {
    assert_eq!(sessions.len(), tokens.len(), "one token per session");
    if sessions.is_empty() {
        return Vec::new();
    }
    let mut sc = StepScratch::new();
    let logits = step_many_into(sessions, tokens, &mut sc);
    (0..logits.rows).map(|i| logits.row(i).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn decode_matches_full_forward() {
        // incremental KV decode must reproduce the full-sequence scorer
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 0));
        let toks: Vec<u32> = (1..21).collect();
        let full = model.score(&toks);
        let mut sess = DecodeSession::new(model.clone(), None);
        let mut last = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            last = sess.step(t);
            let want = full.row(i);
            for (g, w) in last.iter().zip(want) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "pos {i}: {g} vs {w}"
                );
            }
        }
        assert_eq!(last.len(), cfg.vocab_size);
        assert_eq!(sess.pos, 20);
    }

    #[test]
    fn batched_prefill_matches_stepwise() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 4));
        let toks: Vec<u32> = (1..25).collect();
        for odp in [
            None,
            Some(DecodeOdp { mu: vec![0.6; cfg.n_layers], l1_threshold: None }),
        ] {
            let mut stepwise = DecodeSession::new(model.clone(), odp.clone());
            let mut last = Vec::new();
            for &t in &toks {
                last = stepwise.step(t);
            }
            let mut batched = DecodeSession::new(model.clone(), odp);
            let got = batched.prefill(&toks);
            assert_eq!(batched.pos, stepwise.pos);
            for (g, w) in got.iter().zip(&last) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "prefill logits diverge: {g} vs {w}"
                );
            }
            // identical pruning decisions token-by-token vs batched
            assert_eq!(batched.stats.dropped_secondary,
                       stepwise.stats.dropped_secondary);
            assert_eq!(batched.stats.expert_calls, stepwise.stats.expert_calls);
        }
    }

    #[test]
    fn step_many_matches_individual_steps() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 5));
        let prompts: [&[u32]; 3] = [&[1, 5, 80], &[2, 9, 81, 44, 7], &[3]];
        let next: [u32; 3] = [10, 11, 12];
        // serial reference
        let mut serial_logits = Vec::new();
        for (p, &n) in prompts.iter().zip(&next) {
            let mut s = DecodeSession::new(model.clone(), None);
            s.prefill(p);
            serial_logits.push(s.step(n));
        }
        // fused
        let mut fused: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| {
                let mut s = DecodeSession::new(model.clone(), None);
                s.prefill(p);
                s
            })
            .collect();
        let got = {
            let mut refs: Vec<&mut DecodeSession> = fused.iter_mut().collect();
            step_many(&mut refs, &next)
        };
        for (i, (g, w)) in got.iter().zip(&serial_logits).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "session {i}: fused {a} vs serial {b}"
                );
            }
        }
        for (s, p) in fused.iter().zip(&prompts) {
            assert_eq!(s.pos, p.len() + 1);
        }
    }

    #[test]
    fn step_scratch_buffers_are_pointer_stable() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 6));
        let mut sessions: Vec<DecodeSession> = (0..3)
            .map(|i| {
                let mut s = DecodeSession::new(model.clone(), None);
                s.prefill(&[1, 4 + i as u32, 9]);
                s
            })
            .collect();
        let mut refs: Vec<&mut DecodeSession> =
            sessions.iter_mut().collect();
        let toks = [7u32, 8, 9];
        let mut sc = StepScratch::new();
        step_many_into(&mut refs, &toks, &mut sc);
        let ptrs = [
            sc.x.data.as_ptr(),
            sc.h.data.as_ptr(),
            sc.probs.data.as_ptr(),
            sc.logits.data.as_ptr(),
        ];
        for _ in 0..6 {
            step_many_into(&mut refs, &toks, &mut sc);
        }
        assert_eq!(
            ptrs,
            [
                sc.x.data.as_ptr(),
                sc.h.data.as_ptr(),
                sc.probs.data.as_ptr(),
                sc.logits.data.as_ptr(),
            ],
            "steady-state step buffers must not reallocate"
        );
    }

    #[test]
    fn decode_odp_prunes() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 1));
        let odp = DecodeOdp { mu: vec![2.0; cfg.n_layers], l1_threshold: None };
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..17 {
            sess.step(t);
        }
        // mu = 2.0 prunes every secondary expert
        assert_eq!(sess.stats.dropped_secondary, 16 * cfg.n_layers);
        assert_eq!(sess.stats.expert_calls,
                   sess.stats.expert_possible - sess.stats.dropped_secondary);
        assert_eq!(sess.stats.pruned_total(), sess.stats.dropped_secondary);
    }

    #[test]
    fn l1_protection_spares_some() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 2));
        let seqs: Vec<Vec<u32>> = vec![(1..33).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs,
                                       vec![2.0; cfg.n_layers], 0.5);
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..33 {
            sess.step(t);
        }
        // with 50% protection at an always-prune threshold, roughly
        // half the secondary experts survive
        let frac = sess.stats.dropped_secondary as f64
            / (sess.stats.tokens_seen * cfg.n_layers) as f64;
        assert!((0.2..0.8).contains(&frac), "{frac}");
    }

    #[test]
    fn calibrated_thresholds_have_layer_arity() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 3));
        let seqs: Vec<Vec<u32>> = vec![(1..17).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs, vec![0.5; cfg.n_layers], 0.02);
        assert_eq!(odp.l1_threshold.unwrap().len(), cfg.n_layers);
    }

    #[test]
    fn shared_prefix_decode_matches_private_bit_exact() {
        // a session that attaches an exported prefix must produce the
        // same logits and greedy tokens as one owning the whole prompt
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 7));
        let prompt: Vec<u32> = (1..25).collect();
        let head = &prompt[..20];

        let mut donor = DecodeSession::new(model.clone(), None);
        donor.enable_importance();
        donor.prefill(&prompt);
        let (k, v, imp) = donor.export_prefix(head.len());
        assert_eq!(imp.len(), head.len());
        assert!(imp.iter().all(|x| x.is_finite()));
        let prefix = Arc::new(SharedPrefix {
            tokens: head.to_vec(),
            k,
            v,
            rows: head.len(),
            importance: imp,
        });

        let decode = |sess: &mut DecodeSession, tail: &[u32]| {
            let mut logits = sess.prefill(tail);
            let mut toks = Vec::new();
            for _ in 0..8 {
                let next = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0 as u32;
                toks.push(next);
                logits = sess.step(next);
            }
            (toks, logits)
        };

        let mut private = DecodeSession::new(model.clone(), None);
        let (want_toks, want_logits) = decode(&mut private, &prompt);

        let mut shared = DecodeSession::new(model.clone(), None);
        shared.enable_importance();
        shared.attach_prefix(prefix.clone());
        assert_eq!(shared.pos, head.len());
        let (got_toks, got_logits) =
            decode(&mut shared, &prompt[head.len()..]);

        assert_eq!(got_toks, want_toks, "greedy tokens must be identical");
        assert_eq!(got_logits, want_logits, "logits must be bit-exact");
        assert_eq!(Arc::strong_count(&prefix), 2, "session holds the Arc");
    }

    #[test]
    fn kv_compress_quantizes_cold_pages_and_stays_close() {
        let mut cfg = ModelConfig::test_tiny();
        cfg.max_seq = 3 * DEFAULT_PAGE_ROWS;
        let model = Arc::new(random_model(&cfg, 8));
        let prompt: Vec<u32> =
            (0..2 * DEFAULT_PAGE_ROWS as u32 + 2).map(|t| 1 + t % 250).collect();

        let mut plain = DecodeSession::new(model.clone(), None);
        plain.prefill(&prompt);
        let mut sess = DecodeSession::new(model.clone(), None);
        sess.enable_importance();
        sess.prefill(&prompt);

        // protect_recent large enough -> nothing eligible
        assert_eq!(sess.kv_compress(1.0, cfg.max_seq), 0);
        let saved = sess.kv_compress(1.0, 0);
        assert!(saved > 0, "two full cold pages must down-quantize");
        assert_eq!(sess.quantized_pages(), 2);
        // idempotent: already-quantized pages are skipped
        assert_eq!(sess.kv_compress(1.0, 0), 0);

        // decode after compression tracks the uncompressed session
        for t in [9u32, 42, 77] {
            let want = plain.step(t);
            let got = sess.step(t);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() < 0.05 * (1.0 + w.abs()),
                    "f16 KV drifted: {g} vs {w}"
                );
            }
        }

        // reset drops the (unwritable) quantized pages; session reusable
        sess.reset();
        assert_eq!(sess.quantized_pages(), 0);
        sess.prefill(&[1, 2, 3]);
        sess.step(4);
    }

    #[test]
    fn half_fraction_compress_prefers_low_importance_pages() {
        let mut cfg = ModelConfig::test_tiny();
        cfg.max_seq = 3 * DEFAULT_PAGE_ROWS;
        let model = Arc::new(random_model(&cfg, 9));
        let prompt: Vec<u32> =
            (0..2 * DEFAULT_PAGE_ROWS as u32).map(|t| 1 + t % 250).collect();
        let mut sess = DecodeSession::new(model, None);
        sess.enable_importance();
        sess.prefill(&prompt);
        assert_eq!(sess.importance().len(), prompt.len());
        let saved = sess.kv_compress(0.5, 0);
        assert!(saved > 0);
        assert_eq!(sess.quantized_pages(), 1, "ceil(0.5 * 2) = 1 page");
    }
}
