//! Incremental KV-cache decoding over the (quantized) native engine,
//! as a thin driver over the shared execution core `moe::exec`
//! (DESIGN.md §2): the attention kernel, routing/ODP decisions, and
//! expert dispatch are the same code the scoring forward runs, so the
//! two paths can no longer drift.
//!
//! **Zero-allocation steady state (DESIGN.md §4):** every buffer the
//! decode loop touches lives in a scratch arena owned by its driver —
//! [`SessionScratch`] per session (projection/attention/router/
//! dispatch buffers, reserved up front so the growing KV window never
//! reallocates) and [`StepScratch`] per fused-batch driver. After the
//! first step at a given batch shape, `step_many_into` performs no
//! heap allocation in the attention/dispatch/GEMM paths
//! (`tests/zero_alloc.rs` asserts this with a counting allocator).
//!
//! ODP at decode time (paper Sec. 3.3 applied autoregressively): the
//! w1/w0 ratio rule is exact; Eq.-6 token protection needs attention
//! *received from future queries*, which doesn't exist yet for the
//! token being decoded, so protection falls back to the L1-norm factor
//! of Eq. 6 alone. The threshold is the calibrated (1-protect_ratio)
//! percentile of training-distribution L1 norms (see
//! `DecodeOdp::calibrate`); divergence from the paper documented in
//! DESIGN.md §2.
//!
//! `prefill` runs the whole prompt as ONE batched full-sequence pass
//! that fills the KV cache in a single shot (not S sequential steps);
//! `step_many` advances several sessions at once, dispatching each
//! expert at most once per layer per iteration (the fused batcher
//! step, DESIGN.md §3), with per-session attention fanned out across
//! the `WorkerPool` (disjoint KV caches and output rows, so pooled
//! results are bit-exact with serial).

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::moe::exec::{attention, dispatch, router};
use crate::moe::exec::attention::AttnScratch;
use crate::moe::exec::dispatch::{DispatchMode, DispatchScratch, ExpertsRef};
use crate::moe::model::{Expert, MoeModel, RunStats, RMS_EPS};
use crate::offload;
use crate::quant::QmScratch;
use crate::tensor::{
    add_inplace, matmul_reset_into, rmsnorm_into, vecmat_into, Mat,
};
use crate::util::pool::{SendPtr, WorkerPool};

pub use crate::moe::exec::router::DecodeOdp;

/// Per-session attention fan-out gate: total score+mix work
/// (Σ klen · d) below this stays serial in `step_many_into`.
const SESSION_ATTN_MIN_WORK: usize = 65_536;

struct LayerKv {
    k: Mat, // [max_seq, D]
    v: Mat,
}

/// Per-session scratch arena: every intermediate of the layer stack,
/// reused across steps. Buffers are reserved for the session's
/// steady-state decode shapes at construction, so their pointers stay
/// stable from the first step on.
pub struct SessionScratch {
    pub attn: AttnScratch,
    pub attn_out: Mat,
    pub x: Mat,
    pub h: Mat,
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub proj: Mat,
    pub probs: Mat,
    pub moe_y: Mat,
    pub xf: Mat,
    pub topk: Vec<Vec<(usize, f32)>>,
    pub dispatch: DispatchScratch,
    pub qs: QmScratch,
    /// per-layer routed expert set + pinned slots (cache-resolved
    /// models only; resident decode never touches these)
    needed: Vec<usize>,
    pins: Vec<Option<Arc<Expert>>>,
}

impl SessionScratch {
    fn new(cfg: &ModelConfig) -> SessionScratch {
        let mut attn = AttnScratch::new();
        attn.reserve(cfg.head_dim(), cfg.max_seq);
        SessionScratch {
            attn,
            attn_out: Mat::zeros(0, 0),
            x: Mat::zeros(0, 0),
            h: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            probs: Mat::zeros(0, 0),
            moe_y: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            topk: Vec::new(),
            dispatch: DispatchScratch::new(),
            qs: QmScratch::new(),
            needed: Vec::new(),
            pins: Vec::new(),
        }
    }
}

pub struct DecodeSession {
    pub model: Arc<MoeModel>,
    kv: Vec<LayerKv>,
    pub pos: usize,
    pub odp: Option<DecodeOdp>,
    /// Same accounting struct as the scoring path (`RunStats`), so
    /// pruning metrics mean the same thing on both paths.
    pub stats: RunStats,
    pub scratch: SessionScratch,
}

impl DecodeSession {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>) -> DecodeSession {
        let (s, d) = (model.cfg.max_seq, model.cfg.d_model);
        let kv = (0..model.cfg.n_layers)
            .map(|_| LayerKv { k: Mat::zeros(s, d), v: Mat::zeros(s, d) })
            .collect();
        let stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
        let scratch = SessionScratch::new(&model.cfg);
        DecodeSession { model, kv, pos: 0, odp, stats, scratch }
    }

    pub fn remaining(&self) -> usize {
        self.model.cfg.max_seq - self.pos
    }

    /// Rewind to an empty sequence, keeping the allocated KV buffers
    /// (stale rows are never read: attention only sees rows < pos).
    pub fn reset(&mut self) {
        self.pos = 0;
        self.stats = RunStats::new(self.model.cfg.n_layers,
                                   self.model.cfg.n_experts);
    }

    /// Feed the whole prompt in ONE batched full-sequence pass (fills
    /// the KV cache in a single shot); returns last-position logits.
    pub fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        self.prefill_into(tokens, &mut logits);
        logits
    }

    /// `prefill` into a caller-owned logits buffer (left empty for an
    /// empty prompt).
    pub fn prefill_into(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        logits.clear();
        if !tokens.is_empty() {
            self.append(tokens, logits);
        }
    }

    /// Append one token, return next-token logits.
    pub fn step(&mut self, token: u32) -> Vec<f32> {
        let mut logits = Vec::new();
        self.step_into(token, &mut logits);
        logits
    }

    /// `step` into a caller-owned logits buffer — with a warmed buffer
    /// this is the zero-allocation single-session decode path.
    pub fn step_into(&mut self, token: u32, logits: &mut Vec<f32>) {
        self.append(&[token], logits);
    }

    /// Append `tokens` at positions `pos..pos+T` in one batched pass
    /// and write the logits of the last appended position.
    fn append(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        let model = self.model.clone();
        let cfg = &model.cfg;
        let d = cfg.d_model;
        let t_new = tokens.len();
        let pos0 = self.pos;
        assert!(t_new >= 1);
        assert!(pos0 + t_new <= cfg.max_seq, "KV cache exhausted");
        self.pos += t_new;
        self.stats.tokens_seen += t_new;
        // multi-token appends (prefill) pool attention across heads;
        // single-token decode stays serial (it is pooled across
        // sessions by `step_many_into` instead)
        let attn_pool =
            if t_new > 1 { Some(WorkerPool::global()) } else { None };

        let (kv, sc, stats, odp) = (
            &mut self.kv,
            &mut self.scratch,
            &mut self.stats,
            self.odp.as_ref(),
        );

        // token + positional embedding at this session's positions
        sc.x.resize_to(t_new, d);
        for (t, &tok) in tokens.iter().enumerate() {
            model.embed_row(tok, pos0 + t, sc.x.row_mut(t));
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // attention with KV cache (shared kernel, append shape)
            rmsnorm_into(&sc.x, &layer.attn_norm, RMS_EPS, &mut sc.h);
            layer.wq.matmul_into(&sc.h, &mut sc.q, &mut sc.qs);
            layer.wk.matmul_into(&sc.h, &mut sc.k, &mut sc.qs);
            layer.wv.matmul_into(&sc.h, &mut sc.v, &mut sc.qs);
            let cache = &mut kv[li];
            for i in 0..t_new {
                cache.k.row_mut(pos0 + i).copy_from_slice(sc.k.row(i));
                cache.v.row_mut(pos0 + i).copy_from_slice(sc.v.row(i));
            }
            attention::causal_attention_into(
                &sc.q, &cache.k, &cache.v, pos0 + t_new, cfg.n_heads, false,
                attn_pool, &mut sc.attn, &mut sc.attn_out,
            );
            layer.wo.matmul_into(&sc.attn_out, &mut sc.proj, &mut sc.qs);
            add_inplace(&mut sc.x, &sc.proj);

            // MoE with decode-time ODP (shared router + dispatch)
            rmsnorm_into(&sc.x, &layer.ffn_norm, RMS_EPS, &mut sc.h);
            router::gate_probs_into(&sc.h, &layer.gate, &mut sc.probs);
            while sc.topk.len() < t_new {
                sc.topk.push(Vec::new());
            }
            for t in 0..t_new {
                router::decode_select_into(
                    sc.probs.row(t),
                    sc.h.row(t),
                    cfg.top_k,
                    li,
                    odp,
                    stats,
                    &mut sc.topk[t],
                );
            }
            if model.resolver.is_resident() {
                dispatch::dispatch_experts_into(
                    &sc.h,
                    &sc.topk[..t_new],
                    ExpertsRef::resident(&layer.experts),
                    None,
                    DispatchMode::Auto,
                    &mut sc.dispatch,
                );
            } else {
                // pin the routed set for this dispatch; the predictor
                // prefetches layer li+1 while these FFNs execute
                offload::unique_experts(&sc.topk[..t_new], &mut sc.needed);
                let unavailable =
                    model.resolver.pin_layer(li, &sc.needed, &mut sc.pins);
                model.resolver.note_routing(li, &sc.needed);
                if unavailable > 0
                    && offload::degrade_topk(&mut sc.topk[..t_new], &sc.pins) > 0
                {
                    model.resolver.note_degraded();
                }
                dispatch::dispatch_experts_into(
                    &sc.h,
                    &sc.topk[..t_new],
                    ExpertsRef::pinned(&sc.pins),
                    None,
                    DispatchMode::Auto,
                    &mut sc.dispatch,
                );
                model.resolver.unpin_layer(li, &sc.needed);
            }
            dispatch::scatter_into(&sc.dispatch, t_new, d, &mut sc.moe_y);
            add_inplace(&mut sc.x, &sc.moe_y);
        }

        rmsnorm_into(&sc.x, &model.final_norm, RMS_EPS, &mut sc.xf);
        // only the last position's logits are the decode output
        vecmat_into(sc.xf.row(t_new - 1), &model.lm_head, logits);
    }
}

/// Per-driver scratch for the fused multi-session step: batched
/// projections, routing selections, dispatch buffers, and the logits
/// matrix `step_many_into` returns a view of. `dispatch_mode` defaults
/// to `Auto`; `benches/hotpath.rs` overrides it to compare the pool
/// against the legacy spawn-per-step baseline.
pub struct StepScratch {
    pub dispatch_mode: DispatchMode,
    pub x: Mat,
    pub h: Mat,
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub attn_out: Mat,
    pub proj: Mat,
    pub probs: Mat,
    pub moe_y: Mat,
    pub xf: Mat,
    pub logits: Mat,
    pub topk: Vec<Vec<(usize, f32)>>,
    pub dispatch: DispatchScratch,
    pub qs: QmScratch,
    positions: Vec<usize>,
    /// cache-resolved models only (see `SessionScratch`)
    needed: Vec<usize>,
    pins: Vec<Option<Arc<Expert>>>,
}

impl Default for StepScratch {
    fn default() -> StepScratch {
        StepScratch {
            dispatch_mode: DispatchMode::Auto,
            x: Mat::zeros(0, 0),
            h: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            attn_out: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            probs: Mat::zeros(0, 0),
            moe_y: Mat::zeros(0, 0),
            xf: Mat::zeros(0, 0),
            logits: Mat::zeros(0, 0),
            topk: Vec::new(),
            dispatch: DispatchScratch::new(),
            qs: QmScratch::new(),
            positions: Vec::new(),
            needed: Vec::new(),
            pins: Vec::new(),
        }
    }
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch::default()
    }
}

/// One session's decode attention inside the fused step: append this
/// step's K/V rows to the session's cache, run single-query attention
/// with the session-owned scratch, and write the result into row `i`
/// of the shared attention output (disjoint across sessions).
fn session_attention(
    sess: &mut DecodeSession,
    li: usize,
    i: usize,
    t: usize,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    n_heads: usize,
    attn_base: SendPtr<f32>,
    d: usize,
) {
    let cache = &mut sess.kv[li];
    cache.k.row_mut(t).copy_from_slice(k.row(i));
    cache.v.row_mut(t).copy_from_slice(v.row(i));
    let sc = &mut sess.scratch;
    sc.q.resize_to(1, d);
    sc.q.row_mut(0).copy_from_slice(q.row(i));
    attention::causal_attention_into(
        &sc.q, &cache.k, &cache.v, t + 1, n_heads, false, None, &mut sc.attn,
        &mut sc.attn_out,
    );
    // Safety: session i owns row i of the shared output exclusively.
    let orow =
        unsafe { std::slice::from_raw_parts_mut(attn_base.0.add(i * d), d) };
    orow.copy_from_slice(sc.attn_out.row(0));
}

/// Advance several sessions (sharing one model) by one token each in a
/// fused pass: attention runs per session over its own KV cache
/// (pool-parallel across sessions), while layer projections, routing,
/// and expert dispatch run once over the whole batch — each expert
/// executes at most once per layer per iteration, regardless of how
/// many sessions selected it. Returns a view of the per-session
/// next-token logits ([B, vocab], row i = session i), identical to
/// calling `step` on each session individually.
pub fn step_many_into<'a>(
    sessions: &mut [&mut DecodeSession],
    tokens: &[u32],
    sc: &'a mut StepScratch,
) -> &'a Mat {
    let b = sessions.len();
    assert_eq!(b, tokens.len(), "one token per session");
    assert!(b > 0, "empty fused step");
    let model = sessions[0].model.clone();
    for s in sessions.iter() {
        assert!(Arc::ptr_eq(&s.model, &model), "fused step needs a shared model");
        assert!(s.pos < model.cfg.max_seq, "KV cache exhausted");
    }
    let cfg = &model.cfg;
    let d = cfg.d_model;

    // each session's token embeds at that session's own position
    sc.positions.clear();
    sc.x.resize_to(b, d);
    for (i, s) in sessions.iter_mut().enumerate() {
        sc.positions.push(s.pos);
        model.embed_row(tokens[i], s.pos, sc.x.row_mut(i));
        s.pos += 1;
        s.stats.tokens_seen += 1;
    }

    let pool = WorkerPool::global();
    let attn_work: usize = sc.positions.iter().map(|p| (p + 1) * d).sum();

    for (li, layer) in model.layers.iter().enumerate() {
        // batched projections; per-session attention over its own cache
        rmsnorm_into(&sc.x, &layer.attn_norm, RMS_EPS, &mut sc.h);
        layer.wq.matmul_into(&sc.h, &mut sc.q, &mut sc.qs);
        layer.wk.matmul_into(&sc.h, &mut sc.k, &mut sc.qs);
        layer.wv.matmul_into(&sc.h, &mut sc.v, &mut sc.qs);
        sc.attn_out.resize_to(b, d);
        {
            let attn_base = SendPtr(sc.attn_out.data.as_mut_ptr());
            let (q, k, v) = (&sc.q, &sc.k, &sc.v);
            let positions = &sc.positions;
            let nh = cfg.n_heads;
            if b >= 2
                && pool.width() > 1
                && attn_work >= SESSION_ATTN_MIN_WORK
                && !WorkerPool::on_worker()
            {
                let sptr = SendPtr(sessions.as_mut_ptr());
                pool.for_each(b, move |i| {
                    // Safety: indices are unique per region, so each
                    // task holds the only &mut to its session.
                    let sess = unsafe { &mut **sptr.0.add(i) };
                    session_attention(sess, li, i, positions[i], q, k, v, nh,
                                      attn_base, d);
                });
            } else {
                for i in 0..b {
                    session_attention(&mut *sessions[i], li, i, positions[i],
                                      q, k, v, nh, attn_base, d);
                }
            }
        }
        layer.wo.matmul_into(&sc.attn_out, &mut sc.proj, &mut sc.qs);
        add_inplace(&mut sc.x, &sc.proj);

        // fused MoE: route the whole batch, dispatch each expert once
        rmsnorm_into(&sc.x, &layer.ffn_norm, RMS_EPS, &mut sc.h);
        router::gate_probs_into(&sc.h, &layer.gate, &mut sc.probs);
        while sc.topk.len() < b {
            sc.topk.push(Vec::new());
        }
        for (i, sess) in sessions.iter_mut().enumerate() {
            router::decode_select_into(
                sc.probs.row(i),
                sc.h.row(i),
                cfg.top_k,
                li,
                sess.odp.as_ref(),
                &mut sess.stats,
                &mut sc.topk[i],
            );
        }
        if model.resolver.is_resident() {
            dispatch::dispatch_experts_into(
                &sc.h,
                &sc.topk[..b],
                ExpertsRef::resident(&layer.experts),
                None,
                sc.dispatch_mode,
                &mut sc.dispatch,
            );
        } else {
            offload::unique_experts(&sc.topk[..b], &mut sc.needed);
            let unavailable =
                model.resolver.pin_layer(li, &sc.needed, &mut sc.pins);
            model.resolver.note_routing(li, &sc.needed);
            if unavailable > 0
                && offload::degrade_topk(&mut sc.topk[..b], &sc.pins) > 0
            {
                model.resolver.note_degraded();
            }
            dispatch::dispatch_experts_into(
                &sc.h,
                &sc.topk[..b],
                ExpertsRef::pinned(&sc.pins),
                None,
                sc.dispatch_mode,
                &mut sc.dispatch,
            );
            model.resolver.unpin_layer(li, &sc.needed);
        }
        dispatch::scatter_into(&sc.dispatch, b, d, &mut sc.moe_y);
        add_inplace(&mut sc.x, &sc.moe_y);
    }

    rmsnorm_into(&sc.x, &model.final_norm, RMS_EPS, &mut sc.xf);
    matmul_reset_into(&sc.xf, &model.lm_head, &mut sc.logits);
    &sc.logits
}

/// Allocating wrapper over [`step_many_into`] (tests and one-off
/// callers; the batcher reuses a `StepScratch` across iterations).
pub fn step_many(sessions: &mut [&mut DecodeSession], tokens: &[u32])
                 -> Vec<Vec<f32>> {
    assert_eq!(sessions.len(), tokens.len(), "one token per session");
    if sessions.is_empty() {
        return Vec::new();
    }
    let mut sc = StepScratch::new();
    let logits = step_many_into(sessions, tokens, &mut sc);
    (0..logits.rows).map(|i| logits.row(i).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn decode_matches_full_forward() {
        // incremental KV decode must reproduce the full-sequence scorer
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 0));
        let toks: Vec<u32> = (1..21).collect();
        let full = model.score(&toks);
        let mut sess = DecodeSession::new(model.clone(), None);
        let mut last = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            last = sess.step(t);
            let want = full.row(i);
            for (g, w) in last.iter().zip(want) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "pos {i}: {g} vs {w}"
                );
            }
        }
        assert_eq!(last.len(), cfg.vocab_size);
        assert_eq!(sess.pos, 20);
    }

    #[test]
    fn batched_prefill_matches_stepwise() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 4));
        let toks: Vec<u32> = (1..25).collect();
        for odp in [
            None,
            Some(DecodeOdp { mu: vec![0.6; cfg.n_layers], l1_threshold: None }),
        ] {
            let mut stepwise = DecodeSession::new(model.clone(), odp.clone());
            let mut last = Vec::new();
            for &t in &toks {
                last = stepwise.step(t);
            }
            let mut batched = DecodeSession::new(model.clone(), odp);
            let got = batched.prefill(&toks);
            assert_eq!(batched.pos, stepwise.pos);
            for (g, w) in got.iter().zip(&last) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "prefill logits diverge: {g} vs {w}"
                );
            }
            // identical pruning decisions token-by-token vs batched
            assert_eq!(batched.stats.dropped_secondary,
                       stepwise.stats.dropped_secondary);
            assert_eq!(batched.stats.expert_calls, stepwise.stats.expert_calls);
        }
    }

    #[test]
    fn step_many_matches_individual_steps() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 5));
        let prompts: [&[u32]; 3] = [&[1, 5, 80], &[2, 9, 81, 44, 7], &[3]];
        let next: [u32; 3] = [10, 11, 12];
        // serial reference
        let mut serial_logits = Vec::new();
        for (p, &n) in prompts.iter().zip(&next) {
            let mut s = DecodeSession::new(model.clone(), None);
            s.prefill(p);
            serial_logits.push(s.step(n));
        }
        // fused
        let mut fused: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| {
                let mut s = DecodeSession::new(model.clone(), None);
                s.prefill(p);
                s
            })
            .collect();
        let got = {
            let mut refs: Vec<&mut DecodeSession> = fused.iter_mut().collect();
            step_many(&mut refs, &next)
        };
        for (i, (g, w)) in got.iter().zip(&serial_logits).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "session {i}: fused {a} vs serial {b}"
                );
            }
        }
        for (s, p) in fused.iter().zip(&prompts) {
            assert_eq!(s.pos, p.len() + 1);
        }
    }

    #[test]
    fn step_scratch_buffers_are_pointer_stable() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 6));
        let mut sessions: Vec<DecodeSession> = (0..3)
            .map(|i| {
                let mut s = DecodeSession::new(model.clone(), None);
                s.prefill(&[1, 4 + i as u32, 9]);
                s
            })
            .collect();
        let mut refs: Vec<&mut DecodeSession> =
            sessions.iter_mut().collect();
        let toks = [7u32, 8, 9];
        let mut sc = StepScratch::new();
        step_many_into(&mut refs, &toks, &mut sc);
        let ptrs = [
            sc.x.data.as_ptr(),
            sc.h.data.as_ptr(),
            sc.probs.data.as_ptr(),
            sc.logits.data.as_ptr(),
        ];
        for _ in 0..6 {
            step_many_into(&mut refs, &toks, &mut sc);
        }
        assert_eq!(
            ptrs,
            [
                sc.x.data.as_ptr(),
                sc.h.data.as_ptr(),
                sc.probs.data.as_ptr(),
                sc.logits.data.as_ptr(),
            ],
            "steady-state step buffers must not reallocate"
        );
    }

    #[test]
    fn decode_odp_prunes() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 1));
        let odp = DecodeOdp { mu: vec![2.0; cfg.n_layers], l1_threshold: None };
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..17 {
            sess.step(t);
        }
        // mu = 2.0 prunes every secondary expert
        assert_eq!(sess.stats.dropped_secondary, 16 * cfg.n_layers);
        assert_eq!(sess.stats.expert_calls,
                   sess.stats.expert_possible - sess.stats.dropped_secondary);
        assert_eq!(sess.stats.pruned_total(), sess.stats.dropped_secondary);
    }

    #[test]
    fn l1_protection_spares_some() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 2));
        let seqs: Vec<Vec<u32>> = vec![(1..33).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs,
                                       vec![2.0; cfg.n_layers], 0.5);
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..33 {
            sess.step(t);
        }
        // with 50% protection at an always-prune threshold, roughly
        // half the secondary experts survive
        let frac = sess.stats.dropped_secondary as f64
            / (sess.stats.tokens_seen * cfg.n_layers) as f64;
        assert!((0.2..0.8).contains(&frac), "{frac}");
    }

    #[test]
    fn calibrated_thresholds_have_layer_arity() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 3));
        let seqs: Vec<Vec<u32>> = vec![(1..17).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs, vec![0.5; cfg.n_layers], 0.02);
        assert_eq!(odp.l1_threshold.unwrap().len(), cfg.n_layers);
    }
}
