//! Incremental KV-cache decoding over the (quantized) native engine.
//!
//! ODP at decode time (paper Sec. 3.3 applied autoregressively): the
//! w1/w0 ratio rule is exact; Eq.-6 token protection needs attention
//! *received from future queries*, which doesn't exist yet for the
//! token being decoded, so protection falls back to the L1-norm factor
//! of Eq. 6 alone — a token whose hidden state has large ‖t‖₁ keeps
//! both experts. The threshold is the calibrated (1-protect_ratio)
//! percentile of training-distribution L1 norms (see
//! `DecodeOdp::calibrate`); divergence from the paper documented in
//! DESIGN.md §2.

use std::sync::Arc;

use crate::moe::model::{select_top_k, MoeModel, RMS_EPS};
use crate::quant::QTensor;
use crate::tensor::{rmsnorm, silu, softmax_rows, Mat};
use crate::util::stats::percentile;

#[derive(Debug, Clone, Default)]
pub struct DecodeOdp {
    /// per-layer ratio threshold (median of w1/w0 on calibration data)
    pub mu: Vec<f32>,
    /// per-layer L1-norm protection threshold (None = no protection)
    pub l1_threshold: Option<Vec<f32>>,
}

impl DecodeOdp {
    /// Calibrate L1 thresholds: protect tokens whose post-norm hidden
    /// L1 exceeds the (1-protect_ratio) percentile per layer.
    pub fn calibrate(model: &MoeModel, seqs: &[Vec<u32>], mu: Vec<f32>,
                     protect_ratio: f32) -> DecodeOdp {
        use crate::moe::model::{CalibSink, ForwardOpts};
        struct L1Sink(Vec<Vec<f32>>);
        impl CalibSink for L1Sink {
            fn moe_input(&mut self, layer: usize, x: &Mat) {
                for r in 0..x.rows {
                    self.0[layer].push(x.row(r).iter().map(|v| v.abs()).sum());
                }
            }
        }
        let mut sink = L1Sink(vec![Vec::new(); model.cfg.n_layers]);
        for s in seqs {
            model.forward(s, &ForwardOpts::default(), &mut sink);
        }
        let thresholds = sink
            .0
            .iter()
            .map(|l1s| percentile(l1s, 100.0 * (1.0 - protect_ratio)))
            .collect();
        DecodeOdp { mu, l1_threshold: Some(thresholds) }
    }
}

struct LayerKv {
    k: Mat, // [max_seq, D]
    v: Mat,
}

#[derive(Debug, Default, Clone)]
pub struct DecodeStats {
    pub tokens: usize,
    pub expert_calls: usize,
    pub expert_possible: usize,
    pub dropped_secondary: usize,
}

pub struct DecodeSession {
    pub model: Arc<MoeModel>,
    kv: Vec<LayerKv>,
    pub pos: usize,
    pub odp: Option<DecodeOdp>,
    pub stats: DecodeStats,
}

impl DecodeSession {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>) -> DecodeSession {
        let (s, d) = (model.cfg.max_seq, model.cfg.d_model);
        let kv = (0..model.cfg.n_layers)
            .map(|_| LayerKv { k: Mat::zeros(s, d), v: Mat::zeros(s, d) })
            .collect();
        DecodeSession { model, kv, pos: 0, odp, stats: DecodeStats::default() }
    }

    pub fn remaining(&self) -> usize {
        self.model.cfg.max_seq - self.pos
    }

    /// Feed the prompt token-by-token; returns last-position logits.
    pub fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.step(t);
        }
        logits
    }

    /// Append one token, return next-token logits.
    pub fn step(&mut self, token: u32) -> Vec<f32> {
        let model = self.model.clone();
        let cfg = &model.cfg;
        let (d, nh) = (cfg.d_model, cfg.n_heads);
        let hd = d / nh;
        let t = self.pos;
        assert!(t < cfg.max_seq, "KV cache exhausted");
        self.pos += 1;
        self.stats.tokens += 1;

        let mut x = Mat::zeros(1, d);
        let emb = model.tok_emb.row(token as usize);
        let pos = model.pos_emb.row(t);
        for c in 0..d {
            x.data[c] = emb[c] + pos[c];
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // attention with KV cache
            let h = rmsnorm(&x, &layer.attn_norm, RMS_EPS);
            let q = layer.wq.matmul(&h);
            let krow = layer.wk.matmul(&h);
            let vrow = layer.wv.matmul(&h);
            let cache = &mut self.kv[li];
            cache.k.row_mut(t).copy_from_slice(krow.row(0));
            cache.v.row_mut(t).copy_from_slice(vrow.row(0));
            let mut attn_out = Mat::zeros(1, d);
            let scale = 1.0 / (hd as f32).sqrt();
            for head in 0..nh {
                let c0 = head * hd;
                let qh = &q.row(0)[c0..c0 + hd];
                let mut scores = Mat::zeros(1, t + 1);
                for j in 0..=t {
                    let kh = &cache.k.row(j)[c0..c0 + hd];
                    scores.data[j] =
                        qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax_rows(&mut scores);
                let orow = &mut attn_out.data[c0..c0 + hd];
                for j in 0..=t {
                    let a = scores.data[j];
                    let vh = &cache.v.row(j)[c0..c0 + hd];
                    for (o, &vv) in orow.iter_mut().zip(vh) {
                        *o += a * vv;
                    }
                }
            }
            let proj = layer.wo.matmul(&attn_out);
            for (xa, &p) in x.data.iter_mut().zip(&proj.data) {
                *xa += p;
            }

            // MoE with decode-time ODP
            let h = rmsnorm(&x, &layer.ffn_norm, RMS_EPS);
            let mut probs = h.matmul(&layer.gate);
            softmax_rows(&mut probs);
            let mut sel = select_top_k(probs.row(0), cfg.top_k, |_| true);
            let sum: f32 = sel.iter().map(|&(_, w)| w).sum();
            for se in sel.iter_mut() {
                se.1 /= sum;
            }
            self.stats.expert_possible += sel.len();
            if let Some(odp) = &self.odp {
                let ratio = if sel.len() >= 2 { sel[1].1 / sel[0].1 } else { 0.0 };
                let protected = match &odp.l1_threshold {
                    Some(thr) => {
                        let l1: f32 = h.row(0).iter().map(|v| v.abs()).sum();
                        l1 >= thr[li]
                    }
                    None => false,
                };
                if !protected && sel.len() >= 2 && ratio < odp.mu[li] {
                    sel.truncate(1);
                    sel[0].1 = 1.0;
                    self.stats.dropped_secondary += 1;
                }
            }
            self.stats.expert_calls += sel.len();
            let mut y = vec![0.0f32; d];
            for &(e, w) in &sel {
                let out = expert_forward_row(&layer.experts[e].w1,
                                             &layer.experts[e].w3,
                                             &layer.experts[e].w2, &h);
                for (ya, &o) in y.iter_mut().zip(&out) {
                    *ya += w * o;
                }
            }
            for (xa, &ya) in x.data.iter_mut().zip(&y) {
                *xa += ya;
            }
        }

        let xf = rmsnorm(&x, &model.final_norm, RMS_EPS);
        xf.matmul(&model.lm_head).data
    }
}

/// Single-row SwiGLU expert FFN (the decode hot path).
pub fn expert_forward_row(w1: &QTensor, w3: &QTensor, w2: &QTensor,
                          x: &Mat) -> Vec<f32> {
    let mut h1 = w1.matmul(x);
    let h3 = w3.matmul(x);
    for (a, &b) in h1.data.iter_mut().zip(&h3.data) {
        *a = silu(*a) * b;
    }
    w2.matmul(&h1).data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn decode_matches_full_forward() {
        // incremental KV decode must reproduce the full-sequence scorer
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 0));
        let toks: Vec<u32> = (1..21).collect();
        let full = model.score(&toks);
        let mut sess = DecodeSession::new(model.clone(), None);
        let mut last = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            last = sess.step(t);
            let want = full.row(i);
            for (g, w) in last.iter().zip(want) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "pos {i}: {g} vs {w}"
                );
            }
        }
        assert_eq!(last.len(), cfg.vocab_size);
        assert_eq!(sess.pos, 20);
    }

    #[test]
    fn decode_odp_prunes() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 1));
        let odp = DecodeOdp { mu: vec![2.0; cfg.n_layers], l1_threshold: None };
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..17 {
            sess.step(t);
        }
        // mu = 2.0 prunes every secondary expert
        assert_eq!(sess.stats.dropped_secondary, 16 * cfg.n_layers);
        assert_eq!(sess.stats.expert_calls,
                   sess.stats.expert_possible - sess.stats.dropped_secondary);
    }

    #[test]
    fn l1_protection_spares_some() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 2));
        let seqs: Vec<Vec<u32>> = vec![(1..33).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs,
                                       vec![2.0; cfg.n_layers], 0.5);
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..33 {
            sess.step(t);
        }
        // with 50% protection at an always-prune threshold, roughly
        // half the secondary experts survive
        let frac = sess.stats.dropped_secondary as f64
            / (sess.stats.tokens * cfg.n_layers) as f64;
        assert!((0.2..0.8).contains(&frac), "{frac}");
    }

    #[test]
    fn calibrated_thresholds_have_layer_arity() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 3));
        let seqs: Vec<Vec<u32>> = vec![(1..17).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs, vec![0.5; cfg.n_layers], 0.02);
        assert_eq!(odp.l1_threshold.unwrap().len(), cfg.n_layers);
    }
}
