//! Incremental KV-cache decoding over the (quantized) native engine,
//! as a thin driver over the shared execution core `moe::exec`
//! (DESIGN.md §2): the attention kernel, routing/ODP decisions, and
//! expert dispatch are the same code the scoring forward runs, so the
//! two paths can no longer drift.
//!
//! ODP at decode time (paper Sec. 3.3 applied autoregressively): the
//! w1/w0 ratio rule is exact; Eq.-6 token protection needs attention
//! *received from future queries*, which doesn't exist yet for the
//! token being decoded, so protection falls back to the L1-norm factor
//! of Eq. 6 alone — a token whose hidden state has large ‖t‖₁ keeps
//! both experts. The threshold is the calibrated (1-protect_ratio)
//! percentile of training-distribution L1 norms (see
//! `DecodeOdp::calibrate`); divergence from the paper documented in
//! DESIGN.md §2.
//!
//! `prefill` runs the whole prompt as ONE batched full-sequence pass
//! that fills the KV cache in a single shot (not S sequential steps);
//! `step_many` advances several sessions at once, dispatching each
//! expert at most once per layer across the whole batch (the fused
//! batcher step, DESIGN.md §3).

use std::sync::Arc;

use crate::moe::exec::{attention, dispatch, router};
use crate::moe::model::{MoeModel, RunStats, RMS_EPS};
use crate::tensor::{add_inplace, rmsnorm, Mat};

pub use crate::moe::exec::router::DecodeOdp;

struct LayerKv {
    k: Mat, // [max_seq, D]
    v: Mat,
}

pub struct DecodeSession {
    pub model: Arc<MoeModel>,
    kv: Vec<LayerKv>,
    pub pos: usize,
    pub odp: Option<DecodeOdp>,
    /// Same accounting struct as the scoring path (`RunStats`), so
    /// pruning metrics mean the same thing on both paths.
    pub stats: RunStats,
}

impl DecodeSession {
    pub fn new(model: Arc<MoeModel>, odp: Option<DecodeOdp>) -> DecodeSession {
        let (s, d) = (model.cfg.max_seq, model.cfg.d_model);
        let kv = (0..model.cfg.n_layers)
            .map(|_| LayerKv { k: Mat::zeros(s, d), v: Mat::zeros(s, d) })
            .collect();
        let stats = RunStats::new(model.cfg.n_layers, model.cfg.n_experts);
        DecodeSession { model, kv, pos: 0, odp, stats }
    }

    pub fn remaining(&self) -> usize {
        self.model.cfg.max_seq - self.pos
    }

    /// Rewind to an empty sequence, keeping the allocated KV buffers
    /// (stale rows are never read: attention only sees rows < pos).
    pub fn reset(&mut self) {
        self.pos = 0;
        self.stats = RunStats::new(self.model.cfg.n_layers,
                                   self.model.cfg.n_experts);
    }

    /// Feed the whole prompt in ONE batched full-sequence pass (fills
    /// the KV cache in a single shot); returns last-position logits.
    pub fn prefill(&mut self, tokens: &[u32]) -> Vec<f32> {
        if tokens.is_empty() {
            return Vec::new();
        }
        self.append(tokens)
    }

    /// Append one token, return next-token logits.
    pub fn step(&mut self, token: u32) -> Vec<f32> {
        self.append(&[token])
    }

    /// Append `tokens` at positions `pos..pos+T` in one batched pass
    /// and return the logits of the last appended position.
    fn append(&mut self, tokens: &[u32]) -> Vec<f32> {
        let model = self.model.clone();
        let cfg = &model.cfg;
        let d = cfg.d_model;
        let t_new = tokens.len();
        let pos0 = self.pos;
        assert!(t_new >= 1);
        assert!(pos0 + t_new <= cfg.max_seq, "KV cache exhausted");
        self.pos += t_new;
        self.stats.tokens_seen += t_new;

        let mut x = model.embed(tokens, pos0);
        for (li, layer) in model.layers.iter().enumerate() {
            // attention with KV cache (shared kernel, append shape)
            let h = rmsnorm(&x, &layer.attn_norm, RMS_EPS);
            let q = layer.wq.matmul(&h);
            let knew = layer.wk.matmul(&h);
            let vnew = layer.wv.matmul(&h);
            let cache = &mut self.kv[li];
            for i in 0..t_new {
                cache.k.row_mut(pos0 + i).copy_from_slice(knew.row(i));
                cache.v.row_mut(pos0 + i).copy_from_slice(vnew.row(i));
            }
            let attn = attention::causal_attention(
                &q, &cache.k, &cache.v, pos0 + t_new, cfg.n_heads, false,
            );
            let proj = layer.wo.matmul(&attn.out);
            add_inplace(&mut x, &proj);

            // MoE with decode-time ODP (shared router + dispatch)
            let h = rmsnorm(&x, &layer.ffn_norm, RMS_EPS);
            let probs = router::gate_probs(&h, &layer.gate);
            let topk: Vec<Vec<(usize, f32)>> = (0..t_new)
                .map(|t| {
                    router::decode_select(
                        probs.row(t),
                        h.row(t),
                        cfg.top_k,
                        li,
                        self.odp.as_ref(),
                        &mut self.stats,
                    )
                })
                .collect();
            let batches = dispatch::dispatch_experts(
                &h,
                &topk,
                &layer.experts,
                None,
                dispatch::DispatchMode::Auto,
            );
            let y = dispatch::scatter(&batches, t_new, d);
            add_inplace(&mut x, &y);
        }

        let xf = rmsnorm(&x, &model.final_norm, RMS_EPS);
        // only the last position's logits are the decode output
        let last = xf.slice_rows(t_new - 1, t_new);
        last.matmul(&model.lm_head).data
    }
}

/// Advance several sessions (sharing one model) by one token each in a
/// fused pass: attention runs per session over its own KV cache, while
/// layer projections, routing, and expert dispatch run once over the
/// whole batch — each expert executes at most once per layer per
/// iteration, regardless of how many sessions selected it.
/// Returns next-token logits per session, identical to calling
/// `step` on each session individually.
pub fn step_many(sessions: &mut [&mut DecodeSession], tokens: &[u32])
                 -> Vec<Vec<f32>> {
    let b = sessions.len();
    assert_eq!(b, tokens.len(), "one token per session");
    if b == 0 {
        return Vec::new();
    }
    let model = sessions[0].model.clone();
    for s in sessions.iter() {
        assert!(Arc::ptr_eq(&s.model, &model), "fused step needs a shared model");
        assert!(s.pos < model.cfg.max_seq, "KV cache exhausted");
    }
    let cfg = &model.cfg;
    let d = cfg.d_model;
    // each session's token embeds at that session's own position
    let positions: Vec<usize> = sessions.iter().map(|s| s.pos).collect();
    let mut x = Mat::zeros(b, d);
    for (i, s) in sessions.iter_mut().enumerate() {
        let emb = model.tok_emb.row(tokens[i] as usize);
        let pos = model.pos_emb.row(s.pos);
        for c in 0..d {
            x.data[i * d + c] = emb[c] + pos[c];
        }
        s.pos += 1;
        s.stats.tokens_seen += 1;
    }

    for (li, layer) in model.layers.iter().enumerate() {
        // batched projections; per-session attention over its own cache
        let h = rmsnorm(&x, &layer.attn_norm, RMS_EPS);
        let q = layer.wq.matmul(&h);
        let k = layer.wk.matmul(&h);
        let v = layer.wv.matmul(&h);
        let mut attn_out = Mat::zeros(b, d);
        for (i, sess) in sessions.iter_mut().enumerate() {
            let t = positions[i];
            let cache = &mut sess.kv[li];
            cache.k.row_mut(t).copy_from_slice(k.row(i));
            cache.v.row_mut(t).copy_from_slice(v.row(i));
            let qi = q.slice_rows(i, i + 1);
            let a = attention::causal_attention(
                &qi, &cache.k, &cache.v, t + 1, cfg.n_heads, false,
            );
            attn_out.row_mut(i).copy_from_slice(a.out.row(0));
        }
        let proj = layer.wo.matmul(&attn_out);
        add_inplace(&mut x, &proj);

        // fused MoE: route the whole batch, dispatch each expert once
        let h = rmsnorm(&x, &layer.ffn_norm, RMS_EPS);
        let probs = router::gate_probs(&h, &layer.gate);
        let topk: Vec<Vec<(usize, f32)>> = sessions
            .iter_mut()
            .enumerate()
            .map(|(i, sess)| {
                router::decode_select(
                    probs.row(i),
                    h.row(i),
                    cfg.top_k,
                    li,
                    sess.odp.as_ref(),
                    &mut sess.stats,
                )
            })
            .collect();
        let batches = dispatch::dispatch_experts(
            &h,
            &topk,
            &layer.experts,
            None,
            dispatch::DispatchMode::Auto,
        );
        let y = dispatch::scatter(&batches, b, d);
        add_inplace(&mut x, &y);
    }

    let xf = rmsnorm(&x, &model.final_norm, RMS_EPS);
    let logits = xf.matmul(&model.lm_head);
    (0..b).map(|i| logits.row(i).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn decode_matches_full_forward() {
        // incremental KV decode must reproduce the full-sequence scorer
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 0));
        let toks: Vec<u32> = (1..21).collect();
        let full = model.score(&toks);
        let mut sess = DecodeSession::new(model.clone(), None);
        let mut last = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            last = sess.step(t);
            let want = full.row(i);
            for (g, w) in last.iter().zip(want) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "pos {i}: {g} vs {w}"
                );
            }
        }
        assert_eq!(last.len(), cfg.vocab_size);
        assert_eq!(sess.pos, 20);
    }

    #[test]
    fn batched_prefill_matches_stepwise() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 4));
        let toks: Vec<u32> = (1..25).collect();
        for odp in [
            None,
            Some(DecodeOdp { mu: vec![0.6; cfg.n_layers], l1_threshold: None }),
        ] {
            let mut stepwise = DecodeSession::new(model.clone(), odp.clone());
            let mut last = Vec::new();
            for &t in &toks {
                last = stepwise.step(t);
            }
            let mut batched = DecodeSession::new(model.clone(), odp);
            let got = batched.prefill(&toks);
            assert_eq!(batched.pos, stepwise.pos);
            for (g, w) in got.iter().zip(&last) {
                assert!(
                    (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "prefill logits diverge: {g} vs {w}"
                );
            }
            // identical pruning decisions token-by-token vs batched
            assert_eq!(batched.stats.dropped_secondary,
                       stepwise.stats.dropped_secondary);
            assert_eq!(batched.stats.expert_calls, stepwise.stats.expert_calls);
        }
    }

    #[test]
    fn step_many_matches_individual_steps() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 5));
        let prompts: [&[u32]; 3] = [&[1, 5, 80], &[2, 9, 81, 44, 7], &[3]];
        let next: [u32; 3] = [10, 11, 12];
        // serial reference
        let mut serial_logits = Vec::new();
        for (p, &n) in prompts.iter().zip(&next) {
            let mut s = DecodeSession::new(model.clone(), None);
            s.prefill(p);
            serial_logits.push(s.step(n));
        }
        // fused
        let mut fused: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| {
                let mut s = DecodeSession::new(model.clone(), None);
                s.prefill(p);
                s
            })
            .collect();
        let got = {
            let mut refs: Vec<&mut DecodeSession> = fused.iter_mut().collect();
            step_many(&mut refs, &next)
        };
        for (i, (g, w)) in got.iter().zip(&serial_logits).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "session {i}: fused {a} vs serial {b}"
                );
            }
        }
        for (s, p) in fused.iter().zip(&prompts) {
            assert_eq!(s.pos, p.len() + 1);
        }
    }

    #[test]
    fn decode_odp_prunes() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 1));
        let odp = DecodeOdp { mu: vec![2.0; cfg.n_layers], l1_threshold: None };
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..17 {
            sess.step(t);
        }
        // mu = 2.0 prunes every secondary expert
        assert_eq!(sess.stats.dropped_secondary, 16 * cfg.n_layers);
        assert_eq!(sess.stats.expert_calls,
                   sess.stats.expert_possible - sess.stats.dropped_secondary);
        assert_eq!(sess.stats.pruned_total(), sess.stats.dropped_secondary);
    }

    #[test]
    fn l1_protection_spares_some() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 2));
        let seqs: Vec<Vec<u32>> = vec![(1..33).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs,
                                       vec![2.0; cfg.n_layers], 0.5);
        let mut sess = DecodeSession::new(model, Some(odp));
        for t in 1..33 {
            sess.step(t);
        }
        // with 50% protection at an always-prune threshold, roughly
        // half the secondary experts survive
        let frac = sess.stats.dropped_secondary as f64
            / (sess.stats.tokens_seen * cfg.n_layers) as f64;
        assert!((0.2..0.8).contains(&frac), "{frac}");
    }

    #[test]
    fn calibrated_thresholds_have_layer_arity() {
        let cfg = ModelConfig::test_tiny();
        let model = Arc::new(random_model(&cfg, 3));
        let seqs: Vec<Vec<u32>> = vec![(1..17).collect()];
        let odp = DecodeOdp::calibrate(&model, &seqs, vec![0.5; cfg.n_layers], 0.02);
        assert_eq!(odp.l1_threshold.unwrap().len(), cfg.n_layers);
    }
}
