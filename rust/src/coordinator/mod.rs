//! L3 serving coordinator: the runtime system around the compressed
//! model — KV-cache decode (single-shot batched prefill + fused
//! multi-session stepping over `moe::exec`), continuous batching, a
//! threaded request server, the device memory model (Tab. 4/13/14),
//! and metrics.
//!
//! Rust owns the event loop and process topology; python exists only
//! at build time (DESIGN.md §3).

pub mod batcher;
pub mod decode;
pub mod engine;
pub mod memmodel;
pub mod metrics;
pub mod server;

pub use batcher::{Batcher, Request};
pub use decode::{step_many, DecodeOdp, DecodeSession};
pub use engine::McEngine;
pub use memmodel::{Platform, PLATFORMS};
pub use metrics::Metrics;
pub use server::Server;
