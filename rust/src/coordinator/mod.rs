//! L3 serving coordinator: the runtime system around the compressed
//! model — KV-cache decode (single-shot batched prefill + fused
//! multi-session stepping over `moe::exec`), continuous batching, a
//! threaded request server, the device memory model (Tab. 4/13/14),
//! and metrics.
//!
//! One request surface (`request::GenerateRequest` + `SamplingParams`
//! + `StopCondition`) feeds every path — `McEngine` (single request),
//! `Batcher` (fused continuous batching), `Server` (threaded) — with
//! all sampling in `sampling::Sampler` and per-token streaming +
//! cancellation over `RequestHandle` (DESIGN.md §3.1).
//!
//! Rust owns the event loop and process topology; python exists only
//! at build time (DESIGN.md §3).

pub mod batcher;
pub mod decode;
pub mod engine;
pub mod memgov;
pub mod memmodel;
pub mod metrics;
pub mod request;
pub mod sampling;
pub mod server;

pub use batcher::Batcher;
pub use decode::{
    step_many, step_many_into, DecodeOdp, DecodeSession, StepScratch,
};
pub use engine::McEngine;
pub use memgov::{
    MemGovConfig, MemReservation, MemoryGovernor, SessionGrant,
};
pub use memmodel::{Platform, PLATFORMS};
pub use metrics::Metrics;
pub use request::{
    Completion, FinishReason, GenerateRequest, Priority, RequestHandle,
    SamplingParams, StopCondition, StreamEvent,
};
pub use sampling::Sampler;
pub use server::{Server, ServerConfig};
