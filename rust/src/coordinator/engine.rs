//! McEngine: the compressed-model serving facade — scoring with ODP,
//! and single-request generation driven by the unified
//! `GenerateRequest`/`SamplingParams`/`StopCondition` surface (the
//! same types the batcher and server consume, sampled by the same
//! shared `Sampler`). This is what `mc-moe generate` and the examples
//! drive for one-off requests; batched serving goes through `Server`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::moe::model::{ForwardOpts, MoeModel, NullSink, OdpPolicy};
use crate::tensor::Mat;

use super::decode::{DecodeOdp, DecodeSession};
use super::memgov::{MemoryGovernor, SessionGrant};
use super::memmodel;
use super::metrics::Metrics;
use super::request::{Completion, FinishReason, GenerateRequest};
use super::sampling::Sampler;

pub struct McEngine {
    pub model: Arc<MoeModel>,
    /// scoring-time policy (full-sequence forward)
    pub odp: Option<OdpPolicy>,
    /// decode-time policy (KV-cache path)
    pub decode_odp: Option<DecodeOdp>,
    pub metrics: Arc<Metrics>,
    /// optional memory governor: when set, every request reserves its
    /// worst-case KV footprint up front (over-budget errors instead of
    /// OOM), attaches/publishes shared prompt prefixes, and ticks the
    /// pressure ladder (DESIGN.md §8)
    pub governor: Option<Arc<MemoryGovernor>>,
}

impl McEngine {
    pub fn new(model: MoeModel, odp: Option<OdpPolicy>,
               decode_odp: Option<DecodeOdp>) -> McEngine {
        // start the worker pool now so its spawn cost is paid at
        // construction, not inside the first request
        let _ = crate::util::pool::WorkerPool::global();
        // pin + announce the kernel dispatch table before any request
        // runs (one banner per process, DESIGN.md §4)
        let kops = crate::kernels::log_selection();
        // a cache-resolved model already records hit/miss/stall into
        // its own Metrics — adopt it so one snapshot covers everything
        let metrics = model
            .resolver
            .metrics()
            .unwrap_or_else(|| Arc::new(Metrics::new()));
        metrics.set_kernel_backend(kops.isa.name());
        McEngine {
            model: Arc::new(model),
            odp,
            decode_odp,
            metrics,
            governor: None,
        }
    }

    /// Attach a memory governor (built over this engine's metrics so
    /// its gauges land in the same snapshot).
    pub fn set_governor(&mut self, gov: Arc<MemoryGovernor>) {
        self.governor = Some(gov);
    }

    /// Full-sequence scoring logits (teacher-forced evaluation path).
    pub fn score(&self, tokens: &[u32]) -> Mat {
        let opts = ForwardOpts { odp: self.odp.as_ref(), ..Default::default() };
        let out = self.model.forward(tokens, &opts, &mut NullSink);
        Metrics::inc(&self.metrics.expert_calls, out.stats.expert_calls as u64);
        Metrics::inc(&self.metrics.experts_pruned,
                     out.stats.pruned_total() as u64);
        out.logits
    }

    /// Run one `GenerateRequest` to completion on the KV-cache decode
    /// path, streaming each produced token to `on_token` as it is
    /// sampled. Records TTFT (batched prefill + first logits) and
    /// per-token decode latency, so `tokens_per_sec()` /
    /// `mc_ttft_ms_mean` are live on the single-request path, not
    /// just under the batcher.
    pub fn generate_stream(
        &self,
        req: &GenerateRequest,
        mut on_token: impl FnMut(u32),
    ) -> Result<Completion> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        // memory admission: reserve the worst-case footprint before
        // any compute — over-budget is a clean error, never an OOM
        let grant: Option<Arc<SessionGrant>> =
            match (&req.grant, &self.governor) {
                (Some(g), _) => Some(g.clone()),
                (None, Some(gov)) => {
                    match gov.admit_session(&req.prompt, req.max_new_tokens) {
                        Ok(g) => Some(Arc::new(g)),
                        Err(needed) => anyhow::bail!(
                            "memory budget exceeded: session needs {needed} \
                             bytes (budget {})",
                            gov.budget_bytes()
                        ),
                    }
                }
                (None, None) => None,
            };
        Metrics::inc(&self.metrics.requests_admitted, 1);
        let mut sampler = Sampler::new(req.sampling.clone());
        let mut sess =
            DecodeSession::new(self.model.clone(), self.decode_odp.clone());
        if self.governor.is_some() {
            sess.enable_importance();
        }
        if let Some(p) = grant.as_ref().and_then(|g| g.prefix.clone()) {
            sess.attach_prefix(p);
        }
        let started = Instant::now();
        // one logits buffer for the whole request: after prefill the
        // decode loop reuses it (and the session's scratch arena), so
        // steady-state stepping allocates nothing. A granted shared
        // prefix already covers its rows: prefill only the remainder
        // (at least the final prompt token, so logits stay valid).
        let mut logits = Vec::new();
        let covered = sess.pos;
        {
            let _sp = crate::obs::span(crate::obs::Cat::Prefill, "prefill")
                .arg("tokens", (req.prompt.len() - covered) as u64)
                .arg("prefix_rows", covered as u64);
            sess.prefill_into(&req.prompt[covered..], &mut logits);
        }
        if let Some(gov) = &self.governor {
            let head = &req.prompt[..req.prompt.len() - 1];
            if grant.as_ref().map_or(true, |g| g.prefix.is_none())
                && gov.wants_prefix(head)
            {
                let (k, v, imp) = sess.export_prefix(head.len());
                gov.publish_prefix(head, k, v, imp);
            }
            gov.tick(&self.model);
        }
        let ttft_ns = started.elapsed().as_nanos() as u64;
        self.metrics.record_ttft(ttft_ns);
        let mut tokens = Vec::with_capacity(req.max_new_tokens);
        let mut finish = FinishReason::MaxTokens;
        while tokens.len() < req.max_new_tokens {
            let next = sampler.next_token(&logits);
            crate::obs::instant(crate::obs::Cat::Sample, "token_sampled",
                                crate::obs::args1("token", next as u64));
            tokens.push(next);
            on_token(next);
            if req.stop.hits(next) {
                finish = FinishReason::Stop(next);
                break;
            }
            if tokens.len() >= req.max_new_tokens || sess.remaining() == 0 {
                break;
            }
            // wall-clock budget check per token: the single-request
            // path has no batcher/watchdog, so the engine enforces
            // the deadline itself (partial tokens are still returned)
            if req.deadline.is_some_and(|d| started.elapsed() >= d) {
                finish = FinishReason::DeadlineExceeded;
                Metrics::inc(&self.metrics.deadline_exceeded, 1);
                crate::obs::instant(crate::obs::Cat::Decode,
                                    "deadline_expired_active",
                                    crate::obs::args1(
                                        "tokens", tokens.len() as u64));
                crate::obs::dump_now("deadline");
                break;
            }
            let t0 = Instant::now();
            {
                let _sp = crate::obs::span(crate::obs::Cat::Decode,
                                           "decode_step")
                    .arg("batch", 1);
                sess.step_into(next, &mut logits);
            }
            self.metrics.record_tpot(t0.elapsed().as_nanos() as u64);
        }
        Metrics::inc(&self.metrics.tokens_generated, tokens.len() as u64);
        Metrics::inc(&self.metrics.requests_completed, 1);
        Metrics::inc(&self.metrics.expert_calls,
                     sess.stats.expert_calls as u64);
        Metrics::inc(&self.metrics.experts_pruned,
                     sess.stats.pruned_total() as u64);
        // release this session's reservation, then let the ladder
        // disengage any rungs the freed bytes no longer justify
        drop(sess);
        drop(grant);
        if let Some(gov) = &self.governor {
            gov.tick(&self.model);
        }
        Ok(Completion {
            id: 0,
            tokens,
            finish,
            ttft_ns,
            total_ns: started.elapsed().as_nanos() as u64,
        })
    }

    /// `generate_stream` without a token callback.
    pub fn generate(&self, req: &GenerateRequest) -> Result<Completion> {
        self.generate_stream(req, |_| {})
    }

    /// One-line deployment summary (Tab. 4-style row). Budgeted
    /// models report resident (budget-capped) weight bytes alongside
    /// total model size.
    pub fn summary(&self) -> String {
        let load = memmodel::loading_bytes(&self.model);
        let act = memmodel::activated_bytes_per_token(&self.model, 1.0);
        let budget = match self.model.resolver.budget_bytes() {
            Some(b) => format!(
                " resident={:.3}GB (expert budget {:.1}MB)",
                memmodel::gb(memmodel::resident_weight_bytes(
                    &self.model, Some(b))),
                b as f64 / (1 << 20) as f64,
            ),
            None => String::new(),
        };
        format!(
            "model={} bits={:.2} load={:.3}GB act/token={:.3}MB odp={}{}",
            self.model.cfg.name,
            self.model.expert_avg_bits(),
            memmodel::gb(load),
            act / (1 << 20) as f64,
            self.odp.is_some(),
            budget,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::request::StopCondition;
    use crate::moe::model::tests::random_model;

    #[test]
    fn generate_terminates_and_counts() {
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 0), None, None);
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 8);
        let out = engine.generate(&req).unwrap();
        assert!(!out.tokens.is_empty() && out.tokens.len() <= 8);
        assert!(engine.metrics.tokens_generated.load(
            std::sync::atomic::Ordering::Relaxed) as usize
            == out.tokens.len());
        assert!(engine.summary().contains("model=test"));
    }

    #[test]
    fn generate_streams_tokens_in_order() {
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 3), None, None);
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 6);
        let mut streamed = Vec::new();
        let out = engine.generate_stream(&req, |t| streamed.push(t)).unwrap();
        assert_eq!(streamed, out.tokens);
    }

    #[test]
    fn generate_records_latency_metrics() {
        // single-request path must feed TTFT/TPOT (not just Batcher)
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 2), None, None);
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 6);
        let out = engine.generate(&req).unwrap();
        assert_eq!(engine.metrics.ttft_ns.lock().unwrap().len(), 1);
        if out.tokens.len() > 1 {
            // at least one decode step ran -> TPOT samples exist
            assert!(!engine.metrics.tpot_ns.lock().unwrap().is_empty());
            assert!(engine.metrics.tokens_per_sec() > 0.0);
        }
        assert!(engine.metrics.render_text().contains("mc_ttft_ms_mean"));
    }

    #[test]
    fn max_len_stop_ignores_eos() {
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 0), None, None);
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 8)
            .with_stop(StopCondition::MaxLen);
        let out = engine.generate(&req).unwrap();
        assert_eq!(out.tokens.len(), 8);
        assert_eq!(out.finish, FinishReason::MaxTokens);
    }

    #[test]
    fn deadline_caps_generation_with_partial_tokens() {
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 4), None, None);
        let req = GenerateRequest::greedy(vec![1, 5, 80, 3], 32)
            .with_stop(StopCondition::MaxLen)
            .with_deadline(std::time::Duration::ZERO);
        let out = engine.generate(&req).unwrap();
        assert_eq!(out.finish, FinishReason::DeadlineExceeded);
        // the first sampled token always lands before the clock check
        assert_eq!(out.tokens.len(), 1);
        assert_eq!(engine.metrics.deadline_exceeded.load(
            std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn score_records_pruning_metrics() {
        let cfg = ModelConfig::test_tiny();
        let policy = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let engine = McEngine::new(random_model(&cfg, 1), Some(policy), None);
        engine.score(&(1..17).collect::<Vec<u32>>());
        assert!(engine.metrics.prune_ratio() > 0.4);
    }
}
