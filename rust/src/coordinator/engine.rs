//! McEngine: the compressed-model serving facade — scoring with ODP,
//! greedy/sampled generation, and memory/throughput reporting. This is
//! what `mc-moe serve` and the examples drive.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::moe::model::{ForwardOpts, MoeModel, NullSink, OdpPolicy};
use crate::tensor::Mat;

use super::decode::{DecodeOdp, DecodeSession};
use super::memmodel;
use super::metrics::Metrics;

pub struct McEngine {
    pub model: Arc<MoeModel>,
    /// scoring-time policy (full-sequence forward)
    pub odp: Option<OdpPolicy>,
    /// decode-time policy (KV-cache path)
    pub decode_odp: Option<DecodeOdp>,
    pub metrics: Arc<Metrics>,
}

impl McEngine {
    pub fn new(model: MoeModel, odp: Option<OdpPolicy>,
               decode_odp: Option<DecodeOdp>) -> McEngine {
        McEngine {
            model: Arc::new(model),
            odp,
            decode_odp,
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Full-sequence scoring logits (teacher-forced evaluation path).
    pub fn score(&self, tokens: &[u32]) -> Mat {
        let opts = ForwardOpts { odp: self.odp.as_ref(), ..Default::default() };
        let out = self.model.forward(tokens, &opts, &mut NullSink);
        Metrics::inc(&self.metrics.expert_calls, out.stats.expert_calls as u64);
        Metrics::inc(&self.metrics.experts_pruned,
                     out.stats.pruned_total() as u64);
        out.logits
    }

    /// Greedy generation via the KV-cache decode path. Records TTFT
    /// (batched prefill + first logits) and per-token decode latency,
    /// so `tokens_per_sec()` / `mc_ttft_ms_mean` are live on the
    /// single-request path, not just under the batcher.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Result<Vec<u32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut sess =
            DecodeSession::new(self.model.clone(), self.decode_odp.clone());
        let started = Instant::now();
        let logits = sess.prefill(prompt);
        let mut out = Vec::with_capacity(max_new);
        let mut next = crate::util::stats::argmax(&logits) as u32;
        self.metrics.record_ttft(started.elapsed().as_nanos() as u64);
        for _ in 0..max_new {
            out.push(next);
            if next == crate::config::EOS || sess.remaining() == 0 {
                break;
            }
            let t0 = Instant::now();
            let logits = sess.step(next);
            self.metrics.record_tpot(t0.elapsed().as_nanos() as u64);
            next = crate::util::stats::argmax(&logits) as u32;
        }
        Metrics::inc(&self.metrics.tokens_generated, out.len() as u64);
        Metrics::inc(&self.metrics.expert_calls, sess.stats.expert_calls as u64);
        Metrics::inc(&self.metrics.experts_pruned,
                     sess.stats.pruned_total() as u64);
        Ok(out)
    }

    /// One-line deployment summary (Tab. 4-style row).
    pub fn summary(&self) -> String {
        let load = memmodel::loading_bytes(&self.model);
        let act = memmodel::activated_bytes_per_token(&self.model, 1.0);
        format!(
            "model={} bits={:.2} load={:.3}GB act/token={:.3}MB odp={}",
            self.model.cfg.name,
            self.model.expert_avg_bits(),
            memmodel::gb(load),
            act / (1 << 20) as f64,
            self.odp.is_some(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    #[test]
    fn generate_terminates_and_counts() {
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 0), None, None);
        let out = engine.generate(&[1, 5, 80, 3], 8).unwrap();
        assert!(!out.is_empty() && out.len() <= 8);
        assert!(engine.metrics.tokens_generated.load(
            std::sync::atomic::Ordering::Relaxed) as usize == out.len());
        assert!(engine.summary().contains("model=test"));
    }

    #[test]
    fn generate_records_latency_metrics() {
        // single-request path must feed TTFT/TPOT (not just Batcher)
        let cfg = ModelConfig::test_tiny();
        let engine = McEngine::new(random_model(&cfg, 2), None, None);
        let out = engine.generate(&[1, 5, 80, 3], 6).unwrap();
        assert_eq!(engine.metrics.ttft_ns.lock().unwrap().len(), 1);
        if out.len() > 1 {
            // at least one decode step ran -> TPOT samples exist
            assert!(!engine.metrics.tpot_ns.lock().unwrap().is_empty());
            assert!(engine.metrics.tokens_per_sec() > 0.0);
        }
        assert!(engine.metrics.render_text().contains("mc_ttft_ms_mean"));
    }

    #[test]
    fn score_records_pruning_metrics() {
        let cfg = ModelConfig::test_tiny();
        let policy = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let engine = McEngine::new(random_model(&cfg, 1), Some(policy), None);
        engine.score(&(1..17).collect::<Vec<u32>>());
        assert!(engine.metrics.prune_ratio() > 0.4);
    }
}
