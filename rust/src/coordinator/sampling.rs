//! The one sampler. Every serving path (engine single-request decode,
//! fused batcher, server) turns logits into tokens through `Sampler`,
//! so greedy/temperature/top-k/top-p semantics cannot drift between
//! paths — same `SamplingParams` + same seed + same logits = same
//! tokens, regardless of which path ran them.
//!
//! Sampling is Gumbel-max over the temperature-scaled logits after
//! top-k / top-p truncation: argmax_i (l_i/T + g_i) with g_i standard
//! Gumbel noise from a per-request splitmix64 stream keyed by an LCG
//! chain off the request seed (one chain step per emitted token).

use crate::util::rng::{lcg_next, Rng};
use crate::util::stats::argmax;

use super::request::SamplingParams;

#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    rng_state: u64,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        let rng_state = params.seed;
        Sampler { params, rng_state }
    }

    pub fn greedy() -> Sampler {
        Sampler::new(SamplingParams::greedy())
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Pick the next token from `logits`, advancing the RNG stream iff
    /// the params call for sampling.
    pub fn next_token(&mut self, logits: &[f32]) -> u32 {
        if self.params.is_greedy() {
            return argmax(logits) as u32;
        }
        let temp = self.params.temperature;
        let scaled: Vec<f32> = logits.iter().map(|l| l / temp).collect();
        self.rng_state = lcg_next(self.rng_state);
        let mut rng = Rng::new(self.rng_state);
        let k = self.params.top_k;
        let p = self.params.top_p;
        if (k == 0 || k >= scaled.len()) && p >= 1.0 {
            // no truncation: no sort, no index Vec on the hot path
            return gumbel_pick(&mut rng, &scaled, 0..scaled.len()) as u32;
        }
        let allowed = self.allowed_indices(&scaled);
        gumbel_pick(&mut rng, &scaled, allowed.iter().copied()) as u32
    }

    /// Indices surviving top-k then top-p truncation of the scaled
    /// logits, in ascending index order (never empty: the argmax
    /// always survives both filters).
    fn allowed_indices(&self, scaled: &[f32]) -> Vec<usize> {
        let k = self.params.top_k;
        let p = self.params.top_p;
        let mut order: Vec<usize> = (0..scaled.len()).collect();
        order.sort_by(|&a, &b| {
            scaled[b].partial_cmp(&scaled[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        if k > 0 && k < order.len() {
            order.truncate(k);
        }
        if p < 1.0 {
            // softmax over the (already top-k-truncated) candidates
            let m = scaled[order[0]];
            let exps: Vec<f64> = order
                .iter()
                .map(|&i| ((scaled[i] - m) as f64).exp())
                .collect();
            let z: f64 = exps.iter().sum();
            let mut cum = 0.0;
            let mut keep = order.len();
            for (rank, e) in exps.iter().enumerate() {
                cum += e / z;
                if cum >= p as f64 {
                    keep = rank + 1;
                    break;
                }
            }
            order.truncate(keep.max(1));
        }
        order.sort_unstable();
        order
    }
}

/// Gumbel-max over `scaled` restricted to `idxs` (ascending index
/// order keeps the per-candidate draw sequence deterministic).
fn gumbel_pick(rng: &mut Rng, scaled: &[f32],
               idxs: impl IntoIterator<Item = usize>) -> usize {
    let mut best: Option<(usize, f32)> = None;
    for i in idxs {
        let g = -(-(rng.f64().max(1e-12).ln())).ln() as f32;
        let v = scaled[i] + g;
        let better = match best {
            None => true,
            Some((_, bv)) => v > bv,
        };
        if better {
            best = Some((i, v));
        }
    }
    best.expect("non-empty candidate set").0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.0, -1.0, 1.5, 0.0, -3.0, 0.7, 1.0]
    }

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.next_token(&logits()), 1);
        // greedy never advances RNG: repeated calls identical
        assert_eq!(s.next_token(&logits()), 1);
    }

    #[test]
    fn same_seed_same_stream() {
        let p = SamplingParams::temperature(1.3, 42);
        let mut a = Sampler::new(p.clone());
        let mut b = Sampler::new(p);
        for _ in 0..20 {
            assert_eq!(a.next_token(&logits()), b.next_token(&logits()));
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Sampler::new(SamplingParams::temperature(5.0, 1));
        let mut b = Sampler::new(SamplingParams::temperature(5.0, 2));
        let sa: Vec<u32> = (0..32).map(|_| a.next_token(&logits())).collect();
        let sb: Vec<u32> = (0..32).map(|_| b.next_token(&logits())).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn top_k_restricts_support() {
        let p = SamplingParams {
            temperature: 10.0, // near-uniform without truncation
            top_k: 2,
            ..SamplingParams::temperature(10.0, 7)
        };
        let mut s = Sampler::new(p);
        for _ in 0..64 {
            let t = s.next_token(&logits());
            assert!(t == 1 || t == 3, "top-2 support is {{1,3}}, got {t}");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // one dominant logit: tiny p keeps only the argmax
        let p = SamplingParams {
            temperature: 1.0,
            top_p: 0.1,
            ..SamplingParams::temperature(1.0, 9)
        };
        let mut s = Sampler::new(p);
        let sharp = vec![0.0, 10.0, 0.0, 0.0];
        for _ in 0..32 {
            assert_eq!(s.next_token(&sharp), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut s = Sampler::new(SamplingParams::temperature(50.0, 11));
        let seen: std::collections::BTreeSet<u32> =
            (0..200).map(|_| s.next_token(&logits())).collect();
        assert!(seen.len() > 3, "hot sampling should visit many tokens");
    }
}
