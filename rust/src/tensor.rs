//! Dense f32 tensor substrate for the native engine.
//!
//! `matmul_into` is a register-blocked tiled kernel: output rows are
//! processed in blocks of 4 so each `w` panel row is loaded once per
//! block instead of once per row, and the K loop is unrolled by 4 so
//! the inner axpy carries 4 independent FMA streams (EXPERIMENTS.md
//! §Perf). The axpy primitives themselves are dispatched through the
//! runtime-selected [`crate::kernels`] backend (scalar/AVX2/AVX-512/
//! NEON); `*_ops` variants take the table explicitly so tests and
//! benches can pin a backend. Large GEMMs additionally split their
//! output columns into strips across the persistent `WorkerPool` —
//! column partitioning never changes any element's accumulation order,
//! so pooled and serial results are bit-identical on any one backend.
//! The pre-tiling scalar "ikj" kernel is kept as [`matmul_into_naive`]:
//! it is the parity reference for the kernel test suite and the
//! baseline `benches/hotpath.rs` measures the tiled kernel against.
//!
//! Backing storage is [`AVec`], 64-byte aligned so SIMD row loads
//! never split cache lines. The `*_into` variants write into
//! caller-owned buffers so the decode hot path runs allocation-free
//! (DESIGN.md §4 scratch rules).

use std::fmt;

use crate::kernels::{self, KernelOps};
use crate::util::alloc::{AVec, BUF_ALIGN};
use crate::util::pool::{SendPtr, WorkerPool};
use crate::util::rng::Rng;

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: AVec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: AVec::zeroed(rows * cols) }
    }

    pub fn from_vec(
        rows: usize,
        cols: usize,
        data: impl Into<AVec<f32>>,
    ) -> Mat {
        let data = data.into();
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Mat { rows, cols, data }
    }

    /// Reshape to `[rows, cols]`, reusing the existing allocation when
    /// capacity allows (the scratch-buffer contract: steady-state
    /// shapes never reallocate). Contents are unspecified.
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let cols = self.cols;
        &mut self.data[r * cols..(r + 1) * cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols;
        self.data[r * cols + c] = v;
    }

    /// y = self @ w  (self: [M,K], w: [K,N])
    pub fn matmul(&self, w: &Mat) -> Mat {
        let mut y = Mat::zeros(self.rows, w.cols);
        matmul_into(self, w, &mut y);
        y
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn slice_rows(&self, start: usize, end: usize) -> Mat {
        assert!(start <= end && end <= self.rows);
        Mat {
            rows: end - start,
            cols: self.cols,
            data: AVec::from(&self.data[start * self.cols..end * self.cols]),
        }
    }
}

/// FLOP volume below which a GEMM is not worth a pool region.
const GEMM_PAR_MIN_FLOPS: usize = 2_000_000;
/// Minimum output-column strip width per pool task.
const GEMM_MIN_STRIP: usize = 32;

/// y += x @ w, accumulating into a pre-zeroed (or pre-filled) buffer.
/// Tiled kernel; auto-parallelized over column strips for large
/// shapes. Bit-identical to `matmul_into_with(.., None)`.
pub fn matmul_into(x: &Mat, w: &Mat, y: &mut Mat) {
    let pool = WorkerPool::global();
    let flops = 2 * x.rows * x.cols * w.cols;
    let p = if flops >= GEMM_PAR_MIN_FLOPS
        && pool.width() > 1
        && !WorkerPool::on_worker()
    {
        Some(pool)
    } else {
        None
    };
    matmul_into_with(x, w, y, p);
}

/// y += x @ w with an explicit pool choice (None = serial), on the
/// process-wide kernel backend.
pub fn matmul_into_with(x: &Mat, w: &Mat, y: &mut Mat, pool: Option<&WorkerPool>) {
    matmul_into_ops(x, w, y, pool, kernels::active());
}

/// y += x @ w on an explicit kernel table. Pooled and serial
/// execution are bit-identical on any one backend: strips partition
/// output columns, and each element's K-accumulation order is
/// unchanged.
pub fn matmul_into_ops(
    x: &Mat,
    w: &Mat,
    y: &mut Mat,
    pool: Option<&WorkerPool>,
    ops: &'static KernelOps,
) {
    assert_eq!(x.cols, w.rows, "matmul inner dim");
    assert_eq!((y.rows, y.cols), (x.rows, w.cols), "matmul out dims");
    debug_assert_eq!(x.data.as_ptr() as usize % BUF_ALIGN, 0);
    debug_assert_eq!(w.data.as_ptr() as usize % BUF_ALIGN, 0);
    debug_assert_eq!(y.data.as_ptr() as usize % BUF_ALIGN, 0);
    let n = w.cols;
    if let Some(p) = pool {
        let tasks = p.width().min(n / GEMM_MIN_STRIP);
        if tasks >= 2 && !WorkerPool::on_worker() {
            let ybase = SendPtr(y.data.as_mut_ptr());
            p.for_each(tasks, move |t| {
                let (c0, c1) = WorkerPool::strip(n, tasks, t);
                // Safety: strips are disjoint column ranges of y.
                unsafe { matmul_cols(x, w, ybase.0, c0, c1, ops) };
            });
            return;
        }
    }
    // Safety: exclusive access to all of y.
    unsafe { matmul_cols(x, w, y.data.as_mut_ptr(), 0, n, ops) };
}

/// Tiled kernel over output columns [c0, c1): 4-row output blocks
/// reuse each `w` panel, K unrolled by 4, no per-element zero test
/// (dense path). Caller guarantees `ybase` points at a row-major
/// [x.rows, w.cols] buffer and concurrent calls use disjoint column
/// ranges.
unsafe fn matmul_cols(
    x: &Mat,
    w: &Mat,
    ybase: *mut f32,
    c0: usize,
    c1: usize,
    ops: &'static KernelOps,
) {
    let n = w.cols;
    let kk = x.cols;
    let cw = c1 - c0;
    if cw == 0 {
        return;
    }
    let mut i = 0;
    while i + 4 <= x.rows {
        let y0 = std::slice::from_raw_parts_mut(ybase.add(i * n + c0), cw);
        let y1 = std::slice::from_raw_parts_mut(ybase.add((i + 1) * n + c0), cw);
        let y2 = std::slice::from_raw_parts_mut(ybase.add((i + 2) * n + c0), cw);
        let y3 = std::slice::from_raw_parts_mut(ybase.add((i + 3) * n + c0), cw);
        let (x0, x1, x2, x3) =
            (x.row(i), x.row(i + 1), x.row(i + 2), x.row(i + 3));
        let mut k = 0;
        while k + 4 <= kk {
            let w0 = &w.row(k)[c0..c1];
            let w1 = &w.row(k + 1)[c0..c1];
            let w2 = &w.row(k + 2)[c0..c1];
            let w3 = &w.row(k + 3)[c0..c1];
            (ops.axpy4)(y0, w0, w1, w2, w3,
                        [x0[k], x0[k + 1], x0[k + 2], x0[k + 3]]);
            (ops.axpy4)(y1, w0, w1, w2, w3,
                        [x1[k], x1[k + 1], x1[k + 2], x1[k + 3]]);
            (ops.axpy4)(y2, w0, w1, w2, w3,
                        [x2[k], x2[k + 1], x2[k + 2], x2[k + 3]]);
            (ops.axpy4)(y3, w0, w1, w2, w3,
                        [x3[k], x3[k + 1], x3[k + 2], x3[k + 3]]);
            k += 4;
        }
        while k < kk {
            let wr = &w.row(k)[c0..c1];
            (ops.axpy)(y0, wr, x0[k]);
            (ops.axpy)(y1, wr, x1[k]);
            (ops.axpy)(y2, wr, x2[k]);
            (ops.axpy)(y3, wr, x3[k]);
            k += 1;
        }
        i += 4;
    }
    while i < x.rows {
        let y0 = std::slice::from_raw_parts_mut(ybase.add(i * n + c0), cw);
        let x0 = x.row(i);
        let mut k = 0;
        while k + 4 <= kk {
            (ops.axpy4)(
                y0,
                &w.row(k)[c0..c1],
                &w.row(k + 1)[c0..c1],
                &w.row(k + 2)[c0..c1],
                &w.row(k + 3)[c0..c1],
                [x0[k], x0[k + 1], x0[k + 2], x0[k + 3]],
            );
            k += 4;
        }
        while k < kk {
            (ops.axpy)(y0, &w.row(k)[c0..c1], x0[k]);
            k += 1;
        }
        i += 1;
    }
}

/// Scatter/accumulate primitive on the active backend (used by
/// `moe::exec::dispatch` for the weighted expert merge).
#[inline]
pub(crate) fn axpy(y: &mut [f32], w: &[f32], a: f32) {
    (kernels::active().axpy)(y, w, a)
}

/// The pre-tiling scalar "ikj" kernel (with its sparse-activation
/// skip), kept verbatim as the parity reference for
/// `tests/kernel_parity.rs` and the baseline `benches/hotpath.rs`
/// reports speedups against.
pub fn matmul_into_naive(x: &Mat, w: &Mat, y: &mut Mat) {
    assert_eq!(x.cols, w.rows, "matmul inner dim");
    assert_eq!((y.rows, y.cols), (x.rows, w.cols), "matmul out dims");
    let n = w.cols;
    for i in 0..x.rows {
        let xrow = x.row(i);
        let yrow = &mut y.data[i * n..(i + 1) * n];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w.data[k * n..(k + 1) * n];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
}

/// y = x @ w into a reused scratch Mat (resized + zeroed first).
pub fn matmul_reset_into(x: &Mat, w: &Mat, y: &mut Mat) {
    y.resize_to(x.rows, w.cols);
    y.data.fill(0.0);
    matmul_into(x, w, y);
}

/// y[n] = x[k] @ w[k, n] for a single activation row (the decode
/// logits path: only the last position's logits are needed).
pub fn vecmat_into(x: &[f32], w: &Mat, y: &mut Vec<f32>) {
    vecmat_into_ops(x, w, y, kernels::active());
}

/// [`vecmat_into`] on an explicit kernel table.
pub fn vecmat_into_ops(
    x: &[f32],
    w: &Mat,
    y: &mut Vec<f32>,
    ops: &'static KernelOps,
) {
    assert_eq!(x.len(), w.rows, "vecmat inner dim");
    y.clear();
    y.resize(w.cols, 0.0);
    let yrow = y.as_mut_slice();
    let mut k = 0;
    while k + 4 <= x.len() {
        (ops.axpy4)(
            yrow,
            w.row(k),
            w.row(k + 1),
            w.row(k + 2),
            w.row(k + 3),
            [x[k], x[k + 1], x[k + 2], x[k + 3]],
        );
        k += 4;
    }
    while k < x.len() {
        (ops.axpy)(yrow, w.row(k), x[k]);
        k += 1;
    }
}

/// y[m] += x[m] (elementwise over equal-shaped matrices)
pub fn add_inplace(y: &mut Mat, x: &Mat) {
    assert_eq!((y.rows, y.cols), (x.rows, x.cols));
    for (a, b) in y.data.iter_mut().zip(&x.data) {
        *a += b;
    }
}

/// RMSNorm over the last dim with learned gain, eps matching the jax ref.
pub fn rmsnorm(x: &Mat, weight: &[f32], eps: f32) -> Mat {
    let mut y = Mat::zeros(x.rows, x.cols);
    rmsnorm_into(x, weight, eps, &mut y);
    y
}

/// RMSNorm into a reused scratch Mat (resized; fully overwritten).
pub fn rmsnorm_into(x: &Mat, weight: &[f32], eps: f32, y: &mut Mat) {
    assert_eq!(x.cols, weight.len());
    y.resize_to(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let yrow = &mut y.data[r * x.cols..(r + 1) * x.cols];
        for ((yv, &v), &w) in yrow.iter_mut().zip(row).zip(weight) {
            *yv = v * inv * w;
        }
    }
}

/// Numerically-stable in-place softmax over each row.
pub fn softmax_rows(x: &mut Mat) {
    softmax_rows_ops(x, kernels::active());
}

/// [`softmax_rows`] on an explicit kernel table. The max and the
/// final normalization run in SIMD lanes; both are exact operations,
/// so softmax is bit-identical across backends.
pub fn softmax_rows_ops(x: &mut Mat, ops: &'static KernelOps) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let m = (ops.vmax)(row);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        (ops.vscale)(row, 1.0 / sum);
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax of one row (for log-likelihood scoring)
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    log_softmax_into(row, &mut out);
    out
}

/// log-softmax into a reused buffer: scoring loops call this once per
/// position, so the eval paths stop allocating a fresh Vec per token.
pub fn log_softmax_into(row: &[f32], out: &mut Vec<f32>) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    out.clear();
    out.extend(row.iter().map(|v| v - lse));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let y = a.matmul(&b);
        assert_eq!(y.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(&mut rng, 5, 7, 1.0);
        let mut eye = Mat::zeros(7, 7);
        for i in 0..7 {
            eye.set(i, i, 1.0);
        }
        let y = a.matmul(&eye);
        for (x, y) in a.data.iter().zip(&y.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn mat_backing_is_64_byte_aligned() {
        for m in [Mat::zeros(3, 5), Mat::from_vec(1, 3, vec![1., 2., 3.])] {
            assert_eq!(m.data.as_ptr() as usize % BUF_ALIGN, 0);
        }
        let mut rng = Rng::new(3);
        let m = Mat::randn(&mut rng, 9, 17, 1.0);
        assert_eq!(m.data.as_ptr() as usize % BUF_ALIGN, 0);
        assert_eq!(m.slice_rows(2, 5).data.as_ptr() as usize % BUF_ALIGN, 0);
    }

    #[test]
    fn tiled_matches_naive_reference() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[
            (1usize, 7usize, 5usize),
            (3, 17, 9),
            (4, 32, 33),
            (5, 50, 31),
            (9, 65, 66),
        ] {
            let x = Mat::randn(&mut rng, m, k, 1.0);
            let w = Mat::randn(&mut rng, k, n, 1.0);
            let mut tiled = Mat::zeros(m, n);
            matmul_into_with(&x, &w, &mut tiled, None);
            let mut naive = Mat::zeros(m, n);
            matmul_into_naive(&x, &w, &mut naive);
            for (a, b) in tiled.data.iter().zip(&naive.data) {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "({m},{k},{n}): {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn pooled_strips_bit_match_serial() {
        let mut rng = Rng::new(8);
        let pool = WorkerPool::global();
        let (m, k, n) = (7, 33, 130);
        let x = Mat::randn(&mut rng, m, k, 1.0);
        let w = Mat::randn(&mut rng, k, n, 1.0);
        let mut serial = Mat::zeros(m, n);
        matmul_into_with(&x, &w, &mut serial, None);
        let mut pooled = Mat::zeros(m, n);
        matmul_into_with(&x, &w, &mut pooled, Some(pool));
        assert_eq!(serial.data, pooled.data, "pool must be bit-exact");
    }

    #[test]
    fn matmul_accumulates_into_prefilled() {
        let mut rng = Rng::new(9);
        let x = Mat::randn(&mut rng, 3, 8, 1.0);
        let w = Mat::randn(&mut rng, 8, 6, 1.0);
        let mut y = Mat::from_vec(3, 6, vec![1.0; 18]);
        matmul_into(&x, &w, &mut y);
        let base = x.matmul(&w);
        for (a, b) in y.data.iter().zip(&base.data) {
            assert!((a - (b + 1.0)).abs() < 1e-4);
        }
    }

    #[test]
    fn vecmat_matches_matmul_row() {
        let mut rng = Rng::new(10);
        let x = Mat::randn(&mut rng, 1, 37, 1.0);
        let w = Mat::randn(&mut rng, 37, 23, 1.0);
        let full = x.matmul(&w);
        let mut y = Vec::new();
        vecmat_into(x.row(0), &w, &mut y);
        for (a, b) in y.iter().zip(full.row(0)) {
            assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn resize_keeps_capacity() {
        let mut m = Mat::zeros(8, 8);
        let ptr = m.data.as_ptr();
        m.resize_to(2, 3);
        assert_eq!((m.rows, m.cols, m.data.len()), (2, 3, 6));
        m.resize_to(8, 8);
        assert_eq!(m.data.as_ptr(), ptr, "shrink+regrow must not realloc");
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 3, 5, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -5.0, 0.0, 5.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // monotone: larger logits -> larger probs
        assert!(m.at(0, 2) > m.at(0, 1) && m.at(0, 1) > m.at(0, 0));
    }

    #[test]
    fn softmax_extreme_logits_stable() {
        let mut m = Mat::from_vec(1, 3, vec![1e30, -1e30, 0.0]);
        softmax_rows(&mut m);
        assert!((m.at(0, 0) - 1.0).abs() < 1e-6);
        assert!(m.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Mat::from_vec(1, 4, vec![2.0, 2.0, 2.0, 2.0]);
        let y = rmsnorm(&x, &[1.0; 4], 1e-5);
        for v in &y.data {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn log_softmax_sums_to_one() {
        let l = log_softmax(&[0.5, 1.5, -0.5]);
        let s: f32 = l.iter().map(|v| v.exp()).sum();
        assert!((s - 1.0).abs() < 1e-5);
        // into-variant reuses the buffer without reallocating
        let mut buf = l.clone();
        let ptr = buf.as_ptr();
        log_softmax_into(&[1.0, 0.0, -1.0], &mut buf);
        assert_eq!(buf.as_ptr(), ptr);
        let s: f32 = buf.iter().map(|v| v.exp()).sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn fro_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn slice_rows_content() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = a.slice_rows(1, 3);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
    }
}
