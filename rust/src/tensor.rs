//! Dense f32 tensor substrate for the native engine.
//!
//! Row-major matrices with the cache-friendly "ikj" matmul (the inner
//! loop runs contiguously over the output row, which LLVM auto-
//! vectorizes). This is the baseline the packed-quantized hot path in
//! `quant::qmatmul` is benchmarked against (EXPERIMENTS.md §Perf).

use std::fmt;

use crate::util::rng::Rng;

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// y = self @ w  (self: [M,K], w: [K,N])
    pub fn matmul(&self, w: &Mat) -> Mat {
        let mut y = Mat::zeros(self.rows, w.cols);
        matmul_into(self, w, &mut y);
        y
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn slice_rows(&self, start: usize, end: usize) -> Mat {
        assert!(start <= end && end <= self.rows);
        Mat {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }
}

/// y = x @ w, accumulating into a pre-zeroed (or pre-filled) buffer.
/// "ikj" order: the inner loop is a contiguous axpy over the out row.
pub fn matmul_into(x: &Mat, w: &Mat, y: &mut Mat) {
    assert_eq!(x.cols, w.rows, "matmul inner dim");
    assert_eq!((y.rows, y.cols), (x.rows, w.cols), "matmul out dims");
    let n = w.cols;
    for i in 0..x.rows {
        let xrow = x.row(i);
        let yrow = &mut y.data[i * n..(i + 1) * n];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // dense-mixing weights are often sparse
            }
            let wrow = &w.data[k * n..(k + 1) * n];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    }
}

/// y[m] += x[m] (elementwise over equal-shaped matrices)
pub fn add_inplace(y: &mut Mat, x: &Mat) {
    assert_eq!((y.rows, y.cols), (x.rows, x.cols));
    for (a, b) in y.data.iter_mut().zip(&x.data) {
        *a += b;
    }
}

/// RMSNorm over the last dim with learned gain, eps matching the jax ref.
pub fn rmsnorm(x: &Mat, weight: &[f32], eps: f32) -> Mat {
    assert_eq!(x.cols, weight.len());
    let mut y = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (c, (&v, &w)) in row.iter().zip(weight).enumerate() {
            y.data[r * x.cols + c] = v * inv * w;
        }
    }
    y
}

/// Numerically-stable in-place softmax over each row.
pub fn softmax_rows(x: &mut Mat) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax of one row (for log-likelihood scoring)
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    row.iter().map(|v| v - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let y = a.matmul(&b);
        assert_eq!(y.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(&mut rng, 5, 7, 1.0);
        let mut eye = Mat::zeros(7, 7);
        for i in 0..7 {
            eye.set(i, i, 1.0);
        }
        let y = a.matmul(&eye);
        for (x, y) in a.data.iter().zip(&y.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 3, 5, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -5.0, 0.0, 5.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // monotone: larger logits -> larger probs
        assert!(m.at(0, 2) > m.at(0, 1) && m.at(0, 1) > m.at(0, 0));
    }

    #[test]
    fn softmax_extreme_logits_stable() {
        let mut m = Mat::from_vec(1, 3, vec![1e30, -1e30, 0.0]);
        softmax_rows(&mut m);
        assert!((m.at(0, 0) - 1.0).abs() < 1e-6);
        assert!(m.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Mat::from_vec(1, 4, vec![2.0, 2.0, 2.0, 2.0]);
        let y = rmsnorm(&x, &[1.0; 4], 1e-5);
        for v in &y.data {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn log_softmax_sums_to_one() {
        let l = log_softmax(&[0.5, 1.5, -0.5]);
        let s: f32 = l.iter().map(|v| v.exp()).sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn fro_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn slice_rows_content() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = a.slice_rows(1, 3);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
    }
}
