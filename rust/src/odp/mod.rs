//! ODP — Online Dynamic Pruning (paper Sec. 3.3).
//!
//! The pruning *decisions* execute inside the engine's routing loop
//! (`moe::model`, enum `OdpPolicy`); this module owns policy
//! construction and calibration:
//!   * the per-layer threshold μ = median of w1/w0 over calibration
//!     data (Eq. 5, following Lu et al. 2024),
//!   * the significance-aware token-protection configuration (Sec.
//!     3.3.2, default 2% — Fig. 7's sweet spot),
//!   * the Tab.-11 token-statistic baselines and Tab.-12 manual
//!     thresholds.

use crate::moe::model::{OdpPolicy, TokenMetric};
use crate::pmq::calibrate::Calibration;

/// Paper default: protect the top 2% of tokens by Eq.-6 importance.
pub const DEFAULT_PROTECT_RATIO: f32 = 0.02;

/// Weight-only dynamic pruning (Lu et al. 2024): μ = per-layer median.
pub fn weight_only(cal: &Calibration) -> OdpPolicy {
    OdpPolicy::WeightOnly { mu: cal.mu_median() }
}

/// The paper's ODP: median threshold + token protection.
pub fn odp(cal: &Calibration, protect_ratio: f32) -> OdpPolicy {
    OdpPolicy::Protected { mu: cal.mu_median(), protect_ratio }
}

/// ODP with the paper default 2% protection.
pub fn odp_default(cal: &Calibration) -> OdpPolicy {
    odp(cal, DEFAULT_PROTECT_RATIO)
}

/// Fig.-8 mode: ODP + drop all experts of the bottom `drop_ratio`
/// tokens.
pub fn odp_drop_all(cal: &Calibration, protect_ratio: f32,
                    drop_ratio: f32) -> OdpPolicy {
    OdpPolicy::ProtectedDropAll {
        mu: cal.mu_median(),
        protect_ratio,
        drop_ratio,
    }
}

/// Tab.-12 manual threshold ablation: a single μ for all layers.
pub fn manual_threshold(n_layers: usize, mu: f32,
                        protect_ratio: Option<f32>) -> OdpPolicy {
    let mu = vec![mu; n_layers];
    match protect_ratio {
        Some(p) => OdpPolicy::Protected { mu, protect_ratio: p },
        None => OdpPolicy::WeightOnly { mu },
    }
}

/// Tab.-11 baselines: prune the secondary expert of the bottom
/// `prune_frac` tokens ranked by a token statistic.
pub fn token_metric(metric: TokenMetric, prune_frac: f32) -> OdpPolicy {
    OdpPolicy::TokenMetric { metric, prune_frac }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{calibration_set, Split};
    use crate::moe::model::tests::random_model;
    use crate::moe::model::{ForwardOpts, NullSink};
    use crate::pmq::calibrate::calibrate;

    fn setup() -> (ModelConfig, crate::moe::MoeModel, Calibration) {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let seqs = calibration_set(11, 3, 32, Split::General);
        let cal = calibrate(&model, &seqs);
        (cfg, model, cal)
    }

    #[test]
    fn median_threshold_prunes_about_half() {
        // μ = median of ratio distribution => ~50% of tokens pruned
        // (on-distribution), i.e. CR ≈ 25% of expert compute with k=2
        let (_cfg, model, cal) = setup();
        let policy = weight_only(&cal);
        let seqs = calibration_set(12, 3, 32, Split::General);
        let mut pruned = 0usize;
        let mut possible = 0usize;
        for s in &seqs {
            let out = model.forward(
                s,
                &ForwardOpts { odp: Some(&policy), ..Default::default() },
                &mut NullSink,
            );
            pruned += out.stats.dropped_secondary;
            possible += out.stats.expert_possible / 2; // per-token count
        }
        let frac = pruned as f64 / possible as f64;
        assert!((0.3..0.7).contains(&frac), "pruned fraction {frac}");
    }

    #[test]
    fn protection_reduces_pruning_monotonically() {
        let (_cfg, model, cal) = setup();
        let seqs = calibration_set(13, 2, 32, Split::General);
        let mut last = usize::MAX;
        for ratio in [0.0f32, 0.1, 0.3, 0.6] {
            let policy = odp(&cal, ratio);
            let mut pruned = 0;
            for s in &seqs {
                let out = model.forward(
                    s,
                    &ForwardOpts { odp: Some(&policy), ..Default::default() },
                    &mut NullSink,
                );
                pruned += out.stats.dropped_secondary;
            }
            assert!(pruned <= last, "ratio {ratio}: {pruned} > {last}");
            last = pruned;
        }
    }

    #[test]
    fn higher_threshold_prunes_more() {
        // Tab. 12's monotonicity: larger μ => more pruned params
        let (cfg, model, cal) = setup();
        let seqs = calibration_set(14, 2, 32, Split::General);
        let mut last = 0usize;
        for mu in [0.2f32, 0.5, 0.9] {
            let policy = manual_threshold(cfg.n_layers, mu, None);
            let mut pruned = 0;
            for s in &seqs {
                let out = model.forward(
                    s,
                    &ForwardOpts { odp: Some(&policy), ..Default::default() },
                    &mut NullSink,
                );
                pruned += out.stats.dropped_secondary;
            }
            assert!(pruned >= last, "mu {mu}: {pruned} < {last}");
            last = pruned;
        }
        let _ = cal;
    }

    #[test]
    fn token_metric_prunes_requested_fraction() {
        let (cfg, model, _cal) = setup();
        let policy = token_metric(TokenMetric::Variance, 0.3);
        let toks: Vec<u32> = (1..41).collect();
        let out = model.forward(
            &toks,
            &ForwardOpts { odp: Some(&policy), ..Default::default() },
            &mut NullSink,
        );
        let expect = (40.0f32 * 0.3).round() as usize * cfg.n_layers;
        assert_eq!(out.stats.dropped_secondary, expect);
    }

    #[test]
    fn all_metrics_run() {
        let (_cfg, model, _cal) = setup();
        let toks: Vec<u32> = (1..33).collect();
        for metric in [
            TokenMetric::Eq6Importance,
            TokenMetric::Kurtosis,
            TokenMetric::Variance,
            TokenMetric::MeanAbs,
        ] {
            let policy = token_metric(metric, 0.3);
            let out = model.forward(
                &toks,
                &ForwardOpts { odp: Some(&policy), ..Default::default() },
                &mut NullSink,
            );
            assert!(out.logits.data.iter().all(|v| v.is_finite()));
            assert!(out.stats.dropped_secondary > 0);
        }
    }
}
