//! mc-moe CLI: compress, evaluate, analyze, and serve MC-compressed
//! MoE models.
//!
//! Subcommands:
//!   info                         model/artifact status
//!   compress  [--avg-bits 2.5] [--strategy pmq] [--eval] [--save m.mcqz]
//!   eval      [--mode suite|ppl|fewshot|niah|cot] [--odp] [--avg-bits ...]
//!             [--load m.mcqz] [--expert-budget-mb 8] [--prefetch async]
//!   serve     [--port 8080] [--host 127.0.0.1] [--batch 4]
//!             [--max-conns 256] [--max-streams-per-tenant 32]
//!             [--shed-queue-depth 64] [--timeout-ms 0] [--odp]
//!             [--load m.mcqz] [--expert-budget-mb 8]
//!             [--mem-budget-mb 0] [--prefetch off|sync|async]
//!             (no --port: legacy in-process synthetic load,
//!              [--requests 16] [--max-new 24])
//!   generate  [--task 3] [--max-new 16] [--timeout-ms 0] [--odp]
//!             [--load m.mcqz]
//!             [--temperature 0.8] [--top-k 0] [--top-p 1.0] [--seed 5]
//!             [--expert-budget-mb 8] [--prefetch off|sync|async]
//!   expert-analysis [--out file.json]     (Fig. 3 / Fig. 10 data)
//!
//! `serve` and `generate` accept `--load <model.mcqz>` (a compressed
//! model saved by `compress --save`), so the MC-compressed model can
//! be served end-to-end, matching `eval --load`.
//!
//! `--expert-budget-mb <MiB>` (with `--load`) serves the model through
//! the expert residency cache (DESIGN.md §5): only the budgeted bytes
//! of experts stay in RAM, misses demand-load from the segmented
//! `.mcqz` v2 file, and `--prefetch` picks how predicted experts are
//! brought in (default `async`).
//!
//! `--mem-budget-mb <MiB>` caps the memory governor's byte ceiling
//! (DESIGN.md §8): KV pages, the expert residency budget, and scratch
//! arenas all account against it; over-budget requests get 503 +
//! Retry-After, and sustained pressure walks a reversible degradation
//! ladder instead of OOMing. 0/absent derives a worst-case default
//! (the `MC_MEM_BUDGET_MB` env var also works).
//!
//! `--kernel-backend <scalar|avx2|avx512|neon>` (any subcommand) pins
//! the SIMD kernel dispatch table instead of auto-detecting the widest
//! ISA the CPU supports; the `MC_KERNEL` env var does the same
//! (DESIGN.md §4). Errors if the requested backend cannot run on this
//! CPU.
//!
//! `--trace` (any subcommand) arms the flight recorder (DESIGN.md §9):
//! per-request span timelines land in an in-memory ring, exported as
//! Chrome trace-event JSON via `GET /debug/trace` and auto-dumped on
//! panics, blown deadlines, and drain. `--trace-out <dir>` picks where
//! dumps are written (default: the system temp dir). The `MC_TRACE` /
//! `MC_TRACE_OUT` env vars do the same without flags.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use mc_moe::config::{artifacts_dir, ModelConfig, TASK_NAMES};
use mc_moe::coordinator::{
    memmodel, GenerateRequest, MemoryGovernor, SamplingParams, Server,
    ServerConfig,
};
use mc_moe::data::{calibration_set, Split};
use mc_moe::eval::{eval_cot_chain, eval_niah_grid, eval_suite, perplexity};
use mc_moe::moe::{MoeModel, WeightFile};
use mc_moe::pmq::allocate::{Allocator, PmqHyper};
use mc_moe::pmq::{Workbench, WorkbenchConfig};
use mc_moe::util::cli::Args;

fn load_fp(dir: &Path) -> Result<MoeModel> {
    let cfg = ModelConfig::load(&dir.join("config.json"))
        .context("run `make artifacts` first")?;
    let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
    MoeModel::load_f32(&cfg, wf)
}

/// `--expert-budget-mb` in bytes (None when absent or zero).
fn expert_budget_bytes(args: &Args) -> Result<Option<usize>> {
    let mb = args.f64_or("expert-budget-mb", 0.0)?;
    if mb < 0.0 {
        bail!("--expert-budget-mb must be positive, got {mb}");
    }
    if mb == 0.0 {
        return Ok(None);
    }
    Ok(Some((mb * (1 << 20) as f64) as usize))
}

/// `--mem-budget-mb` as the memory governor's byte ceiling (None when
/// absent or zero → the `MC_MEM_BUDGET_MB` env var, then the derived
/// worst-case default; DESIGN.md §8).
fn mem_budget_bytes(args: &Args) -> Result<Option<u64>> {
    let mb = args.f64_or("mem-budget-mb", 0.0)?;
    if mb < 0.0 {
        bail!("--mem-budget-mb must be positive, got {mb}");
    }
    if mb == 0.0 {
        return Ok(None);
    }
    Ok(Some((mb * (1 << 20) as f64) as u64))
}

/// `--timeout-ms` as a per-request deadline (None when absent or 0).
fn timeout_from(args: &Args) -> Result<Option<std::time::Duration>> {
    Ok(match args.usize_or("timeout-ms", 0)? {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms as u64)),
    })
}

fn prefetch_mode(args: &Args) -> Result<mc_moe::offload::PrefetchMode> {
    let s = args.get_or("prefetch", "async");
    mc_moe::offload::PrefetchMode::parse(&s)
        .ok_or_else(|| anyhow::anyhow!(
            "--prefetch expects off|sync|async, got {s:?}"))
}

/// The model a serving command drives: `--load model.mcqz` picks a
/// saved compressed model (optionally under an expert residency
/// budget); otherwise the fp32 training artifacts.
fn load_serving_model(dir: &Path, args: &Args) -> Result<MoeModel> {
    let budget = expert_budget_bytes(args)?;
    match (args.get("load"), budget) {
        (Some(path), Some(budget)) => {
            let model = mc_moe::offload::load_cached(
                Path::new(path), budget, prefetch_mode(args)?)?;
            eprintln!(
                "loaded {} ({:.2} expert bits) under a {:.1} MiB expert \
                 budget ({:.1}% residency)",
                path,
                model.expert_avg_bits(),
                budget as f64 / (1 << 20) as f64,
                100.0 * budget as f64
                    / model.expert_storage_bytes().max(1) as f64,
            );
            Ok(model)
        }
        (Some(path), None) => {
            let model = mc_moe::moe::qz::load(Path::new(path))?;
            eprintln!("loaded {} ({:.2} expert bits)", path,
                      model.expert_avg_bits());
            Ok(model)
        }
        (None, Some(_)) => {
            bail!("--expert-budget-mb needs --load <model.mcqz>: the \
                   residency cache serves from a segmented .mcqz v2 file")
        }
        (None, None) => load_fp(dir),
    }
}

/// Decode-time ODP calibrated on the model being served (only if
/// `--odp` was passed).
fn decode_odp_for(model: &MoeModel, args: &Args)
                  -> Option<mc_moe::coordinator::DecodeOdp> {
    args.flag("odp").then(|| {
        let seqs = calibration_set(17, 4, model.cfg.max_seq.min(256),
                                   Split::General);
        let cal = mc_moe::pmq::calibrate(model, &seqs);
        mc_moe::coordinator::DecodeOdp::calibrate(
            model, &seqs, cal.mu_median(), 0.02)
    })
}

/// Sampling options shared by `generate` and `serve`. Passing a
/// truncation knob (`--top-k`/`--top-p`) without `--temperature`
/// implies temperature 1.0 — otherwise the greedy short-circuit would
/// silently ignore the knobs.
fn sampling_from(args: &Args) -> Result<SamplingParams> {
    let wants_sampling =
        args.get("top-k").is_some() || args.get("top-p").is_some();
    let default_temp = if wants_sampling { 1.0 } else { 0.0 };
    Ok(SamplingParams {
        temperature: args.f64_or("temperature", default_temp)? as f32,
        top_k: args.usize_or("top-k", 0)?,
        top_p: args.f64_or("top-p", 1.0)? as f32,
        seed: args.usize_or("seed", 5)? as u64,
    })
}

fn parse_strategy(s: &str) -> Result<Allocator> {
    Ok(match s {
        "pmq" => Allocator::Pmq,
        "fnorm" => Allocator::FNorm,
        "frequency" | "freq" => Allocator::Frequency,
        "weight" => Allocator::Weight,
        "hessian" => Allocator::Hessian,
        "bsp" => Allocator::Bsp,
        "random" => Allocator::Random(0),
        other => bail!("unknown strategy {other:?}"),
    })
}

fn build_workbench(fp: MoeModel, fast: bool) -> Result<Workbench> {
    let cfg = WorkbenchConfig {
        calib_seqs: if fast { 4 } else { 8 },
        probe_seqs: if fast { 1 } else { 2 },
        fast_eps: fast,
        ..Default::default()
    };
    Workbench::build(fp, cfg)
}

fn cmd_info(dir: &Path) -> Result<()> {
    let cfg = ModelConfig::load(&dir.join("config.json"))?;
    println!("config: {} ({} params, {} expert params)",
             cfg.name, cfg.param_count(), cfg.expert_param_count());
    println!("layers={} experts={} d_model={} d_ff={} top_k={}",
             cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff, cfg.top_k);
    for name in ["weights.mcwt", "model_fwd.hlo.txt", "manifest.json"] {
        println!("  {:22} {}", name,
                 if dir.join(name).exists() { "present" } else { "MISSING" });
    }
    Ok(())
}

fn cmd_compress(dir: &Path, args: &Args) -> Result<()> {
    let fp = load_fp(dir)?;
    let n = fp.cfg.n_experts;
    let avg = args.f64_or("avg-bits", 2.5)?;
    let total = (avg * n as f64).round() as usize;
    let strategy = parse_strategy(&args.get_or("strategy", "pmq"))?;
    eprintln!("building workbench (calibration + GPTQ zoo + probes)...");
    let wb = build_workbench(fp, args.flag("fast"))?;
    let (model, alloc) = wb.compress(strategy, total, PmqHyper::default())?;
    println!("strategy={} nominal-avg={:.2}b storage-true={:.2}b",
             alloc.strategy, alloc.avg_bits(), model.expert_avg_bits());
    println!("histogram 1/2/3-bit: {:?}", alloc.histogram());
    for (l, row) in alloc.bits.iter().enumerate() {
        println!("  layer {l:2}: {row:?}");
    }
    println!("size: fp={:.3}GB -> mc={:.3}GB ({:.1}% compressed)",
             memmodel::gb(memmodel::loading_bytes(&wb.fp)),
             memmodel::gb(memmodel::loading_bytes(&model)),
             100.0 * (1.0 - memmodel::loading_bytes(&model) as f64
                      / memmodel::loading_bytes(&wb.fp) as f64));
    if let Some(save) = args.get("save") {
        mc_moe::moe::qz::save(Path::new(save), &model)?;
        println!("saved compressed model to {save} ({:.3} MB)",
                 std::fs::metadata(save)?.len() as f64 / 1e6);
    }
    if args.flag("eval") {
        let r = eval_suite(&model, 30, 0, 4242, None);
        for (name, analogue, acc) in &r.rows {
            println!("  {name:10} ({analogue:8}): {:.1}%", acc * 100.0);
        }
        println!("  average: {:.2}%", r.average * 100.0);
    }
    Ok(())
}

fn cmd_eval(dir: &Path, args: &Args) -> Result<()> {
    if args.get("load").is_some() {
        // evaluate a saved MCQZ model directly (no recalibration),
        // honoring --expert-budget-mb like serve/generate
        let model = load_serving_model(dir, args)?;
        let samples = args.usize_or("samples", 50)?;
        let r = eval_suite(&model, samples, 0, 4242, None);
        for (name, analogue, acc) in &r.rows {
            println!("{name:10} ({analogue:8}): {:.1}%", acc * 100.0);
        }
        println!("average: {:.2}%", r.average * 100.0);
        return Ok(());
    }
    let fp = load_fp(dir)?;
    let n = fp.cfg.n_experts;
    let n_layers = fp.cfg.n_layers;
    let (model, policy) = if let Some(avg) = args.get("avg-bits") {
        let avg: f64 = avg.parse()?;
        let total = (avg * n as f64).round() as usize;
        let strategy = parse_strategy(&args.get_or("strategy", "pmq"))?;
        let wb = build_workbench(fp, args.flag("fast"))?;
        let (m, _) = wb.compress(strategy, total, PmqHyper::default())?;
        let policy = args.flag("odp").then(|| wb.odp_policy(0.02));
        (m, policy)
    } else {
        let policy = args.flag("odp").then(|| {
            let seqs = calibration_set(17, 4, fp.cfg.max_seq.min(256),
                                       Split::General);
            let cal = mc_moe::pmq::calibrate(&fp, &seqs);
            mc_moe::odp::odp_default(&cal)
        });
        (fp, policy)
    };
    let _ = n_layers;
    match args.get_or("mode", "suite").as_str() {
        "suite" => {
            let samples = args.usize_or("samples", 50)?;
            let r = eval_suite(&model, samples, 0, 4242, policy.as_ref());
            for (name, analogue, acc) in &r.rows {
                println!("{name:10} ({analogue:8}): {:.1}%", acc * 100.0);
            }
            println!("average: {:.2}%  CR: {:.1}%", r.average * 100.0,
                     r.stats.compression_ratio() * 100.0);
        }
        "fewshot" => {
            let samples = args.usize_or("samples", 30)?;
            let shots = args.usize_or("shots", 5)?;
            let (acc, _) = mc_moe::eval::eval_task(&model, 7, samples, shots,
                                                   4242, policy.as_ref());
            println!("induction (MMLU-analogue) {shots}-shot: {:.2}%", acc * 100.0);
        }
        "ppl" => {
            let r = perplexity(&model, Split::Text, 4242, 8, model.cfg.max_seq,
                               policy.as_ref());
            println!("PPL(text): {:.3}  tokens={}  CR={:.1}%", r.ppl, r.tokens,
                     r.stats.compression_ratio() * 100.0);
        }
        "niah" => {
            let grid = eval_niah_grid(&model, &[64, 128, 192, 256],
                                      &[0.1, 0.5, 0.9], 20, 4242, policy.as_ref());
            println!("NIAH accuracy (rows=len 64..256, cols=depth .1/.5/.9):");
            for row in grid {
                println!("  {:?}", row.iter().map(|v| format!("{:.2}", v))
                         .collect::<Vec<_>>());
            }
        }
        "cot" => {
            for steps in [1, 2, 4] {
                let acc = eval_cot_chain(&model, steps, 40, 4242, policy.as_ref());
                println!("CoT chain x{steps}: {:.1}%", acc * 100.0);
            }
        }
        other => bail!("unknown mode {other:?}"),
    }
    Ok(())
}

/// `serve --port <p>`: HTTP/SSE front end (DESIGN.md §6). Runs until
/// SIGTERM or `POST /admin/drain`, then drains in-flight streams and
/// exits cleanly.
fn cmd_serve_http(model: mc_moe::moe::MoeModel, args: &Args) -> Result<()> {
    use mc_moe::serve::{drain, HttpServer, ServeConfig};
    let odp = decode_odp_for(&model, args);
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        host: args.get_or("host", &defaults.host),
        port: args.usize_or("port", defaults.port as usize)? as u16,
        max_conns: args.usize_or("max-conns", defaults.max_conns)?,
        max_streams_per_tenant: args.usize_or(
            "max-streams-per-tenant", defaults.max_streams_per_tenant)?,
        shed_queue_depth: args.usize_or(
            "shed-queue-depth", defaults.shed_queue_depth)?,
        max_batch: args.usize_or("batch", defaults.max_batch)?,
        default_timeout: timeout_from(args)?,
        ..defaults
    };
    let engine = Server::spawn_cfg(
        Arc::new(model), odp,
        ServerConfig {
            max_batch: cfg.max_batch,
            mem_budget: mem_budget_bytes(args)?,
            ..Default::default()
        });
    let budget_mb = engine.governor().budget_bytes() as f64
        / (1 << 20) as f64;
    drain::install_sigterm_hook();
    let http = HttpServer::bind(engine, cfg.clone())?;
    println!(
        "mc-moe serving on http://{}  (batch={} max-conns={} \
         tenant-cap={} shed-depth={} mem-budget={:.1}MiB)",
        http.addr(), cfg.max_batch, cfg.max_conns,
        cfg.max_streams_per_tenant, cfg.shed_queue_depth, budget_mb);
    println!("  POST /v1/generate   GET /healthz   GET /metrics   \
              POST /admin/drain");
    println!("  GET /debug/trace    GET /debug/experts   (flight recorder; \
              arm with --trace or ?enable=1)");
    let metrics = http.metrics();
    let report = http.serve_until_drained();
    println!("{}", metrics.render_text());
    println!("drained: {} in-flight streams in {:.1}ms (clean={})",
             report.inflight_at_start, report.drain_ms, report.drained);
    Ok(())
}

fn cmd_serve(dir: &Path, args: &Args) -> Result<()> {
    let model = load_serving_model(dir, args)?;
    if args.get("port").is_some() {
        return cmd_serve_http(model, args);
    }
    let odp = decode_odp_for(&model, args);
    let sampling = sampling_from(args)?;
    let n_req = args.usize_or("requests", 16)?;
    let batch = args.usize_or("batch", 4)?;
    let max_new = args.usize_or("max-new", 24)?;
    let server = Server::spawn_cfg(
        Arc::new(model), odp,
        ServerConfig {
            max_batch: batch,
            mem_budget: mem_budget_bytes(args)?,
            ..Default::default()
        });
    let mut rng = mc_moe::util::rng::Rng::new(99);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_req)
        .map(|i| {
            let task = rng.below(8);
            let mut prompt = mc_moe::data::task_sequence(&mut rng, task);
            prompt.truncate(prompt.len() - 2); // stop at SEP
            let req = GenerateRequest::greedy(prompt, max_new).with_sampling(
                SamplingParams { seed: sampling.seed ^ i as u64, ..sampling.clone() });
            server.submit(req)
        })
        .collect();
    for h in handles {
        let _ = h.wait();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics.render_text());
    println!("wall: {dt:.2}s  throughput: {:.1} tok/s",
             server.metrics.tokens_generated.load(
                 std::sync::atomic::Ordering::Relaxed) as f64 / dt);
    server.shutdown();
    Ok(())
}

fn cmd_generate(dir: &Path, args: &Args) -> Result<()> {
    let model = load_serving_model(dir, args)?;
    let decode_odp = decode_odp_for(&model, args);
    let mut engine =
        mc_moe::coordinator::McEngine::new(model, None, decode_odp);
    if let Some(budget) = mem_budget_bytes(args)? {
        let gov = MemoryGovernor::for_model(
            &engine.model.cfg, engine.model.resolver.budget_bytes(), 1,
            Some(budget), engine.metrics.clone());
        engine.set_governor(gov);
    }
    let task = args.usize_or("task", 3)?;
    let mut rng = mc_moe::util::rng::Rng::new(args.usize_or("seed", 5)? as u64);
    let seq = mc_moe::data::try_task_sequence(&mut rng, task)
        .ok_or_else(|| anyhow::anyhow!(
            "--task {task} out of range (valid: 0..{})",
            mc_moe::data::NUM_TASKS))?;
    let sep = seq.iter().position(|&t| t == 3).unwrap();
    let prompt = &seq[..=sep];
    let gold = &seq[sep + 1..seq.len() - 1];
    let mut req = GenerateRequest::greedy(
        prompt.to_vec(), args.usize_or("max-new", 16)?)
        .with_sampling(sampling_from(args)?);
    if let Some(d) = timeout_from(args)? {
        req = req.with_deadline(d);
    }
    let out = engine.generate(&req)?;
    println!("task     : {}", TASK_NAMES[task]);
    println!("prompt   : {prompt:?}");
    println!("generated: {:?}", out.tokens);
    println!("finish   : {:?}  ttft: {:.2}ms", out.finish,
             out.ttft_ns as f64 / 1e6);
    println!("gold     : {gold:?}");
    if engine.model.resolver.budget_bytes().is_some() {
        println!("cache    : {}", engine.metrics.cache_summary());
    }
    Ok(())
}

fn cmd_expert_analysis(dir: &Path, args: &Args) -> Result<()> {
    let fp = load_fp(dir)?;
    let wb = build_workbench(fp, args.flag("fast"))?;
    let json = wb.sig.to_json().to_string();
    let out = args.get_or("out", "expert_analysis.json");
    std::fs::write(&out, &json)?;
    println!("wrote {out} ({} bytes)", json.len());
    // also print per-layer summary
    for l in 0..wb.fp.cfg.n_layers {
        let phi: Vec<String> =
            wb.sig.phi[l].iter().map(|v| format!("{v:.2}")).collect();
        println!("layer {l}: phi = {phi:?}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let dir = artifacts_dir();
    if let Some(backend) = args.get("kernel-backend") {
        mc_moe::kernels::force_named(backend)
            .map_err(|e| anyhow::anyhow!("--kernel-backend: {e}"))?;
    }
    if let Some(out) = args.get("trace-out") {
        mc_moe::obs::set_dump_dir(Some(std::path::PathBuf::from(out)));
    }
    if args.flag("trace") {
        mc_moe::obs::set_enabled(true);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&dir),
        Some("compress") => cmd_compress(&dir, &args),
        Some("eval") => cmd_eval(&dir, &args),
        Some("serve") => cmd_serve(&dir, &args),
        Some("generate") => cmd_generate(&dir, &args),
        Some("expert-analysis") => cmd_expert_analysis(&dir, &args),
        _ => {
            eprintln!("usage: mc-moe <info|compress|eval|serve|generate|expert-analysis> [options]");
            std::process::exit(2);
        }
    }
}
