//! `ExpertCache` — byte-budgeted expert residency (DESIGN.md §5).
//!
//! A `(layer, expert)`-keyed map of materialized experts under a hard
//! byte budget. Demand access (`get_pinned`) pins the expert for the
//! duration of the fused step — pinned slots are never evicted, so the
//! weights a dispatch is executing cannot be freed under it. Eviction
//! is clock-style with significance-weighted second chances: every
//! slot carries a credit of `1 + round(3 * sig)` where `sig` blends
//! the pmq significance factors (activation frequency, routing-weight
//! mass, reconstruction error) from the store's priors; the sweeping
//! hand decrements credits and evicts the first unpinned slot at zero.
//! A hit refreshes the slot's credit, so recency and significance
//! jointly pick the victim.
//!
//! Budget discipline: demand loads may exceed the budget when
//! everything else is pinned (the current step's working set must be
//! resident for correctness — the overshoot lasts until the step
//! unpins); speculative prefetch loads never overshoot, they are
//! dropped instead.

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::moe::model::Expert;
use crate::util::faults;

use super::store::ExpertStore;
use super::ExpertUnavailable;

/// Extra eviction credits a maximally significant expert gets on top
/// of the base second chance.
const SIG_CREDITS: f64 = 3.0;

/// Retry / quarantine discipline for demand fetches. A transient
/// failure (short read, injected I/O error, checksum mismatch from a
/// racing writer) is retried with exponential backoff; an expert that
/// exhausts its retries is quarantined for a cool-down during which
/// the resolver reports it [`ExpertUnavailable`] immediately instead
/// of hammering the failing medium, and dispatch degrades around it
/// (DESIGN.md §7). Quarantine expiry re-arms the fetch path, so a
/// healed disk recovers without intervention.
#[derive(Debug, Clone, Copy)]
pub struct FetchPolicy {
    /// extra attempts after the first failure
    pub max_retries: u32,
    /// backoff before retry `n` is `backoff * 2^(n-1)`
    pub backoff: Duration,
    /// how long a failed (layer, expert) stays unavailable
    pub quarantine: Duration,
}

impl Default for FetchPolicy {
    fn default() -> FetchPolicy {
        FetchPolicy {
            max_retries: 3,
            backoff: Duration::from_micros(500),
            quarantine: Duration::from_millis(250),
        }
    }
}

#[derive(Debug)]
struct Slot {
    expert: Arc<Expert>,
    bytes: usize,
    pins: u32,
    /// clock credits left before this slot is evictable
    credit: u8,
    /// inserted by the prefetcher and not yet demanded
    prefetched: bool,
}

#[derive(Debug)]
struct Inner {
    slots: Vec<Vec<Option<Slot>>>,
    bytes: usize,
    /// clock hand over the flattened (layer, expert) space
    hand: usize,
    /// quarantine expiry per [layer][expert]; `Some` while the expert
    /// is sidelined after exhausting its fetch retries
    quarantined: Vec<Vec<Option<Instant>>>,
}

#[derive(Debug)]
pub struct ExpertCache {
    store: Arc<ExpertStore>,
    budget: usize,
    /// memory-governor rung 2: while set, eviction and prefetch
    /// feasibility run against half the configured budget (reversible;
    /// `budget_bytes()` keeps reporting the configured value)
    shrunk: AtomicBool,
    metrics: Arc<Metrics>,
    /// eviction credit per [layer][expert]: 1 + round(3 * sig score)
    credit: Vec<Vec<u8>>,
    n_experts: usize,
    policy: Mutex<FetchPolicy>,
    inner: Mutex<Inner>,
}

impl ExpertCache {
    pub fn new(store: Arc<ExpertStore>, budget_bytes: usize,
               metrics: Arc<Metrics>) -> ExpertCache {
        let cfg = store.config();
        let (nl, ne) = (cfg.n_layers, cfg.n_experts);
        let credit = match store.priors() {
            Some(p) => p
                .scores()
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&s| 1 + (SIG_CREDITS * s).round() as u8)
                        .collect()
                })
                .collect(),
            None => vec![vec![1u8; ne]; nl],
        };
        ExpertCache {
            store,
            budget: budget_bytes,
            shrunk: AtomicBool::new(false),
            metrics,
            credit,
            n_experts: ne,
            policy: Mutex::new(FetchPolicy::default()),
            inner: Mutex::new(Inner {
                slots: (0..nl).map(|_| (0..ne).map(|_| None).collect()).collect(),
                bytes: 0,
                hand: 0,
                quarantined: vec![vec![None; ne]; nl],
            }),
        }
    }

    /// Replace the retry / quarantine discipline (tests and the chaos
    /// bench tighten it; serving keeps the default).
    pub fn set_fetch_policy(&self, policy: FetchPolicy) {
        *self.policy.lock().unwrap() = policy;
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Halve (or restore) the budget the eviction clock and prefetch
    /// feasibility checks run against — the memory governor's rung-2
    /// pressure action. Shrinking does not evict eagerly; the next
    /// load's clock sweep works residency down to the reduced ceiling.
    pub fn set_pressure_shrink(&self, on: bool) {
        self.shrunk.store(on, Relaxed);
    }

    pub fn is_pressure_shrunk(&self) -> bool {
        self.shrunk.load(Relaxed)
    }

    /// The budget currently in force (halved while under rung-2
    /// memory pressure).
    fn effective_budget(&self) -> usize {
        if self.shrunk.load(Relaxed) {
            self.budget / 2
        } else {
            self.budget
        }
    }

    pub fn bytes_resident(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    pub fn contains(&self, layer: usize, expert: usize) -> bool {
        self.inner.lock().unwrap().slots[layer][expert].is_some()
    }

    /// One-shot residency/quarantine table for `/debug/experts`:
    /// `(resident, quarantined)` flags per `[layer][expert]`, read
    /// under the inner lock so the two views are mutually consistent.
    pub fn residency_snapshot(&self) -> (Vec<Vec<bool>>, Vec<Vec<bool>>) {
        let g = self.inner.lock().unwrap();
        let now = Instant::now();
        let resident = g
            .slots
            .iter()
            .map(|row| row.iter().map(|s| s.is_some()).collect())
            .collect();
        let quarantined = g
            .quarantined
            .iter()
            .map(|row| {
                row.iter()
                    .map(|q| q.is_some_and(|until| now < until))
                    .collect()
            })
            .collect();
        (resident, quarantined)
    }

    /// Resolve one expert for the current step, pinning it until the
    /// matching [`unpin`]. Infallible variant of [`try_get_pinned`]
    /// for callers that treat an unavailable expert as a bug (tests,
    /// offline tools); the serving path goes through the fallible one
    /// and degrades instead.
    ///
    /// [`try_get_pinned`]: ExpertCache::try_get_pinned
    pub fn get_pinned(&self, layer: usize, expert: usize) -> Arc<Expert> {
        self.try_get_pinned(layer, expert).unwrap_or_else(|u| {
            panic!("expert store fetch failed after retries: {u}")
        })
    }

    /// Resolve one expert for the current step, pinning it until the
    /// matching [`unpin`]. Misses demand-load from the store (the
    /// stall is recorded in `Metrics::miss_stall_ns`) and may exceed
    /// the budget if every other slot is pinned. Fetch failures are
    /// retried per the [`FetchPolicy`]; an expert that exhausts its
    /// retries is quarantined and reported [`ExpertUnavailable`] until
    /// the quarantine expires (callers drop it from dispatch — the
    /// paper's ODP pruning path — rather than unwinding the step).
    pub fn try_get_pinned(&self, layer: usize, expert: usize)
                          -> Result<Arc<Expert>, ExpertUnavailable> {
        {
            let mut g = self.inner.lock().unwrap();
            if let Some(slot) = g.slots[layer][expert].as_mut() {
                slot.pins += 1;
                slot.credit = self.credit[layer][expert];
                if slot.prefetched {
                    slot.prefetched = false;
                    Metrics::inc(&self.metrics.expert_prefetch_hits, 1);
                }
                Metrics::inc(&self.metrics.expert_cache_hits, 1);
                return Ok(slot.expert.clone());
            }
            if let Some(until) = g.quarantined[layer][expert] {
                if Instant::now() < until {
                    return Err(ExpertUnavailable { layer, expert });
                }
                // cool-down over: re-arm the fetch path
                g.quarantined[layer][expert] = None;
            }
        }
        Metrics::inc(&self.metrics.expert_cache_misses, 1);
        let policy = *self.policy.lock().unwrap();
        // the demand-fetch span IS the decode miss stall: everything
        // from here to the pinned slot blocks the step that routed here
        let mut sp = crate::obs::span(crate::obs::Cat::Expert,
                                      "expert_fetch")
            .arg("layer", layer as u64)
            .arg("expert", expert as u64);
        let t0 = Instant::now();
        let mut fetched = None;
        for attempt in 0..=policy.max_retries {
            if attempt > 0 {
                Metrics::inc(&self.metrics.expert_load_retries, 1);
                std::thread::sleep(
                    policy.backoff * (1u32 << (attempt - 1).min(16)));
            }
            if let Ok(x) = self.store.fetch(layer, expert) {
                fetched = Some(x);
                break;
            }
        }
        let Some(fetched) = fetched else {
            sp.set_arg("quarantined", 1);
            Metrics::inc(&self.metrics.expert_load_failures, 1);
            Metrics::inc(&self.metrics.experts_quarantined, 1);
            let mut g = self.inner.lock().unwrap();
            g.quarantined[layer][expert] =
                Some(Instant::now() + policy.quarantine);
            return Err(ExpertUnavailable { layer, expert });
        };
        self.metrics.record_miss_stall(t0.elapsed().as_nanos() as u64);
        let bytes = fetched.storage_bytes();
        let expert_arc = Arc::new(fetched);
        let mut g = self.inner.lock().unwrap();
        if let Some(slot) = g.slots[layer][expert].as_mut() {
            // another thread (prefetcher) won the race; use its copy.
            // This demand already paid its own stall, so the slot's
            // prefetch does NOT count as a hit — clear the flag
            // silently and refresh the credit like any other access.
            slot.prefetched = false;
            slot.credit = self.credit[layer][expert];
            slot.pins += 1;
            return Ok(slot.expert.clone());
        }
        // demand loads must land even if eviction can't make room
        // (everything else pinned): the step's working set is sacred
        self.evict_for(&mut g, bytes);
        g.slots[layer][expert] = Some(Slot {
            expert: expert_arc.clone(),
            bytes,
            pins: 1,
            credit: self.credit[layer][expert],
            prefetched: false,
        });
        g.bytes += bytes;
        Metrics::set_gauge(&self.metrics.bytes_resident, g.bytes as u64);
        Ok(expert_arc)
    }

    /// Release a step's pin. The slot stays resident; it merely
    /// becomes evictable again.
    pub fn unpin(&self, layer: usize, expert: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(slot) = g.slots[layer][expert].as_mut() {
            debug_assert!(slot.pins > 0, "unbalanced unpin");
            slot.pins = slot.pins.saturating_sub(1);
        }
    }

    /// Speculative load (prefetcher path): no pin, never over-budget.
    /// Returns true when the expert was actually brought in.
    /// Feasibility is checked from the store directory *before* any
    /// I/O or eviction: if the pinned working set plus this expert
    /// cannot fit, nothing is fetched and nothing resident is churned.
    pub fn prefetch(&self, layer: usize, expert: usize) -> bool {
        let bytes = self.store.expert_storage_bytes(layer, expert);
        {
            let g = self.inner.lock().unwrap();
            if g.slots[layer][expert].is_some() {
                return false;
            }
            // everything unpinned is evictable in principle, so the
            // load fits iff the pinned bytes leave room
            if Self::pinned_bytes(&g) + bytes > self.effective_budget() {
                return false;
            }
        }
        if let Some(fp) = faults::plan() {
            if fp.drop_prefetch() {
                return false; // injected: speculative load skipped
            }
        }
        let Ok(fetched) = self.store.fetch_speculative(layer, expert) else {
            return false;
        };
        Metrics::inc(&self.metrics.expert_prefetch_issued, 1);
        crate::obs::instant(crate::obs::Cat::Expert, "expert_prefetched",
                            crate::obs::args2("layer", layer as u64,
                                              "expert", expert as u64));
        debug_assert_eq!(fetched.storage_bytes(), bytes);
        let mut g = self.inner.lock().unwrap();
        if g.slots[layer][expert].is_some() {
            return false; // raced with a demand load
        }
        if !self.evict_for(&mut g, bytes) {
            return false; // pins grew since the check: drop it
        }
        g.slots[layer][expert] = Some(Slot {
            expert: Arc::new(fetched),
            bytes,
            pins: 0,
            credit: self.credit[layer][expert],
            prefetched: true,
        });
        g.bytes += bytes;
        Metrics::set_gauge(&self.metrics.bytes_resident, g.bytes as u64);
        true
    }

    /// Bytes held by currently pinned slots (the floor no eviction can
    /// go below).
    fn pinned_bytes(g: &Inner) -> usize {
        g.slots
            .iter()
            .flatten()
            .filter_map(|s| s.as_ref())
            .filter(|s| s.pins > 0)
            .map(|s| s.bytes)
            .sum()
    }

    /// Clock sweep until `incoming` fits in the budget. Pinned slots
    /// are skipped unconditionally; unpinned slots burn one credit per
    /// visit and are evicted at zero. Returns false when the budget
    /// cannot be met (all remaining residents are pinned).
    fn evict_for(&self, g: &mut Inner, incoming: usize) -> bool {
        let budget = self.effective_budget();
        let nslots = g.slots.len() * self.n_experts;
        if nslots == 0 {
            return g.bytes + incoming <= budget;
        }
        // every slot absorbs at most credit+1 visits before eviction,
        // so this bound means "only pinned slots remain"
        let max_visits = nslots * (SIG_CREDITS as usize + 3);
        let mut visits = 0usize;
        while g.bytes + incoming > budget {
            if visits >= max_visits {
                Metrics::set_gauge(&self.metrics.bytes_resident,
                                   g.bytes as u64);
                return false;
            }
            visits += 1;
            let (l, e) = (g.hand / self.n_experts, g.hand % self.n_experts);
            g.hand = (g.hand + 1) % nslots;
            let Some(slot) = g.slots[l][e].as_mut() else { continue };
            if slot.pins > 0 {
                continue;
            }
            if slot.credit > 0 {
                slot.credit -= 1;
                continue;
            }
            let freed = slot.bytes;
            g.slots[l][e] = None;
            g.bytes -= freed;
            Metrics::inc(&self.metrics.expert_cache_evictions, 1);
        }
        Metrics::set_gauge(&self.metrics.bytes_resident, g.bytes as u64);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;
    use crate::moe::qz;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("{name}_{}.mcqz", std::process::id()))
    }

    /// f32 test model: every expert has identical storage bytes.
    fn setup(name: &str, budget_experts: usize)
             -> (Arc<Metrics>, ExpertCache, usize, std::path::PathBuf) {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 3);
        let per_expert = m.layers[0].experts[0].storage_bytes();
        let path = tmp(name);
        qz::save(&path, &m).unwrap();
        let (_, store) = ExpertStore::open(&path).unwrap();
        let metrics = Arc::new(Metrics::new());
        let cache = ExpertCache::new(Arc::new(store),
                                     budget_experts * per_expert,
                                     metrics.clone());
        (metrics, cache, per_expert, path)
    }

    #[test]
    fn hit_miss_and_budget_accounting() {
        let (metrics, cache, per_expert, path) = setup("cache_hits", 2);
        let a = cache.get_pinned(0, 0);
        cache.unpin(0, 0);
        let b = cache.get_pinned(0, 0);
        cache.unpin(0, 0);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the resident copy");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.expert_cache_misses.load(Relaxed), 1);
        assert_eq!(metrics.expert_cache_hits.load(Relaxed), 1);
        assert_eq!(cache.bytes_resident(), per_expert);
        assert_eq!(metrics.miss_stall_ns.lock().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn eviction_respects_budget_and_clock() {
        let (metrics, cache, per_expert, path) = setup("cache_evict", 2);
        for e in 0..4 {
            cache.get_pinned(0, e);
            cache.unpin(0, e);
        }
        use std::sync::atomic::Ordering::Relaxed;
        assert!(cache.bytes_resident() <= 2 * per_expert);
        assert_eq!(metrics.expert_cache_evictions.load(Relaxed), 2);
        assert_eq!(metrics.expert_cache_misses.load(Relaxed), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pinned_experts_survive_pressure() {
        let (metrics, cache, per_expert, path) = setup("cache_pin", 2);
        // pin two experts (the whole budget), then demand a third:
        // the pinned pair must stay resident, the budget overshoots
        cache.get_pinned(0, 0);
        cache.get_pinned(0, 1);
        cache.get_pinned(0, 2);
        assert!(cache.contains(0, 0) && cache.contains(0, 1),
                "pinned experts must never be evicted");
        assert!(cache.bytes_resident() > cache.budget_bytes(),
                "demand load overshoots rather than evicting pins");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.expert_cache_evictions.load(Relaxed), 0);
        // release the pins: the next load can now evict back under
        cache.unpin(0, 0);
        cache.unpin(0, 1);
        cache.get_pinned(0, 3);
        assert!(cache.bytes_resident() <= 2 * per_expert + per_expert,
                "{} bytes resident", cache.bytes_resident());
        assert!(metrics.expert_cache_evictions.load(Relaxed) > 0);
        assert!(cache.contains(0, 2), "still-pinned expert survives");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prefetch_never_overshoots_and_hits_count() {
        let (metrics, cache, _per, path) = setup("cache_prefetch", 2);
        assert!(cache.prefetch(1, 0), "prefetch into free budget");
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.expert_prefetch_issued.load(Relaxed), 1);
        // demanding the prefetched expert counts a prefetch hit
        cache.get_pinned(1, 0);
        assert_eq!(metrics.expert_prefetch_hits.load(Relaxed), 1);
        // second access is an ordinary hit, not another prefetch hit
        cache.unpin(1, 0);
        cache.get_pinned(1, 0);
        assert_eq!(metrics.expert_prefetch_hits.load(Relaxed), 1);
        // with the rest of the budget pinned, prefetch must refuse
        cache.get_pinned(1, 1);
        let before = cache.bytes_resident();
        assert!(!cache.prefetch(1, 2), "prefetch never overshoots");
        assert_eq!(cache.bytes_resident(), before);
        assert!(!cache.contains(1, 2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_fetch_retries_quarantines_and_recovers() {
        let (metrics, cache, _per, path) = setup("cache_quarantine", 4);
        cache.set_fetch_policy(FetchPolicy {
            max_retries: 2,
            backoff: Duration::ZERO,
            quarantine: Duration::from_millis(40),
        });
        // corrupt expert (0, 0)'s segment on disk: every fetch of it
        // now fails its checksum, everything else stays healthy
        let clean = std::fs::read(&path).unwrap();
        let (_, header, payload_off) =
            crate::moe::qz::parse_container(&clean).unwrap();
        let seg = &header.get("expert_dir").unwrap().as_arr().unwrap()[0]
            .as_arr().unwrap()[0];
        let at = payload_off + seg.get("off").unwrap().as_usize().unwrap()
            + seg.get("len").unwrap().as_usize().unwrap() / 2;
        let mut corrupt = clean.clone();
        corrupt[at] ^= 0x08;
        std::fs::write(&path, &corrupt).unwrap();

        use std::sync::atomic::Ordering::Relaxed;
        let err = cache.try_get_pinned(0, 0).expect_err("corrupt expert");
        assert_eq!((err.layer, err.expert), (0, 0));
        assert_eq!(metrics.expert_load_retries.load(Relaxed), 2,
                   "both retries consumed");
        assert_eq!(metrics.expert_load_failures.load(Relaxed), 1);
        assert_eq!(metrics.experts_quarantined.load(Relaxed), 1);

        // quarantined: the immediate re-ask fails fast, no new retries
        assert!(cache.try_get_pinned(0, 0).is_err());
        assert_eq!(metrics.expert_load_retries.load(Relaxed), 2);
        // siblings are unaffected
        assert!(cache.try_get_pinned(0, 1).is_ok());
        cache.unpin(0, 1);

        // heal the disk, wait out the quarantine: full recovery
        std::fs::write(&path, &clean).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let ex = cache.try_get_pinned(0, 0).expect("recovered after heal");
        cache.unpin(0, 0);
        assert!(ex.storage_bytes() > 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pressure_shrink_halves_effective_budget_reversibly() {
        let (_metrics, cache, per_expert, path) = setup("cache_shrink", 4);
        for e in 0..4 {
            cache.get_pinned(0, e);
            cache.unpin(0, e);
        }
        assert_eq!(cache.bytes_resident(), 4 * per_expert);
        cache.set_pressure_shrink(true);
        assert!(cache.is_pressure_shrunk());
        assert_eq!(cache.budget_bytes(), 4 * per_expert,
                   "configured budget still reported unshrunk");
        // the next load's clock sweep works residency down to half
        cache.get_pinned(1, 0);
        cache.unpin(1, 0);
        assert!(cache.bytes_resident() <= 2 * per_expert,
                "{} resident under a {}-byte effective budget",
                cache.bytes_resident(), 2 * per_expert);
        // lifting the pressure restores the full ceiling
        cache.set_pressure_shrink(false);
        for e in 0..4 {
            cache.get_pinned(0, e);
            cache.unpin(0, e);
        }
        assert!(cache.bytes_resident() > 2 * per_expert,
                "restored budget admits more than the shrunk ceiling");
        assert!(cache.bytes_resident() <= 4 * per_expert);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn significant_experts_outlast_insignificant() {
        // priors make expert 0 maximally significant: under pressure
        // the clock burns through expert 1..3 first
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 5);
        let per_expert = m.layers[0].experts[0].storage_bytes();
        let priors = crate::offload::ResidencyPriors {
            phi: vec![vec![1.0, 0.0, 0.0, 0.0]; cfg.n_layers],
            weight: vec![vec![1.0, 0.0, 0.0, 0.0]; cfg.n_layers],
            recon: vec![vec![1.0, 0.0, 0.0, 0.0]; cfg.n_layers],
        };
        let path = tmp("cache_sig");
        qz::save_with_priors(&path, &m, Some(&priors)).unwrap();
        let (_, store) = ExpertStore::open(&path).unwrap();
        let metrics = Arc::new(Metrics::new());
        let cache = ExpertCache::new(Arc::new(store), 2 * per_expert, metrics);
        cache.get_pinned(0, 0); // sig expert resident, credit 4
        cache.unpin(0, 0);
        for e in [1usize, 2, 3] {
            cache.get_pinned(0, e);
            cache.unpin(0, e);
        }
        // without priors this churn evicts expert 0 (credit 1 burns in
        // one sweep); its 4 significance credits carry it through
        assert!(cache.contains(0, 0),
                "high-significance expert outlasts the churn");
        std::fs::remove_file(&path).ok();
    }
}
