//! Router-predicted expert prefetch (DESIGN.md §5).
//!
//! After the router of layer *l* selects its expert set, the predictor
//! ranks layer *l+1*'s experts by accumulated co-activation counts
//! (`co[l][e][e']`: e active at l together with e' at l+1, wrapping
//! the last layer onto layer 0 of the *next* token so decode loops
//! prefetch across token boundaries) and asks the cache to bring the
//! top candidates in before the dispatch that will need them. Counts
//! are warmed from calibration frequencies (`ResidencyPriors::phi`)
//! when the store carries priors, so the very first tokens already
//! prefetch the frequency-favored experts.
//!
//! `Async` runs the loads on a background thread — the demand path
//! rarely blocks because predicted experts stream in while the
//! current layer's FFNs execute. `Sync` issues the same loads inline
//! (deterministic; used by the parity tests), `Off` disables the
//! predictor entirely.

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::stats::top_k_indices;

use super::cache::ExpertCache;
use super::store::ResidencyPriors;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchMode {
    /// no prediction, no speculative loads
    Off,
    /// predict + load inline on the calling thread (deterministic)
    Sync,
    /// predict inline, load on the background prefetcher thread
    Async,
}

impl PrefetchMode {
    pub fn parse(s: &str) -> Option<PrefetchMode> {
        match s {
            "off" => Some(PrefetchMode::Off),
            "sync" => Some(PrefetchMode::Sync),
            "async" => Some(PrefetchMode::Async),
            _ => None,
        }
    }
}

#[derive(Debug)]
struct Predictor {
    n_layers: usize,
    n_experts: usize,
    /// co[l][e][e']: times expert e (layer l) co-activated with
    /// expert e' at layer (l+1) % n_layers
    co: Vec<Vec<Vec<f32>>>,
    /// last observed (layer, expert set), for count updates
    last: Option<(usize, Vec<usize>)>,
}

impl Predictor {
    fn new(n_layers: usize, n_experts: usize,
           priors: Option<&ResidencyPriors>) -> Predictor {
        let co = (0..n_layers)
            .map(|l| {
                let next = (l + 1) % n_layers;
                (0..n_experts)
                    .map(|_| {
                        (0..n_experts)
                            .map(|e2| match priors {
                                // calibration frequency of the *next*
                                // layer's expert seeds every row
                                Some(p) => p.phi[next][e2] as f32,
                                None => 0.0,
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        Predictor { n_layers, n_experts, co, last: None }
    }

    /// Record layer `layer`'s routed set and predict the next layer's:
    /// returns `(next_layer, predicted experts)`.
    fn observe(&mut self, layer: usize, set: &[usize])
               -> (usize, Vec<usize>) {
        if let Some((pl, pset)) = self.last.take() {
            if (pl + 1) % self.n_layers == layer {
                for &a in &pset {
                    for &b in set {
                        self.co[pl][a][b] += 1.0;
                    }
                }
            }
        }
        self.last = Some((layer, set.to_vec()));
        let next = (layer + 1) % self.n_layers;
        let mut score = vec![0.0f32; self.n_experts];
        for &a in set {
            for (b, sc) in score.iter_mut().enumerate() {
                *sc += self.co[layer][a][b];
            }
        }
        let k = set.len().min(self.n_experts);
        (next, top_k_indices(&score, k))
    }
}

/// The prefetcher: a predictor plus (in `Async` mode) a background
/// worker draining prediction batches into `ExpertCache::prefetch`.
#[derive(Debug)]
pub struct Prefetcher {
    mode: PrefetchMode,
    cache: Arc<ExpertCache>,
    predictor: Mutex<Predictor>,
    tx: Option<SyncSender<(usize, Vec<usize>)>>,
    worker: Option<JoinHandle<()>>,
    /// memory-governor rung 1: speculative loads suppressed while set
    /// (reversible; the predictor keeps learning nothing — routing
    /// observations are skipped too, so resuming replays cleanly)
    paused: AtomicBool,
}

impl Prefetcher {
    pub fn new(cache: Arc<ExpertCache>, n_layers: usize, n_experts: usize,
               priors: Option<&ResidencyPriors>, mode: PrefetchMode)
               -> Prefetcher {
        let predictor = Mutex::new(Predictor::new(n_layers, n_experts, priors));
        let (tx, worker) = if mode == PrefetchMode::Async {
            // bounded handoff: when the worker's store I/O is slower
            // than the decode loop, stale predictions are DROPPED
            // (try_send below) instead of queueing without bound —
            // loading experts for layers the decode already passed
            // only evicts residents that are still useful
            let (tx, rx) = sync_channel::<(usize, Vec<usize>)>(2);
            let c = cache.clone();
            let worker = std::thread::Builder::new()
                .name("mc-prefetch".into())
                .spawn(move || {
                    for (layer, experts) in rx {
                        for e in experts {
                            c.prefetch(layer, e);
                        }
                    }
                })
                .expect("spawning prefetcher thread");
            (Some(tx), Some(worker))
        } else {
            (None, None)
        };
        Prefetcher {
            mode,
            cache,
            predictor,
            tx,
            worker,
            paused: AtomicBool::new(false),
        }
    }

    /// Suppress (or resume) speculative loads — the memory governor's
    /// rung-1 pressure action.
    pub fn set_paused(&self, on: bool) {
        self.paused.store(on, Relaxed);
    }

    pub fn is_paused(&self) -> bool {
        self.paused.load(Relaxed)
    }

    /// Feed one layer's routed expert set; predicts and (unless `Off`
    /// or paused under memory pressure) loads the next layer's
    /// candidates.
    pub fn note_routing(&self, layer: usize, selected: &[usize]) {
        if self.mode == PrefetchMode::Off
            || selected.is_empty()
            || self.paused.load(Relaxed)
        {
            return;
        }
        let (next, predicted) =
            self.predictor.lock().unwrap().observe(layer, selected);
        crate::obs::instant(crate::obs::Cat::Expert, "prefetch_predicted",
                            crate::obs::args2(
                                "layer", next as u64,
                                "candidates", predicted.len() as u64));
        match (&self.mode, &self.tx) {
            (PrefetchMode::Sync, _) => {
                for e in predicted {
                    self.cache.prefetch(next, e);
                }
            }
            (PrefetchMode::Async, Some(tx)) => {
                // never block the decode loop: a Full error means the
                // worker is behind and this prediction is best dropped
                let _: Result<(), TrySendError<_>> =
                    tx.try_send((next, predicted));
            }
            _ => {}
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // closing the channel ends the worker's recv loop
        self.tx = None;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predictor_learns_coactivation() {
        let mut p = Predictor::new(2, 4, None);
        // teach: layer 0 {0} -> layer 1 {2, 3}, twice
        for _ in 0..2 {
            p.observe(0, &[0]);
            p.observe(1, &[2, 3]);
        }
        let (next, pred) = p.observe(0, &[0]);
        assert_eq!(next, 1);
        assert_eq!(pred.len(), 1);
        assert!([2usize, 3].contains(&pred[0]), "{pred:?}");
    }

    #[test]
    fn predictor_wraps_last_layer_to_first() {
        let mut p = Predictor::new(2, 4, None);
        p.observe(1, &[1]);
        // layer 1 -> layer 0 crosses the token boundary
        p.observe(0, &[3]);
        let (next, pred) = p.observe(1, &[1]);
        assert_eq!(next, 0);
        // the learned transition 1@L1 -> 3@L0 dominates
        assert_eq!(pred, vec![3]);
    }

    #[test]
    fn priors_warm_the_first_prediction() {
        let priors = ResidencyPriors {
            phi: vec![vec![0.0, 0.0, 0.9, 0.1], vec![0.8, 0.1, 0.1, 0.0]],
            weight: vec![vec![0.25; 4]; 2],
            recon: vec![vec![0.0; 4]; 2],
        };
        let mut p = Predictor::new(2, 4, Some(&priors));
        // before any observations, layer 0 predicts layer 1's most
        // frequent expert (phi[1][0] = 0.8)
        let (next, pred) = p.observe(0, &[1]);
        assert_eq!(next, 1);
        assert_eq!(pred, vec![0]);
    }
}
