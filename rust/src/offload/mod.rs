//! Expert residency (DESIGN.md §5): serve MoE models whose expert
//! working set exceeds the memory budget.
//!
//! Four parts: [`store::ExpertStore`] (random access to single experts
//! of a segmented `.mcqz` v2 file), [`cache::ExpertCache`] (a
//! byte-budgeted residency map with pin/unpin and significance-blended
//! clock eviction), [`prefetch::Prefetcher`] (co-activation-predicted
//! speculative loads), and the [`ExpertResolver`] seam every expert
//! access in the engine flows through:
//!
//! * [`Resident`] — today's behavior: experts live eagerly in
//!   `Layer::experts`, the resolver is a no-op, and the decode hot
//!   path keeps its zero-allocation contract untouched.
//! * [`CachedResolver`] — layers carry *empty* expert vecs; the
//!   drivers (scoring forward, KV decode, fused batcher step) pin each
//!   layer's routed experts for the duration of its dispatch, feed the
//!   routed set to the prefetcher, and unpin afterwards.
//!
//! Pinning rule: an expert stays pinned from `pin_layer` until the
//! matching `unpin_layer` — the cache never evicts a pinned slot, so
//! weights cannot be freed while a dispatch executes over them.
//! Tokens are bit-exact with the fully-resident run because the cache
//! materializes the same bytes the monolithic loader would
//! (`tests/offload_parity.rs`).

pub mod cache;
pub mod prefetch;
pub mod store;

use std::fmt::Debug;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::moe::model::{Expert, MoeModel};

pub use cache::{ExpertCache, FetchPolicy};
pub use prefetch::{Prefetcher, PrefetchMode};
pub use store::{ExpertStore, ResidencyPriors};

/// Typed "this expert cannot be materialized right now" signal: the
/// (layer, expert) exhausted its fetch retries and sits in quarantine.
/// Deliberately *not* an `anyhow::Error` — it is an expected serving
/// condition the dispatch path degrades around (renormalize the
/// surviving routed weights, the paper's Eq.-6 pruning), never an
/// unwind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExpertUnavailable {
    pub layer: usize,
    pub expert: usize,
}

impl std::fmt::Display for ExpertUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "expert unavailable (layer {}, expert {}): \
                   fetch retries exhausted, quarantined",
               self.layer, self.expert)
    }
}

impl std::error::Error for ExpertUnavailable {}

/// How a model's experts are materialized for execution. One seam for
/// every driver: `moe/exec/dispatch.rs` consumes the pinned slots,
/// `coordinator/decode.rs` and `MoeModel::forward` drive
/// pin → dispatch → unpin per layer.
pub trait ExpertResolver: Send + Sync + Debug {
    /// Experts owned eagerly in `Layer::experts`. When true, drivers
    /// bypass the resolver entirely (the zero-cost path).
    fn is_resident(&self) -> bool;

    /// Pin every expert in `needed` (unique ids) of `layer` into
    /// `pins` — a caller-owned slot vec indexed by expert id, cleared
    /// and refilled here so steady-state callers reuse its capacity.
    /// Pins hold until [`ExpertResolver::unpin_layer`].
    ///
    /// Returns the number of `needed` experts that could NOT be
    /// materialized (quarantined after fetch failures) — their slots
    /// stay `None` and the caller degrades dispatch around them via
    /// [`degrade_topk`]. Zero on every healthy path.
    fn pin_layer(&self, layer: usize, needed: &[usize],
                 pins: &mut Vec<Option<Arc<Expert>>>) -> usize;

    /// Release the pins taken by the matching `pin_layer` (safe to
    /// pass the full `needed` set even when some experts never pinned:
    /// the cache tolerates unpinning absent slots).
    fn unpin_layer(&self, layer: usize, needed: &[usize]);

    /// Report the routed expert set of `layer` (drives the
    /// co-activation predictor and its prefetch loads).
    fn note_routing(&self, layer: usize, selected: &[usize]);

    /// A dispatch ran without one or more routed experts (degraded
    /// mode). Default no-op; the cached resolver counts it.
    fn note_degraded(&self) {}

    /// Total expert storage bytes behind this resolver (None when the
    /// experts are resident and countable from the layers).
    fn expert_bytes(&self) -> Option<usize> {
        None
    }

    /// Residency byte budget (None = unbudgeted / fully resident).
    fn budget_bytes(&self) -> Option<u64> {
        None
    }

    /// Metrics sink the cache records into (hit/miss/prefetch/stall);
    /// serving facades adopt it so one snapshot covers both worlds.
    fn metrics(&self) -> Option<Arc<Metrics>> {
        None
    }

    /// Memory-governor rung-1 hook: stop (or resume) speculative
    /// prefetch loads. Default no-op (resident models have none).
    fn pause_prefetch(&self, _on: bool) {}

    /// Memory-governor rung-2 hook: halve (or restore) the effective
    /// expert-cache byte budget. Default no-op.
    fn shrink_budget(&self, _on: bool) {}

    /// Live `(resident, quarantined)` flags per `[layer][expert]` for
    /// serve-tier introspection (`/debug/experts`). `None` when the
    /// experts are eagerly resident (everything is, trivially).
    fn residency(&self) -> Option<(Vec<Vec<bool>>, Vec<Vec<bool>>)> {
        None
    }
}

/// Today's behavior: all experts in RAM, resolver is a no-op.
#[derive(Debug, Clone, Copy, Default)]
pub struct Resident;

impl ExpertResolver for Resident {
    fn is_resident(&self) -> bool {
        true
    }

    fn pin_layer(&self, _layer: usize, _needed: &[usize],
                 _pins: &mut Vec<Option<Arc<Expert>>>) -> usize {
        0
    }

    fn unpin_layer(&self, _layer: usize, _needed: &[usize]) {}

    fn note_routing(&self, _layer: usize, _selected: &[usize]) {}
}

/// The default resolver every eagerly-loaded model carries.
pub fn resident() -> Arc<dyn ExpertResolver> {
    Arc::new(Resident)
}

/// Byte-budgeted residency over an on-disk `ExpertStore`.
#[derive(Debug)]
pub struct CachedResolver {
    cache: Arc<ExpertCache>,
    prefetcher: Prefetcher,
    metrics: Arc<Metrics>,
    n_experts: usize,
    expert_bytes: usize,
    budget: usize,
}

impl CachedResolver {
    pub fn cache(&self) -> &Arc<ExpertCache> {
        &self.cache
    }
}

impl ExpertResolver for CachedResolver {
    fn is_resident(&self) -> bool {
        false
    }

    fn pin_layer(&self, layer: usize, needed: &[usize],
                 pins: &mut Vec<Option<Arc<Expert>>>) -> usize {
        pins.clear();
        pins.resize(self.n_experts, None);
        let mut unavailable = 0usize;
        for &e in needed {
            match self.cache.try_get_pinned(layer, e) {
                Ok(x) => pins[e] = Some(x),
                Err(_) => unavailable += 1,
            }
        }
        unavailable
    }

    fn unpin_layer(&self, layer: usize, needed: &[usize]) {
        for &e in needed {
            self.cache.unpin(layer, e);
        }
    }

    fn note_routing(&self, layer: usize, selected: &[usize]) {
        self.prefetcher.note_routing(layer, selected);
    }

    fn note_degraded(&self) {
        Metrics::inc(&self.metrics.degraded_dispatches, 1);
    }

    fn expert_bytes(&self) -> Option<usize> {
        Some(self.expert_bytes)
    }

    fn budget_bytes(&self) -> Option<u64> {
        Some(self.budget as u64)
    }

    fn metrics(&self) -> Option<Arc<Metrics>> {
        Some(self.metrics.clone())
    }

    fn pause_prefetch(&self, on: bool) {
        self.prefetcher.set_paused(on);
    }

    fn shrink_budget(&self, on: bool) {
        self.cache.set_pressure_shrink(on);
    }

    fn residency(&self) -> Option<(Vec<Vec<bool>>, Vec<Vec<bool>>)> {
        Some(self.cache.residency_snapshot())
    }
}

/// Open a segmented `.mcqz` v2 file for serving under `budget_bytes`
/// of expert residency: the model head loads eagerly, experts resolve
/// through the cache + prefetcher. The returned model's `resolver`
/// carries the `Metrics` the cache records into
/// (`model.resolver.metrics()`), which `McEngine`/`Server` adopt.
pub fn load_cached(path: &Path, budget_bytes: usize,
                   mode: PrefetchMode) -> Result<MoeModel> {
    load_cached_with_policy(path, budget_bytes, mode,
                            FetchPolicy::default())
}

/// [`load_cached`] with an explicit retry / quarantine discipline
/// (the chaos bench and fault tests tighten it to force quarantines).
pub fn load_cached_with_policy(path: &Path, budget_bytes: usize,
                               mode: PrefetchMode,
                               policy: FetchPolicy) -> Result<MoeModel> {
    let metrics = Arc::new(Metrics::new());
    let (mut model, store) = ExpertStore::open(path)?;
    let store = Arc::new(store);
    let cfg = store.config().clone();
    let cache = Arc::new(ExpertCache::new(store.clone(), budget_bytes,
                                          metrics.clone()));
    cache.set_fetch_policy(policy);
    let prefetcher = Prefetcher::new(cache.clone(), cfg.n_layers,
                                     cfg.n_experts, store.priors(), mode);
    model.resolver = Arc::new(CachedResolver {
        cache,
        prefetcher,
        metrics,
        n_experts: cfg.n_experts,
        expert_bytes: store.total_expert_bytes(),
        budget: budget_bytes,
    });
    Ok(model)
}

/// Collect the unique experts routed to in `topk`, ascending — the
/// per-layer pin set. `out` is reused by steady-state callers.
pub fn unique_experts(topk: &[Vec<(usize, f32)>], out: &mut Vec<usize>) {
    out.clear();
    for sel in topk {
        for &(e, _) in sel {
            out.push(e);
        }
    }
    out.sort_unstable();
    out.dedup();
}

/// Degraded dispatch (DESIGN.md §7): drop routed selections whose
/// expert has no pinned slot and renormalize each token's surviving
/// weights — exactly the paper's Eq.-6 online-pruning arithmetic, with
/// "unavailable" standing in for "pruned". A token that loses every
/// expert keeps an empty selection: its FFN contribution is zero and
/// the residual stream carries it (ODP's drop-all case). Returns the
/// number of selections dropped; callers report a degraded dispatch
/// via [`ExpertResolver::note_degraded`] when it is non-zero.
pub fn degrade_topk(topk: &mut [Vec<(usize, f32)>],
                    pins: &[Option<Arc<Expert>>]) -> usize {
    let mut dropped = 0usize;
    for sel in topk.iter_mut() {
        let before = sel.len();
        sel.retain(|&(e, _)| pins.get(e).is_some_and(|p| p.is_some()));
        if sel.len() == before {
            continue;
        }
        dropped += before - sel.len();
        let sum: f32 = sel.iter().map(|&(_, w)| w).sum();
        if sum > 0.0 {
            for s in sel.iter_mut() {
                s.1 /= sum;
            }
        }
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;
    use crate::moe::qz;

    #[test]
    fn unique_experts_sorts_and_dedups() {
        let topk = vec![
            vec![(3usize, 0.5f32), (1, 0.5)],
            vec![(1, 1.0)],
            vec![(0, 0.7), (3, 0.3)],
        ];
        let mut out = vec![9, 9, 9];
        unique_experts(&topk, &mut out);
        assert_eq!(out, vec![0, 1, 3]);
    }

    #[test]
    fn degrade_topk_renormalizes_survivors() {
        // experts 0 and 2 pinned, 1 and 3 unavailable
        let dummy = || {
            Some(Arc::new(crate::moe::model::Expert {
                w1: crate::quant::QTensor::F32(
                    crate::tensor::Mat::zeros(1, 1)),
                w3: crate::quant::QTensor::F32(
                    crate::tensor::Mat::zeros(1, 1)),
                w2: crate::quant::QTensor::F32(
                    crate::tensor::Mat::zeros(1, 1)),
            }))
        };
        let pins = vec![dummy(), None, dummy(), None];
        let mut topk = vec![
            vec![(0usize, 0.6f32), (1, 0.4)], // loses 1, renormalizes
            vec![(0, 0.5), (2, 0.5)],         // untouched
            vec![(1, 0.7), (3, 0.3)],         // loses everything
        ];
        let dropped = degrade_topk(&mut topk, &pins);
        assert_eq!(dropped, 3);
        assert_eq!(topk[0].len(), 1);
        assert_eq!(topk[0][0].0, 0);
        assert!((topk[0][0].1 - 1.0).abs() < 1e-6, "renormalized to 1");
        assert_eq!(topk[1], vec![(0, 0.5), (2, 0.5)], "healthy untouched");
        assert!(topk[2].is_empty(), "drop-all leaves residual-only token");
        // a second pass over the degraded set is a no-op
        assert_eq!(degrade_topk(&mut topk, &pins), 0);
    }

    #[test]
    fn cached_model_scores_bit_exact() {
        // the scoring forward also flows through the resolver seam
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 11);
        let path = std::env::temp_dir()
            .join(format!("offload_score_{}.mcqz", std::process::id()));
        qz::save(&path, &m).unwrap();
        let expert_bytes: usize = m.layers.iter().flat_map(|l| &l.experts)
            .map(|e| e.storage_bytes()).sum();
        let cached = load_cached(&path, expert_bytes / 2,
                                 PrefetchMode::Sync).unwrap();
        assert!(!cached.resolver.is_resident());
        assert!(cached.layers.iter().all(|l| l.experts.is_empty()));
        assert_eq!(cached.resolver.expert_bytes(), Some(expert_bytes));
        let toks: Vec<u32> = (1..25).collect();
        assert_eq!(m.score(&toks).data, cached.score(&toks).data,
                   "budget-capped scoring must be bit-exact");
        // accounting through the model surface still works
        assert_eq!(cached.storage_bytes(), m.storage_bytes());
        assert!((cached.expert_avg_bits() - m.expert_avg_bits()).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
    }
}
