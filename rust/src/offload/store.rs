//! `ExpertStore` — random access to single experts of a segmented
//! `.mcqz` v2 file (DESIGN.md §5).
//!
//! `open` reads the header and the non-expert region only, so the
//! model head materializes without touching expert bytes; `fetch`
//! reads one expert's contiguous segment with a single seek +
//! `read_exact` and decodes its three tensors in place. This is the
//! I/O half of the pre-loading story: the cache above it decides
//! *which* experts deserve residency, the store makes any of them
//! reachable in one bounded read.

use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::moe::model::{Expert, MoeModel};
use crate::moe::qz;
use crate::pmq::significance::Significance;
use crate::util::crc32::crc32;
use crate::util::faults::{self, Site};
use crate::util::json::{arr, num, obj, Json};

/// Calibration-time significance factors shipped in the v2 header:
/// the cache blends them into its eviction score and the prefetcher
/// warms its co-activation table from the frequencies.
#[derive(Debug, Clone, Default)]
pub struct ResidencyPriors {
    /// activation frequency per [layer][expert] (phi)
    pub phi: Vec<Vec<f64>>,
    /// routing-weight mass per [layer][expert] (w)
    pub weight: Vec<Vec<f64>>,
    /// reconstruction / quantization output error per [layer][expert]
    pub recon: Vec<Vec<f64>>,
}

impl ResidencyPriors {
    pub fn from_significance(sig: &Significance) -> ResidencyPriors {
        ResidencyPriors {
            phi: sig.phi.clone(),
            weight: sig.weight.clone(),
            recon: sig
                .eps
                .iter()
                .map(|layer| {
                    layer
                        .iter()
                        .map(|e| e.iter().map(|&v| v as f64).sum::<f64>() / 3.0)
                        .collect()
                })
                .collect(),
        }
    }

    /// Blend the three factors into one max-normalized significance
    /// score per (layer, expert) in [0, 1].
    pub fn scores(&self) -> Vec<Vec<f64>> {
        let norm = |v: &Vec<Vec<f64>>| -> Vec<Vec<f64>> {
            let max = v
                .iter()
                .flatten()
                .cloned()
                .fold(0.0f64, f64::max)
                .max(1e-12);
            v.iter().map(|r| r.iter().map(|x| x / max).collect()).collect()
        };
        let (p, w, r) = (norm(&self.phi), norm(&self.weight), norm(&self.recon));
        p.iter()
            .zip(&w)
            .zip(&r)
            .map(|((pr, wr), rr)| {
                pr.iter()
                    .zip(wr)
                    .zip(rr)
                    .map(|((a, b), c)| (a + b + c) / 3.0)
                    .collect()
            })
            .collect()
    }

    /// Arity check against the model shape: the cache and predictor
    /// index `[layer][expert]` without bounds slack, so a mismatched
    /// priors block is a malformed file, not a latent panic.
    pub(crate) fn validate(&self, n_layers: usize,
                           n_experts: usize) -> Result<()> {
        for (name, v) in [("phi", &self.phi), ("weight", &self.weight),
                          ("recon", &self.recon)] {
            if v.len() != n_layers
                || v.iter().any(|row| row.len() != n_experts)
            {
                bail!(
                    "priors.{name} arity mismatch: expected \
                     {n_layers}x{n_experts} (layers x experts)"
                );
            }
        }
        Ok(())
    }

    pub(crate) fn to_json(&self) -> Json {
        let f = |v: &Vec<Vec<f64>>| {
            arr(v.iter().map(|r| arr(r.iter().map(|&x| num(x)))))
        };
        obj(vec![
            ("phi", f(&self.phi)),
            ("weight", f(&self.weight)),
            ("recon", f(&self.recon)),
        ])
    }

    pub(crate) fn from_json(j: &Json) -> Result<ResidencyPriors> {
        let f = |key: &str| -> Result<Vec<Vec<f64>>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|row| -> Result<Vec<f64>> {
                    row.as_arr()?.iter().map(|v| v.as_f64()).collect()
                })
                .collect()
        };
        Ok(ResidencyPriors {
            phi: f("phi")?,
            weight: f("weight")?,
            recon: f("recon")?,
        })
    }
}

#[derive(Debug, Clone, Copy)]
struct Segment {
    /// absolute payload offset of the expert's byte range
    off: usize,
    len: usize,
    /// crc32 of the segment bytes; `None` for directories written
    /// before checksums existed (re-saving the file backfills them)
    crc: Option<u32>,
}

#[derive(Debug)]
struct ExpertMeta {
    seg: Segment,
    /// header metadata of w1 / w3 / w2 (offsets absolute in payload)
    tensors: [Json; 3],
    /// exact `QTensor::storage_bytes` of the materialized expert
    storage_bytes: usize,
}

/// Random-access reader over the expert segments of a `.mcqz` v2 file.
#[derive(Debug)]
pub struct ExpertStore {
    file: Mutex<std::fs::File>,
    payload_off: u64,
    cfg: ModelConfig,
    metas: Vec<Vec<ExpertMeta>>,
    priors: Option<ResidencyPriors>,
    total_storage_bytes: usize,
}

impl ExpertStore {
    /// Open a v2 file: parse the header, materialize the model head
    /// (everything except experts — their layer vecs come back empty),
    /// and index the expert directory for `fetch`.
    pub fn open(path: &Path) -> Result<(MoeModel, ExpertStore)> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("opening {path:?}"))?;
        let mut fixed = [0u8; 12];
        file.read_exact(&mut fixed).context("reading MCQZ header")?;
        if &fixed[0..4] != qz::MAGIC {
            bail!("bad MCQZ magic");
        }
        let version = u32::from_le_bytes(fixed[4..8].try_into().unwrap());
        if version != qz::VERSION {
            bail!(
                "expert offload needs a segmented .mcqz v2 file (got \
                 version {version}); re-save the model with this build"
            );
        }
        let hlen = u32::from_le_bytes(fixed[8..12].try_into().unwrap()) as usize;
        let mut hbytes = vec![0u8; hlen];
        file.read_exact(&mut hbytes).context("reading MCQZ header json")?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)?;
        let payload_off = (12 + hlen) as u64;

        // the non-expert region is payload[..experts_off] by the v2
        // layout contract; it alone materializes the model head
        let experts_off = header.get("experts_off")?.as_usize()?;
        let mut head = vec![0u8; experts_off];
        file.read_exact(&mut head).context("reading non-expert region")?;
        let model = qz::build_model(&header, &head, false)?;
        let cfg = model.cfg.clone();

        let dir = header.get("expert_dir")?.as_arr()?;
        if dir.len() != cfg.n_layers {
            bail!("expert_dir layer arity mismatch");
        }
        let tensors = header.get("tensors")?;
        let mut metas = Vec::with_capacity(cfg.n_layers);
        let mut total = 0usize;
        for (l, row) in dir.iter().enumerate() {
            let row = row.as_arr()?;
            if row.len() != cfg.n_experts {
                bail!("expert_dir expert arity mismatch at layer {l}");
            }
            let mut layer_metas = Vec::with_capacity(cfg.n_experts);
            for (e, seg) in row.iter().enumerate() {
                let seg = Segment {
                    off: seg.get("off")?.as_usize()?,
                    len: seg.get("len")?.as_usize()?,
                    crc: match seg.opt("crc") {
                        Some(c) => Some(c.as_usize()? as u32),
                        None => None,
                    },
                };
                let meta = |w: &str| -> Result<Json> {
                    Ok(tensors
                        .get(&format!("layers.{l}.experts.{e}.{w}"))?
                        .clone())
                };
                let tensors = [meta("w1")?, meta("w3")?, meta("w2")?];
                let storage_bytes = tensors
                    .iter()
                    .map(qz::entry_storage_bytes)
                    .sum::<Result<usize>>()?;
                total += storage_bytes;
                layer_metas.push(ExpertMeta { seg, tensors, storage_bytes });
            }
            metas.push(layer_metas);
        }
        let priors = match header.opt("priors") {
            Some(p) => {
                let p = ResidencyPriors::from_json(p)?;
                p.validate(cfg.n_layers, cfg.n_experts)?;
                Some(p)
            }
            None => None,
        };
        let store = ExpertStore {
            file: Mutex::new(file),
            payload_off,
            cfg,
            metas,
            priors,
            total_storage_bytes: total,
        };
        Ok((model, store))
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn priors(&self) -> Option<&ResidencyPriors> {
        self.priors.as_ref()
    }

    /// Exact `storage_bytes` of one expert once materialized (the unit
    /// the cache budget is accounted in).
    pub fn expert_storage_bytes(&self, layer: usize, expert: usize) -> usize {
        self.metas[layer][expert].storage_bytes
    }

    /// Sum of all experts' storage bytes (the paper's expert "Params").
    pub fn total_expert_bytes(&self) -> usize {
        self.total_storage_bytes
    }

    /// Read + decode one expert: a single seek + `read_exact` of its
    /// segment, then in-place tensor decode. Never touches the rest of
    /// the file. The segment's crc32 is re-verified on every read, so
    /// disk corruption surfaces as a typed `Err` here instead of a
    /// garbage expert downstream.
    pub fn fetch(&self, layer: usize, expert: usize) -> Result<Expert> {
        self.fetch_at(layer, expert, Site::Demand)
    }

    /// Prefetch-path fetch: identical I/O, but draws injected faults
    /// from the prefetch site so a chaos plan perturbs speculative and
    /// demand traffic independently.
    pub(crate) fn fetch_speculative(&self, layer: usize,
                                    expert: usize) -> Result<Expert> {
        self.fetch_at(layer, expert, Site::Prefetch)
    }

    fn fetch_at(&self, layer: usize, expert: usize,
                site: Site) -> Result<Expert> {
        let fault = faults::plan();
        if let Some(fp) = &fault {
            if let Some(d) = fp.delay(site) {
                std::thread::sleep(d);
            }
            if fp.io_error(site) {
                bail!("injected I/O error (layer {layer}, expert {expert})");
            }
        }
        let meta = &self.metas[layer][expert];
        let mut buf = vec![0u8; meta.seg.len];
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(self.payload_off + meta.seg.off as u64))?;
            f.read_exact(&mut buf).with_context(|| {
                format!("reading expert segment (layer {layer}, expert {expert})")
            })?;
        }
        if let Some(fp) = &fault {
            if !buf.is_empty() && fp.corrupt(site) {
                buf[meta.seg.len / 2] ^= 0x01; // caught by the crc below
            }
        }
        if let Some(want) = meta.seg.crc {
            let got = crc32(&buf);
            if got != want {
                bail!("expert segment checksum mismatch (layer {layer}, \
                       expert {expert}): crc32 {got:#010x} != {want:#010x}");
            }
        }
        let r = qz::Reader { payload: &buf, base: meta.seg.off };
        Ok(Expert {
            w1: r.qtensor(&meta.tensors[0])?,
            w3: r.qtensor(&meta.tensors[1])?,
            w2: r.qtensor(&meta.tensors[2])?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::model::tests::random_model;
    use crate::quant::quantize_rtn;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("{name}_{}.mcqz", std::process::id()))
    }

    fn quantized_model() -> MoeModel {
        let cfg = ModelConfig::test_tiny();
        let mut m = random_model(&cfg, 7);
        for layer in m.layers.iter_mut() {
            for (e, bits) in [(0usize, 2usize), (1, 3), (2, 1)] {
                let ex = &mut layer.experts[e];
                ex.w1 = quantize_rtn(&ex.w1.dequantize(), bits);
                ex.w3 = quantize_rtn(&ex.w3.dequantize(), bits);
                ex.w2 = quantize_rtn(&ex.w2.dequantize(), bits);
            }
        }
        m
    }

    #[test]
    fn fetch_matches_full_load_bit_exact() {
        let m = quantized_model();
        let path = tmp("store_fetch");
        qz::save(&path, &m).unwrap();
        let (head, store) = ExpertStore::open(&path).unwrap();
        assert_eq!(head.cfg, m.cfg);
        assert!(head.layers.iter().all(|l| l.experts.is_empty()));
        let mut total = 0usize;
        for l in 0..m.cfg.n_layers {
            for e in 0..m.cfg.n_experts {
                let got = store.fetch(l, e).unwrap();
                let want = &m.layers[l].experts[e];
                assert_eq!(got.w1.dequantize().data, want.w1.dequantize().data);
                assert_eq!(got.w3.dequantize().data, want.w3.dequantize().data);
                assert_eq!(got.w2.dequantize().data, want.w2.dequantize().data);
                assert_eq!(got.storage_bytes(),
                           store.expert_storage_bytes(l, e));
                total += got.storage_bytes();
            }
        }
        assert_eq!(store.total_expert_bytes(), total);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_rejects_v1() {
        let m = quantized_model();
        let path = tmp("store_v1");
        qz::save_v1(&path, &m).unwrap();
        assert!(ExpertStore::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_rejects_malformed_containers() {
        let path = tmp("store_malformed");
        // bad magic
        std::fs::write(&path, b"NOPE00000000").unwrap();
        assert!(ExpertStore::open(&path).is_err());
        // truncated fixed prelude
        std::fs::write(&path, b"MCQZ").unwrap();
        assert!(ExpertStore::open(&path).is_err());
        // header length pointing past EOF
        let mut bytes = Vec::new();
        bytes.extend_from_slice(qz::MAGIC);
        bytes.extend_from_slice(&qz::VERSION.to_le_bytes());
        bytes.extend_from_slice(&(1u32 << 20).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(ExpertStore::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fetch_detects_corrupt_segment_and_truncation() {
        let m = quantized_model();
        let path = tmp("store_corrupt");
        qz::save(&path, &m).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let (_, header, payload_off) = qz::parse_container(&clean).unwrap();
        let seg0 = &header.get("expert_dir").unwrap().as_arr().unwrap()[0]
            .as_arr().unwrap()[0];
        let off = payload_off + seg0.get("off").unwrap().as_usize().unwrap();
        let len = seg0.get("len").unwrap().as_usize().unwrap();

        // flipped bit inside expert (0, 0): only that fetch fails, and
        // it fails with a typed checksum error, not a panic
        let mut corrupt = clean.clone();
        corrupt[off + len / 3] ^= 0x40;
        std::fs::write(&path, &corrupt).unwrap();
        let (_, store) = ExpertStore::open(&path).unwrap();
        let err = store.fetch(0, 0).expect_err("corrupt segment");
        assert!(format!("{err:#}").contains("checksum mismatch"),
                "{err:#}");
        assert!(store.fetch(0, 1).is_ok(), "sibling experts unaffected");

        // truncated expert region: open still succeeds (header + head
        // are intact), the fetch of the missing segment is an Err
        std::fs::write(&path, &clean[..off + len / 2]).unwrap();
        let (_, store) = ExpertStore::open(&path).unwrap();
        assert!(store.fetch(0, 0).is_err(), "truncated segment");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn priors_roundtrip_and_scores_normalize() {
        let m = quantized_model();
        let priors = ResidencyPriors {
            phi: vec![vec![0.5, 0.25, 0.125, 0.125]; m.cfg.n_layers],
            weight: vec![vec![0.4, 0.3, 0.2, 0.1]; m.cfg.n_layers],
            recon: vec![vec![1.0, 2.0, 3.0, 4.0]; m.cfg.n_layers],
        };
        let path = tmp("store_priors");
        qz::save_with_priors(&path, &m, Some(&priors)).unwrap();
        let (_, store) = ExpertStore::open(&path).unwrap();
        let got = store.priors().expect("priors survive the roundtrip");
        assert_eq!(got.phi, priors.phi);
        assert_eq!(got.weight, priors.weight);
        assert_eq!(got.recon, priors.recon);
        let scores = got.scores();
        assert!(scores
            .iter()
            .flatten()
            .all(|&s| (0.0..=1.0).contains(&s)));
        // the most frequent+heavy+fragile expert scores highest
        assert!(scores[0][0] > scores[0][1] || scores[0][3] == 1.0);
        std::fs::remove_file(&path).ok();
    }
}
