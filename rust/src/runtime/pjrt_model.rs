//! Full-model PJRT scorer: executes `model_fwd.hlo.txt` (the L2 JAX
//! forward with L1 Pallas kernels inlined) with weights passed as
//! runtime arguments in canonical sorted-name order.
//!
//! This is the fast whole-sequence scoring path of the serving stack;
//! the component artifacts (gate / expert_ffn_* / attention) cover the
//! ODP-dynamic path driven by `coordinator`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::moe::weights::WeightFile;
use crate::tensor::Mat;

use super::{lit_f32, lit_i32, mat_from_lit, Runtime};

pub struct PjrtModel {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    /// inputs[0] = tokens (rewritten per call), inputs[1..] = weights
    /// in manifest.param_order — uploaded once, reused across calls.
    inputs: Vec<xla::Literal>,
}

impl PjrtModel {
    /// Load config + weights + model_fwd artifact from `dir`.
    pub fn load(dir: &Path) -> Result<PjrtModel> {
        let cfg = ModelConfig::load(&dir.join("config.json"))?;
        let wf = WeightFile::load(&dir.join("weights.mcwt"))?;
        let mut rt = Runtime::cpu(dir)?;
        rt.load("model_fwd")?;
        let mut inputs = vec![lit_i32(&vec![0; cfg.max_seq], &[cfg.max_seq])?];
        for name in rt.manifest.param_order.clone() {
            let t = wf.get(&name).with_context(|| name.clone())?;
            inputs.push(lit_f32(&t.data, &t.shape)?);
        }
        Ok(PjrtModel { rt, cfg, inputs })
    }

    /// Score a full sequence; pads to max_seq (the artifact's static
    /// shape) and returns logits for the original length.
    ///
    /// The exported forward is causal, so right-padding is exact for
    /// the positions we keep.
    pub fn score(&mut self, tokens: &[u32]) -> Result<Mat> {
        let s = self.cfg.max_seq;
        if tokens.len() > s {
            bail!("sequence longer than max_seq {s}");
        }
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(s, 0);
        self.inputs[0] = lit_i32(&padded, &[s])?;
        let outs = self.rt.execute("model_fwd", &self.inputs)?;
        let logits = mat_from_lit(&outs[0], s, self.cfg.vocab_size)?;
        Ok(logits.slice_rows(0, tokens.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::artifacts_dir;
    use crate::moe::MoeModel;

    /// The L3-runtime keystone: PJRT execution of the AOT artifact must
    /// agree with the native rust engine (which itself matches JAX via
    /// golden_parity).
    #[test]
    fn pjrt_matches_native_engine() {
        let dir = artifacts_dir();
        if !dir.join("model_fwd.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut pm = PjrtModel::load(&dir).unwrap();
        let wf = WeightFile::load(&dir.join("weights.mcwt")).unwrap();
        let native = MoeModel::load_f32(&pm.cfg, wf).unwrap();
        let tokens: Vec<u32> = (0..64u32).map(|i| (i * 31) % 200 + 1).collect();
        let want = native.score(&tokens);
        let got = pm.score(&tokens).unwrap();
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        let mut max_rel = 0.0f32;
        for (g, w) in got.data.iter().zip(&want.data) {
            max_rel = max_rel.max((g - w).abs() / (1.0 + w.abs()));
        }
        assert!(max_rel < 5e-3, "PJRT vs native: max_rel {max_rel}");
    }
}
