//! `artifacts/manifest.json` parsing: artifact -> ordered input/output
//! specs, plus the canonical parameter order for `model_fwd`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config_name: String,
    /// sorted tensor-name order for model_fwd's trailing params
    pub param_order: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let param_order = j
            .get("param_order")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut artifacts = Vec::new();
        for (name, spec) in j.get("artifacts")?.as_obj()? {
            let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
                spec.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io.get("name")?.as_str()?.to_string(),
                            shape: io
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|s| s.as_usize())
                                .collect::<Result<_>>()?,
                            dtype: io
                                .opt("dtype")
                                .map(|d| d.as_str().map(String::from))
                                .transpose()?
                                .unwrap_or_else(|| "f32".into()),
                        })
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                inputs: parse_io("inputs")?,
                outputs: parse_io("outputs")?,
            });
        }
        Ok(Manifest {
            config_name: j.get("config")?.as_str()?.to_string(),
            param_order,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": "tiny",
      "param_order": ["a", "b"],
      "artifacts": {
        "gate": {
          "inputs": [
            {"name": "x", "shape": [128, 128], "dtype": "f32"},
            {"name": "wg", "shape": [128, 8], "dtype": "f32"}
          ],
          "outputs": [{"name": "probs", "shape": [128, 8]}]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config_name, "tiny");
        assert_eq!(m.param_order, vec!["a", "b"]);
        let g = m.artifact("gate").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].shape, vec![128, 128]);
        assert_eq!(g.outputs[0].name, "probs");
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let path = crate::config::artifacts_dir().join("manifest.json");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&path).unwrap();
        for name in ["model_fwd", "gate", "expert_ffn_f32", "expert_ffn_q2",
                     "expert_ffn_q3", "expert_ffn_b1", "attention",
                     "token_importance"] {
            assert!(m.artifact(name).is_ok(), "{name} missing");
        }
        // model_fwd inputs = tokens + all params
        let mf = m.artifact("model_fwd").unwrap();
        assert_eq!(mf.inputs.len(), 1 + m.param_order.len());
    }
}
