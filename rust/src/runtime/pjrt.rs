//! PJRT client plumbing (compiled only with the `pjrt` feature, which
//! needs the vendored `xla` bindings crate): loads the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client — the request-path never touches python
//! (DESIGN.md §3).
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::Mat;

use super::artifacts::Manifest;

/// A compiled artifact registry over one PJRT client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at the artifacts directory.
    pub fn cpu(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            exes: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) one artifact by name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact; returns the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let bufs = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // artifacts are lowered with return_tuple=True
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }
}

// --- Literal <-> native conversions -----------------------------------------

pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape f32 literal: {e:?}"))
}

pub fn lit_mat(m: &Mat) -> Result<xla::Literal> {
    lit_f32(&m.data, &[m.rows, m.cols])
}

pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape i32 literal: {e:?}"))
}

pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape u32 literal: {e:?}"))
}

/// Read a 2-D f32 literal back into a Mat.
pub fn mat_from_lit(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    anyhow::ensure!(v.len() == rows * cols, "literal size mismatch");
    Ok(Mat::from_vec(rows, cols, v))
}
