//! PJRT runtime: executes the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` (DESIGN.md §3).
//!
//! The XLA-backed pieces (`Runtime`, `components`, the real
//! `PjrtModel`) require the vendored `xla` bindings crate and are
//! gated behind the `pjrt` cargo feature; offline images without it
//! build the default feature set, where `PjrtModel` is a stub whose
//! constructor errors. `artifacts` (manifest parsing) is pure rust and
//! always available.

pub mod artifacts;

pub use artifacts::Manifest;

#[cfg(feature = "pjrt")]
pub mod components;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod pjrt_model;

#[cfg(feature = "pjrt")]
pub use pjrt::{lit_f32, lit_i32, lit_mat, lit_u32, mat_from_lit, Runtime};
#[cfg(feature = "pjrt")]
pub use pjrt_model::PjrtModel;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::tensor::Mat;

    /// API-compatible stand-in when the `pjrt` feature is off: loading
    /// always errors, so callers fall back to the native engine.
    pub struct PjrtModel;

    impl PjrtModel {
        pub fn load(_dir: &Path) -> Result<PjrtModel> {
            bail!("mc-moe was built without the `pjrt` feature");
        }

        pub fn score(&mut self, _tokens: &[u32]) -> Result<Mat> {
            bail!("mc-moe was built without the `pjrt` feature");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtModel;
