//! Component executors: run the per-layer AOT artifacts (gate,
//! expert_ffn_{f32,q2,q3,b1}, attention, token_importance) through
//! PJRT with weights as runtime arguments — the building blocks of the
//! PJRT-backed serving path. The coordinator composes these per layer,
//! keeping the data-dependent ODP decisions in rust between calls
//! (DESIGN.md §3).
//!
//! The quantized executors consume the exact packed layout produced by
//! `quant::pack` (tested against the native engine below), proving the
//! L1 Pallas dequant kernels and the rust packer agree bit-for-bit.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::moe::model::Expert;
use crate::quant::QTensor;
use crate::tensor::Mat;

use super::{lit_f32, lit_u32, mat_from_lit, Runtime};

/// Pad-or-truncate a token batch to the artifact's static tile rows.
fn pad_rows(x: &Mat, rows: usize) -> Mat {
    let mut out = Mat::zeros(rows, x.cols);
    let n = x.rows.min(rows);
    out.data[..n * x.cols].copy_from_slice(&x.data[..n * x.cols]);
    out
}

/// Executes one expert FFN artifact matching the expert's bit-width.
pub struct ExpertExec<'rt> {
    rt: &'rt Runtime,
    cfg: ModelConfig,
}

impl<'rt> ExpertExec<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &ModelConfig) -> ExpertExec<'rt> {
        ExpertExec { rt, cfg: cfg.clone() }
    }

    /// Artifact name for an expert's representation.
    pub fn artifact_for(expert: &Expert) -> Result<&'static str> {
        Ok(match (&expert.w1, &expert.w3, &expert.w2) {
            (QTensor::F32(_), QTensor::F32(_), QTensor::F32(_)) => "expert_ffn_f32",
            (QTensor::Packed(a), QTensor::Packed(_), QTensor::Packed(_)) => {
                match a.bits {
                    2 => "expert_ffn_q2",
                    3 => "expert_ffn_q3",
                    b => bail!("no artifact for {b}-bit experts"),
                }
            }
            (QTensor::Binary(_), QTensor::Binary(_), QTensor::Binary(_)) => {
                "expert_ffn_b1"
            }
            _ => bail!("mixed-representation expert"),
        })
    }

    /// Run x[T', D] (T' <= prefill_tile) through `expert` via PJRT.
    pub fn run(&self, expert: &Expert, x: &Mat) -> Result<Mat> {
        let t = self.cfg.prefill_tile;
        if x.rows > t {
            bail!("batch {} exceeds tile {t}", x.rows);
        }
        let name = Self::artifact_for(expert)?;
        let xp = pad_rows(x, t);
        let mut inputs = vec![lit_f32(&xp.data, &[t, self.cfg.d_model])?];
        for w in [&expert.w1, &expert.w3, &expert.w2] {
            match w {
                QTensor::F32(m) => {
                    inputs.push(lit_f32(&m.data, &[m.rows, m.cols])?);
                }
                QTensor::Packed(p) => {
                    inputs.push(lit_u32(&p.qweight, &[p.k_words(), p.n])?);
                    inputs.push(lit_f32(&p.scales, &[p.groups(), p.n])?);
                    inputs.push(lit_f32(&p.zeros, &[p.groups(), p.n])?);
                }
                QTensor::Binary(b) => {
                    inputs.push(lit_u32(&b.packed, &[b.k_words(), b.n])?);
                    inputs.push(lit_f32(&b.scales, &[b.n])?);
                }
            }
        }
        let outs = self.rt.execute(name, &inputs)?;
        let y = mat_from_lit(&outs[0], t, self.cfg.d_model)?;
        Ok(y.slice_rows(0, x.rows))
    }
}

/// Gate executor: router probabilities via the `gate` artifact.
pub struct GateExec<'rt> {
    rt: &'rt Runtime,
    cfg: ModelConfig,
}

impl<'rt> GateExec<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: &ModelConfig) -> GateExec<'rt> {
        GateExec { rt, cfg: cfg.clone() }
    }

    pub fn run(&self, x: &Mat, gate: &Mat) -> Result<Mat> {
        let t = self.cfg.prefill_tile;
        if x.rows > t {
            bail!("batch {} exceeds tile {t}", x.rows);
        }
        let xp = pad_rows(x, t);
        let inputs = vec![
            lit_f32(&xp.data, &[t, self.cfg.d_model])?,
            lit_f32(&gate.data, &[gate.rows, gate.cols])?,
        ];
        let outs = self.rt.execute("gate", &inputs)?;
        let probs = mat_from_lit(&outs[0], t, self.cfg.n_experts)?;
        Ok(probs.slice_rows(0, x.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::artifacts_dir;
    use crate::moe::{MoeModel, WeightFile};
    use crate::quant::{binary::binarize, linear::quantize_groupwise};
    use crate::tensor::softmax_rows;
    use crate::util::rng::Rng;

    fn setup() -> Option<(Runtime, ModelConfig, MoeModel)> {
        let dir = artifacts_dir();
        let cfg = ModelConfig::load(&dir.join("config.json")).ok()?;
        let wf = WeightFile::load(&dir.join("weights.mcwt")).ok()?;
        let model = MoeModel::load_f32(&cfg, wf).ok()?;
        let mut rt = Runtime::cpu(&dir).ok()?;
        for name in ["gate", "expert_ffn_f32", "expert_ffn_q2",
                     "expert_ffn_q3", "expert_ffn_b1"] {
            rt.load(name).ok()?;
        }
        Some((rt, cfg, model))
    }

    fn max_rel(a: &Mat, b: &Mat) -> f32 {
        a.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
            .fold(0.0, f32::max)
    }

    #[test]
    fn pjrt_expert_components_match_native() {
        let Some((rt, cfg, model)) = setup() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let exec = ExpertExec::new(&rt, &cfg);
        let mut rng = Rng::new(0);
        let x = Mat::randn(&mut rng, 13, cfg.d_model, 1.0);
        let fp = &model.layers[0].experts[0];

        // f32 artifact vs native
        let y_pjrt = exec.run(fp, &x).unwrap();
        let y_native = fp.forward(&x);
        assert!(max_rel(&y_pjrt, &y_native) < 5e-3);

        // quantized artifacts vs native quantized expert — proves the
        // rust packer and the L1 Pallas dequant kernel share a layout
        for bits in [2usize, 3] {
            let q = Expert {
                w1: QTensor::Packed(quantize_groupwise(&fp.w1.dequantize(), bits)),
                w3: QTensor::Packed(quantize_groupwise(&fp.w3.dequantize(), bits)),
                w2: QTensor::Packed(quantize_groupwise(&fp.w2.dequantize(), bits)),
            };
            let y_pjrt = exec.run(&q, &x).unwrap();
            let y_native = q.forward(&x);
            assert!(
                max_rel(&y_pjrt, &y_native) < 5e-3,
                "{bits}-bit mismatch: {}",
                max_rel(&y_pjrt, &y_native)
            );
        }

        // binary artifact
        let b = Expert {
            w1: QTensor::Binary(binarize(&fp.w1.dequantize(), false)),
            w3: QTensor::Binary(binarize(&fp.w3.dequantize(), false)),
            w2: QTensor::Binary(binarize(&fp.w2.dequantize(), false)),
        };
        let y_pjrt = exec.run(&b, &x).unwrap();
        let y_native = b.forward(&x);
        assert!(max_rel(&y_pjrt, &y_native) < 5e-3);
    }

    #[test]
    fn pjrt_gate_matches_native() {
        let Some((rt, cfg, model)) = setup() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let exec = GateExec::new(&rt, &cfg);
        let mut rng = Rng::new(1);
        let x = Mat::randn(&mut rng, 9, cfg.d_model, 1.0);
        let probs_pjrt = exec.run(&x, &model.layers[0].gate).unwrap();
        let mut probs_native = x.matmul(&model.layers[0].gate);
        softmax_rows(&mut probs_native);
        assert!(max_rel(&probs_pjrt, &probs_native) < 1e-3);
    }

    #[test]
    fn artifact_selection() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 32, 1.0);
        let f = |t: QTensor| Expert { w1: t.clone(), w3: t.clone(), w2: t };
        assert_eq!(
            ExpertExec::artifact_for(&f(QTensor::F32(w.clone()))).unwrap(),
            "expert_ffn_f32"
        );
        assert_eq!(
            ExpertExec::artifact_for(&f(QTensor::Packed(quantize_groupwise(&w, 2))))
                .unwrap(),
            "expert_ffn_q2"
        );
        assert_eq!(
            ExpertExec::artifact_for(&f(QTensor::Binary(binarize(&w, false)))).unwrap(),
            "expert_ffn_b1"
        );
        assert!(ExpertExec::artifact_for(&Expert {
            w1: QTensor::F32(w.clone()),
            w3: QTensor::Packed(quantize_groupwise(&w, 2)),
            w2: QTensor::F32(w),
        })
        .is_err());
    }
}
