//! Per-connection request handling: route, admit, and stream.
//!
//! The default remains one request per connection (`Connection:
//! close`) — the connection lifecycle *is* the request lifecycle,
//! which makes disconnect semantics exact: a closed socket means the
//! client abandoned the request, and the handler's reply is
//! `RequestHandle::cancel()`, so an abandoned stream can never pin a
//! fused-batcher slot (DESIGN.md §6). Clients that send `Connection:
//! keep-alive` opt into serving further requests on the same socket
//! (bounded by `max_requests_per_conn` and the `keep_alive_idle`
//! timeout); SSE streams and error replies always close — the stream
//! is the rest of the connection, and error states don't deserve a
//! warm socket.
//!
//! Routes:
//!   POST /v1/generate   SSE token stream (or JSON with "stream":false)
//!   GET  /healthz       {"status":"ok"|"draining"}
//!   GET  /metrics       Prometheus text exposition
//!   POST /admin/drain   begin graceful drain (dumps the flight
//!                       recorder when tracing is on)
//!   GET  /debug/trace   Chrome trace-event JSON from the flight
//!                       recorder (`?last_ms=N` trailing window,
//!                       `?enable=1|0` toggles tracing live,
//!                       `?clear=1` empties the ring after rendering)
//!   GET  /debug/experts per-layer expert heat table (activations,
//!                       mean routing weight, residency, quarantine;
//!                       `?clear=1` zeroes the accumulators)

use std::io::ErrorKind;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{RequestHandle, StreamEvent};

use super::admission::Admission;
use super::http::{
    read_request, write_response, write_response_opts, write_sse_event,
    write_sse_head, HttpError, Request,
};
use super::json::{
    cancelled_body, completion_body, error_body, parse_generate, token_body,
};
use super::Shared;

/// Poll interval while an SSE stream waits for the next event; also
/// the granularity of client-disconnect detection between tokens.
const STREAM_POLL: Duration = Duration::from_millis(2);

pub(crate) fn handle(stream: &mut TcpStream, shared: &Shared) {
    let _ = stream.set_write_timeout(Some(shared.cfg.read_timeout));
    let _ = stream.set_nodelay(true);

    let mut served = 0usize;
    loop {
        // the first request gets the full slow-client budget; between
        // kept-alive requests the shorter idle timeout applies so a
        // parked socket frees its pool slot promptly
        let timeout = if served == 0 {
            shared.cfg.read_timeout
        } else {
            shared.cfg.keep_alive_idle
        };
        let _ = stream.set_read_timeout(Some(timeout));
        let req = match read_request(stream, shared.cfg.max_head_bytes,
                                     shared.cfg.max_body_bytes) {
            Ok(req) => req,
            Err(err) => {
                // after a served request, a quiet close or idle expiry
                // is the normal end of a keep-alive session, not a
                // protocol error
                if served > 0
                    && matches!(err,
                                HttpError::Closed | HttpError::Timeout)
                {
                    return;
                }
                Metrics::inc(&shared.metrics.http_bad_requests, 1);
                if let Some((status, reason)) = err.status() {
                    let _ = write_response(
                        stream, status, reason, "application/json", &[],
                        error_body(&err.message()).as_bytes());
                    lingering_close(stream);
                }
                return;
            }
        };
        served += 1;
        // keep-alive is explicit opt-in (`Connection: keep-alive`),
        // capped at max_requests_per_conn per socket
        let keep = served < shared.cfg.max_requests_per_conn
            && wants_keep_alive(&req);
        let kept_open = route(stream, &req, shared, keep);
        if !kept_open {
            return;
        }
    }
}

/// Did the client explicitly ask to reuse the connection? (HTTP/1.1
/// defaults to persistent, but this server keeps `close` as its
/// default and honors keep-alive only when requested — existing
/// clients observe identical behavior.)
fn wants_keep_alive(req: &Request) -> bool {
    req.header("connection").is_some_and(|v| {
        v.split(',')
            .any(|t| t.trim().eq_ignore_ascii_case("keep-alive"))
    })
}

/// Lingering close for error replies sent before the request was
/// fully read (e.g. an oversized body refused up front): send FIN,
/// then sink whatever the peer already had in flight. Closing with
/// unread bytes would RST the connection and can destroy the error
/// response before the client reads it.
fn lingering_close(stream: &TcpStream) {
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut sunk = 0usize;
    let mut chunk = [0u8; 4096];
    let mut r = stream;
    while sunk < 256 << 10 {
        match std::io::Read::read(&mut r, &mut chunk) {
            Ok(0) | Err(_) => break,
            Ok(n) => sunk += n,
        }
    }
}

/// Dispatch one request. Returns whether the connection stays open
/// for another request (`keep` requested AND the route completed with
/// a keep-alive response — SSE streams, errors, and unknown routes
/// always close).
fn route(stream: &mut TcpStream, req: &Request, shared: &Shared,
         keep: bool) -> bool {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => generate(stream, req, shared, keep),
        ("GET", "/healthz") => {
            let status =
                if shared.lifecycle.draining() { "draining" } else { "ok" };
            let body = format!("{{\"status\":\"{status}\"}}");
            write_response_opts(stream, 200, "OK", "application/json",
                                &[], body.as_bytes(), keep)
                .is_ok()
                && keep
        }
        ("GET", "/metrics") => {
            let body = shared.metrics.render_prometheus();
            write_response_opts(
                stream, 200, "OK",
                "text/plain; version=0.0.4; charset=utf-8", &[],
                body.as_bytes(), keep)
                .is_ok()
                && keep
        }
        ("POST", "/admin/drain") | ("GET", "/admin/drain") => {
            shared.lifecycle.begin_drain();
            // post-mortem window: freeze the recorder at the moment
            // the operator pulled the plug
            crate::obs::instant(crate::obs::Cat::Drain, "drain_begun",
                                crate::obs::args1(
                                    "inflight",
                                    shared.admission.inflight() as u64));
            crate::obs::dump_now("drain");
            let body = format!(
                "{{\"draining\":true,\"inflight\":{}}}",
                shared.admission.inflight());
            write_response_opts(stream, 200, "OK", "application/json",
                                &[], body.as_bytes(), keep)
                .is_ok()
                && keep
        }
        ("GET", "/debug/trace") => {
            if let Some(v) = req.query_param("enable") {
                crate::obs::set_enabled(v != "0");
            }
            let last_ns = req
                .query_param("last_ms")
                .and_then(|v| v.parse::<u64>().ok())
                .map(|ms| ms.saturating_mul(1_000_000));
            let events = crate::obs::snapshot(last_ns);
            let body = crate::obs::chrome::render(&events, "http");
            if req.query_param("clear").is_some_and(|v| v == "1") {
                crate::obs::clear();
            }
            write_response_opts(stream, 200, "OK", "application/json",
                                &[], body.as_bytes(), keep)
                .is_ok()
                && keep
        }
        ("GET", "/debug/experts") => {
            let body = experts_body(shared);
            if req.query_param("clear").is_some_and(|v| v == "1") {
                crate::obs::heat::clear();
            }
            write_response_opts(stream, 200, "OK", "application/json",
                                &[], body.as_bytes(), keep)
                .is_ok()
                && keep
        }
        (_, path) => {
            Metrics::inc(&shared.metrics.http_bad_requests, 1);
            let _ = write_response(
                stream, 404, "Not Found", "application/json", &[],
                error_body(&format!("no route for {path}")).as_bytes());
            false
        }
    }
}

/// The per-layer expert heat table (`GET /debug/experts`): live
/// routing counts from `obs::heat` joined with the resolver's
/// residency/quarantine snapshot and (for resident experts) the PMQ
/// bit-width. Hand-rolled JSON like the rest of the serve tier.
fn experts_body(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let (heat, tokens) = crate::obs::heat::snapshot();
    let model = shared.engine.model();
    let residency = model.resolver.residency();
    let (nl, ne) = (model.cfg.n_layers, model.cfg.n_experts);
    let mut out = String::with_capacity(64 * nl * ne);
    let _ = write!(
        out,
        "{{\"tracing\":{},\"n_layers\":{nl},\"n_experts\":{ne},\
         \"layers\":[",
        crate::obs::enabled());
    for l in 0..nl {
        if l > 0 {
            out.push(',');
        }
        let toks = tokens.get(l).copied().unwrap_or(0);
        let _ = write!(out,
                       "{{\"layer\":{l},\"tokens\":{toks},\"experts\":[");
        for e in 0..ne {
            if e > 0 {
                out.push(',');
            }
            let row = heat
                .get(l)
                .and_then(|r| r.get(e))
                .copied()
                .unwrap_or_default();
            // a fully resident model trivially has every expert in
            // memory and none quarantined
            let resident = residency.as_ref().map_or(true, |(res, _)| {
                res.get(l).and_then(|r| r.get(e)).copied().unwrap_or(false)
            });
            let quarantined = residency.as_ref().is_some_and(|(_, q)| {
                q.get(l).and_then(|r| r.get(e)).copied().unwrap_or(false)
            });
            let _ = write!(
                out,
                "{{\"expert\":{e},\"activations\":{},\
                 \"mean_weight\":{:.6},\"resident\":{resident},\
                 \"quarantined\":{quarantined}",
                row.activations, row.mean_weight);
            if let Some(x) =
                model.layers.get(l).and_then(|layer| layer.experts.get(e))
            {
                let bits = x.storage_bytes() as f64 * 8.0
                    / x.param_count().max(1) as f64;
                let _ = write!(out, ",\"bits\":{bits:.3}");
            }
            out.push('}');
        }
        out.push_str("]}");
    }
    out.push_str("]}");
    out
}

/// Handle `POST /v1/generate`. Returns whether the connection stays
/// open (only a non-streaming success under client keep-alive; SSE
/// and every error status close).
fn generate(stream: &mut TcpStream, req: &Request, shared: &Shared,
            keep: bool) -> bool {
    // chaos hook: an injected panic lands here, before any bytes of
    // the response are written, so the recovery path in `worker_loop`
    // can still send the client a clean 500 (never a mid-stream cut)
    if let Some(fp) = crate::util::faults::plan() {
        if fp.panic_now(crate::util::faults::Site::Conn) {
            panic!("injected fault: connection worker panic");
        }
    }
    // one span from the request hitting this route to the engine
    // accepting it: parse + shed/tenant + memory admission
    let mut adm = crate::obs::span(crate::obs::Cat::Serve, "admission");
    if shared.lifecycle.draining() {
        let _ = write_response(
            stream, 503, "Service Unavailable", "application/json",
            &[("Retry-After", "1".to_string())],
            error_body("draining: not accepting new requests").as_bytes());
        return false;
    }
    let (mut gen_req, want_stream) = match parse_generate(&req.body) {
        Ok(parsed) => parsed,
        Err(msg) => {
            Metrics::inc(&shared.metrics.http_bad_requests, 1);
            let _ = write_response(stream, 400, "Bad Request",
                                   "application/json", &[],
                                   error_body(&msg).as_bytes());
            return false;
        }
    };

    // requests without their own timeout_ms inherit the server's
    // default deadline (None = unlimited, the historical behavior)
    if gen_req.deadline.is_none() {
        gen_req.deadline = shared.cfg.default_timeout;
    }

    let tenant = req.header("x-tenant").unwrap_or("default");
    let permit = match shared.admission.try_admit(tenant, gen_req.priority) {
        Admission::Granted(permit) => permit,
        Admission::Shed { retry_after_s } => {
            adm.set_arg("shed", 1);
            let _ = write_response(
                stream, 429, "Too Many Requests", "application/json",
                &[("Retry-After", retry_after_s.to_string())],
                error_body("shed: queue depth over the admission limit")
                    .as_bytes());
            return false;
        }
        Admission::TenantBusy { retry_after_s } => {
            let _ = write_response(
                stream, 429, "Too Many Requests", "application/json",
                &[("Retry-After", retry_after_s.to_string())],
                error_body(&format!(
                    "tenant {tenant:?} at its concurrent-stream cap"))
                    .as_bytes());
            return false;
        }
    };

    // memory admission: reserve the session's worst-case KV footprint
    // before it reaches the batcher — over-budget is a clean 503 with
    // a backlog-scaled Retry-After, never an OOM (DESIGN.md §8). The
    // grant rides on the request; the reservation releases when the
    // retired session drops it.
    match shared
        .engine
        .governor()
        .admit_session(&gen_req.prompt, gen_req.max_new_tokens)
    {
        Ok(grant) => gen_req.grant = Some(Arc::new(grant)),
        Err(needed) => {
            adm.set_arg("mem_refused", 1);
            let retry = shared.admission.retry_after_hint();
            let _ = write_response(
                stream, 503, "Service Unavailable", "application/json",
                &[("Retry-After", retry.to_string())],
                error_body(&format!(
                    "memory budget exhausted: session needs {needed} bytes"
                ))
                .as_bytes());
            return false;
        }
    }

    let handle = shared.engine.submit(gen_req);
    adm.set_arg("req", handle.id);
    drop(adm);
    let kept_open = if want_stream {
        stream_sse(stream, handle, shared);
        false // the SSE stream is the rest of the connection
    } else {
        // non-streaming: drain to the terminal event, reply once. The
        // engine bounds every request (max_new_tokens / KV / deadline),
        // so this always terminates.
        match handle.wait() {
            Some(done)
                if done.finish
                    == crate::coordinator::request::FinishReason
                        ::DeadlineExceeded =>
            {
                // the completion body (with partial tokens) still
                // ships, under a status the client can branch on
                let _ = write_response(
                    stream, 504, "Gateway Timeout", "application/json",
                    &[], completion_body(&done).as_bytes());
                false
            }
            Some(done) => {
                write_response_opts(stream, 200, "OK", "application/json",
                                    &[],
                                    completion_body(&done).as_bytes(),
                                    keep)
                    .is_ok()
                    && keep
            }
            None => {
                let _ = write_response(
                    stream, 500, "Internal Server Error", "application/json",
                    &[], error_body("request terminated without a \
                                     completion").as_bytes());
                false
            }
        }
    };
    drop(permit); // stream over: release tenant + inflight accounting
    kept_open
}

/// Has the peer gone away? A non-blocking zero-byte `peek` result
/// means orderly close; a hard error (reset) counts too. Extra bytes
/// the client sends after its request are ignored, not a close.
fn peer_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let closed = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// Stream `Token` events as SSE frames the step they are produced,
/// ending with one `done`/`cancelled` frame. A write failure or a
/// closed peer cancels the request so the batcher retires the session
/// at its next step, then drains the handle so admission accounting
/// matches the engine's view.
fn stream_sse(stream: &mut TcpStream, mut handle: RequestHandle,
              shared: &Shared) {
    if write_sse_head(stream).is_err() {
        abandon(&mut handle, shared);
        return;
    }
    let mut index = 0usize;
    loop {
        match handle.try_next_event() {
            Some(StreamEvent::Token(t)) => {
                let frame = token_body(t, index);
                let wrote = {
                    let _sp = crate::obs::span(crate::obs::Cat::Serve,
                                               "sse_write")
                        .arg("req", handle.id)
                        .arg("index", index as u64);
                    write_sse_event(stream, "token", &frame)
                };
                index += 1;
                if wrote.is_err() {
                    abandon(&mut handle, shared);
                    return;
                }
            }
            Some(StreamEvent::Done(done)) => {
                use crate::coordinator::request::FinishReason;
                if done.finish == FinishReason::DeadlineExceeded {
                    // deadline blown mid-stream: terminal `error`
                    // event (clients treat it as a failed stream,
                    // with the partial completion attached)
                    let _ = write_sse_event(
                        stream, "error", &completion_body(&done));
                } else {
                    let _ = write_sse_event(stream, "done",
                                            &completion_body(&done));
                }
                return;
            }
            Some(StreamEvent::Cancelled { id }) => {
                let _ = write_sse_event(stream, "cancelled",
                                        &cancelled_body(id));
                return;
            }
            None if handle.is_terminated() => return,
            None => {
                // idle between steps: the cheap moment to notice the
                // client hung up (otherwise detection waits for the
                // next token's failed write)
                if peer_closed(stream) {
                    abandon(&mut handle, shared);
                    return;
                }
                std::thread::sleep(STREAM_POLL);
            }
        }
    }
}

/// The client is gone: cancel so the batcher frees the slot, then
/// drain the handle's channel to its terminal event (bounded: the
/// batcher reaps the cancel flag at its next step).
fn abandon(handle: &mut RequestHandle, shared: &Shared) {
    Metrics::inc(&shared.metrics.client_disconnects, 1);
    handle.cancel();
    while handle.next_event().is_some() {}
}
