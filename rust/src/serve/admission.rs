//! Pre-engine admission: per-tenant concurrent-stream caps and
//! queue-depth-based load shedding, decided *before* a request is
//! submitted to the batcher so a shed request costs the engine
//! nothing (DESIGN.md §6).
//!
//! Pressure is measured as the controller's own count of live
//! generate streams beyond the fused batcher's slot capacity — a
//! deterministic figure updated at admission/retirement, not the
//! engine's step-cadence gauges, so shedding decisions are exact even
//! under bursts that arrive between decode steps.
//!
//! The existing `Priority` lanes extend into shedding: low-priority
//! traffic sheds at half the configured queue depth, normal at the
//! configured depth, high at twice it — paid/interactive traffic
//! sheds last.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Priority;

/// Outcome of an admission check.
#[derive(Debug)]
pub enum Admission {
    /// admitted; drop the permit when the stream terminates
    Granted(StreamPermit),
    /// queue too deep for this priority class → 429 + Retry-After
    Shed { retry_after_s: u64 },
    /// tenant at its concurrent-stream cap → 429 + Retry-After
    TenantBusy { retry_after_s: u64 },
}

struct Inner {
    /// live admitted streams (all tenants)
    inflight: u64,
    /// live admitted streams per tenant
    tenants: HashMap<String, u64>,
}

pub struct AdmissionControl {
    /// fused-batcher slot capacity: streams beyond this are queued
    max_batch: usize,
    /// queued-stream depth at which Normal traffic sheds (0 = never)
    shed_queue_depth: usize,
    /// per-tenant concurrent-stream cap (0 = unlimited)
    max_streams_per_tenant: usize,
    state: Mutex<Inner>,
    metrics: Arc<Metrics>,
}

/// RAII admission token: decrements the tenant and global stream
/// counts when the stream terminates (whatever the exit path).
pub struct StreamPermit {
    ctrl: Arc<AdmissionControl>,
    tenant: String,
}

impl Drop for StreamPermit {
    fn drop(&mut self) {
        let mut inner = self.ctrl.state.lock().unwrap();
        inner.inflight = inner.inflight.saturating_sub(1);
        if let Some(n) = inner.tenants.get_mut(&self.tenant) {
            *n -= 1;
            if *n == 0 {
                inner.tenants.remove(&self.tenant);
            }
        }
        Metrics::set_gauge(&self.ctrl.metrics.streams_inflight,
                           inner.inflight);
    }
}

/// Shedding threshold for a priority class, in queued streams.
/// `base` is `--shed-queue-depth`; the returned threshold is always
/// >= 1 so a zero estimate never sheds.
fn shed_threshold(base: usize, priority: Priority) -> u64 {
    let t = match priority {
        Priority::Low => base.div_ceil(2),
        Priority::Normal => base,
        Priority::High => base.saturating_mul(2),
    };
    t.max(1) as u64
}

impl AdmissionControl {
    pub fn new(
        max_batch: usize,
        shed_queue_depth: usize,
        max_streams_per_tenant: usize,
        metrics: Arc<Metrics>,
    ) -> AdmissionControl {
        AdmissionControl {
            max_batch,
            shed_queue_depth,
            max_streams_per_tenant,
            state: Mutex::new(Inner { inflight: 0, tenants: HashMap::new() }),
            metrics,
        }
    }

    /// Live admitted streams (terminated permits already excluded).
    pub fn inflight(&self) -> u64 {
        self.state.lock().unwrap().inflight
    }

    /// Streams waiting for a batch slot (the shedding signal).
    fn queued(inner: &Inner, max_batch: usize) -> u64 {
        inner.inflight.saturating_sub(max_batch as u64)
    }

    /// Decide admission for one generate request. Checks run under
    /// one lock so concurrent connection threads serialize here and
    /// every decision sees an exact stream count.
    pub fn try_admit(
        self: &Arc<Self>,
        tenant: &str,
        priority: Priority,
    ) -> Admission {
        let mut inner = self.state.lock().unwrap();

        if self.max_streams_per_tenant > 0 {
            let used = inner.tenants.get(tenant).copied().unwrap_or(0);
            if used >= self.max_streams_per_tenant as u64 {
                Metrics::inc(&self.metrics.requests_tenant_limited, 1);
                return Admission::TenantBusy { retry_after_s: 1 };
            }
        }

        let queued = Self::queued(&inner, self.max_batch);
        if self.shed_queue_depth > 0
            && queued >= shed_threshold(self.shed_queue_depth, priority)
        {
            Metrics::inc(&self.metrics.requests_shed, 1);
            return Admission::Shed {
                retry_after_s: self.retry_after(queued),
            };
        }

        inner.inflight += 1;
        *inner.tenants.entry(tenant.to_string()).or_insert(0) += 1;
        Metrics::set_gauge(&self.metrics.streams_inflight, inner.inflight);
        Admission::Granted(StreamPermit {
            ctrl: self.clone(),
            tenant: tenant.to_string(),
        })
    }

    /// Retry-After estimate: one batch-drain interval per queued
    /// batch-width of work, clamped to [1, 60] seconds. Coarse by
    /// design — the point is to spread retries, not to promise a slot.
    fn retry_after(&self, queued: u64) -> u64 {
        (1 + queued / self.max_batch.max(1) as u64).min(60)
    }

    /// The same backlog-scaled Retry-After, computed from the current
    /// queue estimate — used by refusals decided outside this
    /// controller (e.g. the memory governor's 503) so every backoff
    /// hint scales with the same signal.
    pub fn retry_after_hint(self: &Arc<Self>) -> u64 {
        let inner = self.state.lock().unwrap();
        self.retry_after(Self::queued(&inner, self.max_batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn ctrl(max_batch: usize, shed: usize, per_tenant: usize)
            -> Arc<AdmissionControl> {
        Arc::new(AdmissionControl::new(max_batch, shed, per_tenant,
                                       Arc::new(Metrics::new())))
    }

    #[test]
    fn thresholds_order_priority_lanes() {
        assert_eq!(shed_threshold(2, Priority::Low), 1);
        assert_eq!(shed_threshold(2, Priority::Normal), 2);
        assert_eq!(shed_threshold(2, Priority::High), 4);
        // zero estimate never sheds, even at base 0/1
        assert_eq!(shed_threshold(0, Priority::Low), 1);
        assert_eq!(shed_threshold(1, Priority::Low), 1);
    }

    #[test]
    fn low_sheds_before_normal_before_high() {
        let c = ctrl(1, 2, 0);
        // slot holder + one queued → queued estimate 1
        let _a = c.try_admit("t", Priority::Normal);
        let _b = c.try_admit("t", Priority::Normal);
        assert!(matches!(c.try_admit("t", Priority::Low),
                         Admission::Shed { .. }));
        // normal still admits at queued=1, sheds at queued=2
        let _c2 = match c.try_admit("t", Priority::Normal) {
            Admission::Granted(p) => p,
            other => panic!("normal shed early: {other:?}"),
        };
        assert!(matches!(c.try_admit("t", Priority::Normal),
                         Admission::Shed { retry_after_s } if retry_after_s >= 1));
        // high rides through until 2x the configured depth
        assert!(matches!(c.try_admit("t", Priority::High),
                         Admission::Granted(_)));
        assert_eq!(
            c.metrics.requests_shed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn permit_drop_frees_capacity() {
        let c = ctrl(1, 1, 0);
        let a = match c.try_admit("t", Priority::Normal) {
            Admission::Granted(p) => p,
            _ => unreachable!(),
        };
        let _b = c.try_admit("t", Priority::Normal); // queued=0 → granted
        assert!(matches!(c.try_admit("t", Priority::Normal),
                         Admission::Shed { .. }));
        drop(a);
        assert!(matches!(c.try_admit("t", Priority::Normal),
                         Admission::Granted(_)));
        assert_eq!(c.inflight(), 2);
    }

    #[test]
    fn tenant_cap_is_per_tenant() {
        let c = ctrl(8, 0, 1);
        let _a = c.try_admit("acme", Priority::Normal);
        assert!(matches!(c.try_admit("acme", Priority::Normal),
                         Admission::TenantBusy { .. }));
        assert!(matches!(c.try_admit("globex", Priority::Normal),
                         Admission::Granted(_)));
        assert_eq!(
            c.metrics.requests_tenant_limited.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shed_disabled_at_zero_depth() {
        let c = ctrl(1, 0, 0);
        let permits: Vec<_> = (0..20)
            .map(|_| match c.try_admit("t", Priority::Low) {
                Admission::Granted(p) => p,
                other => panic!("shed with shedding off: {other:?}"),
            })
            .collect();
        assert_eq!(c.inflight(), 20);
        drop(permits);
        assert_eq!(c.inflight(), 0);
    }
}
