//! HTTP/1.1 wire format: request parsing and response writing over
//! any `Read`/`Write` stream (dependency-free; no hyper offline).
//!
//! The parser is deliberately strict and small: request line +
//! headers (capped at `max_head` bytes), then an optional
//! `Content-Length` body (capped at `max_body` bytes). Chunked
//! transfer encoding is not accepted — every client this server
//! speaks to (tests, the soak bench, `curl`) sends sized bodies.
//! Every error maps to one response status so a malformed request can
//! never wedge the connection thread (DESIGN.md §6).

use std::io::{ErrorKind, Read, Write};

/// A parsed request. Header names are lowercased at parse time so
/// lookups are case-insensitive (RFC 9110 §5.1).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path with any `?query` suffix stripped
    pub path: String,
    /// raw query string (after `?`, empty when absent) — the debug
    /// endpoints (`/debug/trace?last_ms=..`) read it via
    /// [`Request::query_param`]
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Value of `name` in the query string (`k=v` pairs joined by
    /// `&`; no percent-decoding — debug parameters are plain numbers
    /// and flags). A bare `?flag` yields `Some("")`.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            (k == name).then_some(v)
        })
    }
}

/// Parse failures, each with a definite response status.
#[derive(Debug, PartialEq, Eq)]
pub enum HttpError {
    /// syntactically broken request line / headers / length → 400
    Malformed(&'static str),
    /// request head exceeded `max_head` → 431
    HeadTooLarge,
    /// declared Content-Length exceeded `max_body` → 413
    BodyTooLarge(usize),
    /// the peer stalled past the socket read timeout → 408
    Timeout,
    /// the peer closed before sending a complete request → no reply
    Closed,
}

impl HttpError {
    /// (status, reason) to answer with; `None` for `Closed` (there is
    /// nobody left to answer).
    pub fn status(&self) -> Option<(u16, &'static str)> {
        match self {
            HttpError::Malformed(_) => Some((400, "Bad Request")),
            HttpError::HeadTooLarge => {
                Some((431, "Request Header Fields Too Large"))
            }
            HttpError::BodyTooLarge(_) => {
                Some((413, "Content Too Large"))
            }
            HttpError::Timeout => Some((408, "Request Timeout")),
            HttpError::Closed => None,
        }
    }

    pub fn message(&self) -> String {
        match self {
            HttpError::Malformed(why) => format!("malformed request: {why}"),
            HttpError::HeadTooLarge => "request head too large".to_string(),
            HttpError::BodyTooLarge(n) => {
                format!("request body of {n} bytes exceeds the limit")
            }
            HttpError::Timeout => "timed out reading the request".to_string(),
            HttpError::Closed => "connection closed".to_string(),
        }
    }
}

fn io_err(e: &std::io::Error) -> HttpError {
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => HttpError::Timeout,
        _ => HttpError::Closed,
    }
}

/// Read one request off the stream. `max_head` bounds the request
/// line + headers; `max_body` bounds the declared Content-Length
/// (checked before any body byte is read, so oversized uploads are
/// refused without buffering them).
pub fn read_request<R: Read>(
    r: &mut R,
    max_head: usize,
    max_body: usize,
) -> Result<Request, HttpError> {
    // accumulate until the blank line that ends the head
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > max_head {
            return Err(HttpError::HeadTooLarge);
        }
        let mut chunk = [0u8; 512];
        let n = r.read(&mut chunk).map_err(|e| io_err(&e))?;
        if n == 0 {
            return if buf.is_empty() {
                Err(HttpError::Closed)
            } else {
                Err(HttpError::Malformed("eof inside request head"))
            };
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::Malformed("non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line =
        lines.next().ok_or(HttpError::Malformed("empty request"))?;
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or(HttpError::Malformed("missing method"))?
        .to_string();
    let target = parts
        .next()
        .ok_or(HttpError::Malformed("missing request target"))?;
    let version = parts
        .next()
        .ok_or(HttpError::Malformed("missing http version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed("unsupported http version"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    if !path.starts_with('/') {
        return Err(HttpError::Malformed("request target must be a path"));
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or(HttpError::Malformed("header without a colon"))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }

    // body: everything we over-read past the head, plus the rest of
    // the declared Content-Length
    let declared = match headers.iter().find(|(k, _)| k == "content-length") {
        None => 0usize,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| HttpError::Malformed("bad content-length"))?,
    };
    if declared > max_body {
        return Err(HttpError::BodyTooLarge(declared));
    }
    let mut body = buf[head_end + 4..].to_vec();
    if body.len() > declared {
        // pipelined extra bytes: this server answers one request per
        // connection (Connection: close), so trailing bytes are noise
        body.truncate(declared);
    }
    while body.len() < declared {
        let mut chunk = [0u8; 4096];
        let want = (declared - body.len()).min(chunk.len());
        let n = r.read(&mut chunk[..want]).map_err(|e| io_err(&e))?;
        if n == 0 {
            return Err(HttpError::Malformed("eof inside request body"));
        }
        body.extend_from_slice(&chunk[..n]);
    }

    Ok(Request { method, path, query, headers, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write a complete sized response with `Connection: close` — the
/// historical default; error replies and SSE streams always close.
/// Routes that honor client keep-alive go through
/// [`write_response_opts`].
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write_response_opts(w, status, reason, content_type, extra_headers,
                        body, false)
}

/// Write a complete sized response, advertising `Connection:
/// keep-alive` when `keep_alive` (the connection loop then reads the
/// next request off the same socket) and `Connection: close`
/// otherwise.
pub fn write_response_opts<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Start an SSE response: status line + streaming headers, no
/// Content-Length (the body is the event stream until close).
pub fn write_sse_head<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()
}

/// One SSE frame: `event: <name>\ndata: <data>\n\n`, flushed so the
/// client sees each token the step it was produced.
pub fn write_sse_event<W: Write>(
    w: &mut W,
    name: &str,
    data: &str,
) -> std::io::Result<()> {
    w.write_all(format!("event: {name}\ndata: {data}\n\n").as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut &raw[..], 8192, 1 << 20)
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate?x=1 HTTP/1.1\r\nHost: a\r\n\
                    X-Tenant: acme\r\nContent-Length: 4\r\n\r\nabcd";
        let req = parse(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.query_param("x"), Some("1"));
        assert_eq!(req.query_param("y"), None);
        assert_eq!(req.header("x-tenant"), Some("acme"));
        assert_eq!(req.header("X-TENANT"), Some("acme"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.query.is_empty());
        assert!(req.body.is_empty());
    }

    #[test]
    fn query_params_parse() {
        let req =
            parse(b"GET /debug/trace?last_ms=250&clear=1&flag HTTP/1.1\r\n\r\n")
                .unwrap();
        assert_eq!(req.path, "/debug/trace");
        assert_eq!(req.query_param("last_ms"), Some("250"));
        assert_eq!(req.query_param("clear"), Some("1"));
        assert_eq!(req.query_param("flag"), Some(""));
        assert_eq!(req.query_param("absent"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(matches!(parse(b"nonsense\r\n\r\n"),
                         Err(HttpError::Malformed(_))));
        assert!(matches!(parse(b"GET /x SPDY/3\r\n\r\n"),
                         Err(HttpError::Malformed(_))));
        assert!(matches!(
            parse(b"POST /x HTTP/1.1\r\nContent-Length: zzz\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(parse(b""), Err(HttpError::Closed)));
        assert!(matches!(parse(b"GET / HTTP/1.1\r\nX: y"),
                         Err(HttpError::Malformed(_))));
    }

    #[test]
    fn caps_head_and_body() {
        let big = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000));
        assert_eq!(parse(big.as_bytes()), Err(HttpError::HeadTooLarge));
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 2000000\r\n\r\n";
        assert_eq!(parse(raw), Err(HttpError::BodyTooLarge(2_000_000)));
    }

    #[test]
    fn body_split_across_reads() {
        // a reader that returns one byte at a time exercises the
        // accumulation loop
        struct OneByte<'a>(&'a [u8]);
        impl Read for OneByte<'_> {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                out[0] = self.0[0];
                self.0 = &self.0[1..];
                Ok(1)
            }
        }
        let raw = b"POST /g HTTP/1.1\r\nContent-Length: 3\r\n\r\nxyz";
        let req = read_request(&mut OneByte(raw), 8192, 64).unwrap();
        assert_eq!(req.body, b"xyz");
    }

    #[test]
    fn response_roundtrips_through_parser_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "Too Many Requests",
                       "application/json",
                       &[("Retry-After", "2".to_string())],
                       b"{\"error\":\"shed\"}")
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 2\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("{\"error\":\"shed\"}"));
    }

    #[test]
    fn keep_alive_response_advertises_it() {
        let mut out = Vec::new();
        write_response_opts(&mut out, 200, "OK", "application/json", &[],
                            b"{}", true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(!text.contains("Connection: close\r\n"));
    }

    #[test]
    fn sse_frames() {
        let mut out = Vec::new();
        write_sse_head(&mut out).unwrap();
        write_sse_event(&mut out, "token", "{\"token\":7}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Content-Type: text/event-stream"));
        assert!(text.ends_with("event: token\ndata: {\"token\":7}\n\n"));
    }
}
