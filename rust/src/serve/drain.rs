//! Graceful-drain state machine and the SIGTERM hook (DESIGN.md §6).
//!
//! Drain protocol: `begin_drain()` (from `/admin/drain` or SIGTERM)
//! flips the server into draining — new generate requests are refused
//! with 503 while health/metrics stay up and every in-flight stream
//! runs to its terminal event. Once the stream count hits zero the
//! accept loop stops and the engine shuts down. The drain duration
//! lands in `Metrics::last_drain_ns` and the returned `DrainReport`.
//!
//! The SIGTERM hook is the one place the crate touches a C API: a
//! handler that stores into a process-global `AtomicBool` (the only
//! thing that is async-signal-safe anyway), registered via libc's
//! `signal` — which every unix target links already, so this stays
//! dependency-free. Non-unix builds compile the hook to a no-op.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Server lifecycle flags shared by the acceptor, connection threads,
/// and the drain waiter.
#[derive(Default)]
pub struct Lifecycle {
    draining: AtomicBool,
    stop_accepting: AtomicBool,
    drain_started: Mutex<Option<Instant>>,
}

/// What a completed drain looked like.
#[derive(Debug, Clone, Copy)]
pub struct DrainReport {
    /// every in-flight stream terminated before the deadline
    pub drained: bool,
    /// begin_drain → zero in-flight streams
    pub drain_ms: f64,
    /// streams that were in flight when the drain began
    pub inflight_at_start: u64,
}

impl Lifecycle {
    pub fn new() -> Lifecycle {
        Lifecycle::default()
    }

    /// Enter draining (idempotent; the first call wins the clock).
    /// Returns whether this call initiated the drain.
    pub fn begin_drain(&self) -> bool {
        let first = !self.draining.swap(true, Ordering::SeqCst);
        if first {
            *self.drain_started.lock().unwrap() = Some(Instant::now());
        }
        first
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Milliseconds since `begin_drain` (0.0 if not draining).
    pub fn drain_elapsed_ms(&self) -> f64 {
        self.drain_started
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }

    /// Tell the accept loop to exit (after drain completes, or on a
    /// hard shutdown).
    pub fn stop_accepting(&self) {
        self.stop_accepting.store(true, Ordering::SeqCst);
    }

    pub fn accepting_stopped(&self) -> bool {
        self.stop_accepting.load(Ordering::SeqCst)
    }
}

static SIGTERM_SEEN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigterm(_sig: i32) {
    // async-signal-safe: a single atomic store, nothing else
    SIGTERM_SEEN.store(true, Ordering::SeqCst);
}

/// Install the SIGTERM → drain flag hook. Call once from the serving
/// binary before blocking in the accept loop; the main loop polls
/// [`sigterm_seen`] and begins a drain when it flips.
pub fn install_sigterm_hook() {
    #[cfg(unix)]
    {
        extern "C" {
            // libc::signal without the libc crate: every unix target
            // already links libc, and usize holds the handler pointer
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_sigterm as usize);
        }
    }
}

/// Has SIGTERM been delivered since the hook was installed?
pub fn sigterm_seen() -> bool {
    SIGTERM_SEEN.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_is_idempotent_and_timed() {
        let lc = Lifecycle::new();
        assert!(!lc.draining());
        assert_eq!(lc.drain_elapsed_ms(), 0.0);
        assert!(lc.begin_drain(), "first call initiates");
        assert!(!lc.begin_drain(), "second call is a no-op");
        assert!(lc.draining());
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(lc.drain_elapsed_ms() >= 4.0);
        assert!(!lc.accepting_stopped());
        lc.stop_accepting();
        assert!(lc.accepting_stopped());
    }

    #[test]
    fn sigterm_hook_installs() {
        // just exercises the registration path; delivering a real
        // SIGTERM would tear down the test harness
        install_sigterm_hook();
        assert!(!sigterm_seen());
    }
}
