//! HTTP/SSE serving front end (DESIGN.md §6): the network layer that
//! turns the in-process `coordinator::Server` into a socket-reachable
//! service with a production admission envelope.
//!
//! - `POST /v1/generate` — JSON body → `GenerateRequest`; response is
//!   an SSE stream of `token`/`done`/`cancelled` events (or a single
//!   JSON completion with `"stream": false`)
//! - `GET /healthz`, `GET /metrics` (Prometheus text exposition),
//!   `POST /admin/drain`
//! - connection cap (`--max-conns`), per-tenant concurrent-stream cap
//!   keyed by the `X-Tenant` header, queue-depth load shedding with
//!   priority lanes (429 + Retry-After, low sheds first), client
//!   disconnect → `RequestHandle::cancel`, graceful drain on
//!   SIGTERM / `/admin/drain`
//!
//! Topology: one nonblocking acceptor thread plus a fixed pool of
//! `max_conns` connection threads (256 KiB stacks — they parse and
//! stream, nothing deep) fed over an mpsc channel; the acceptor
//! answers over-capacity connections with 503 inline so a full pool
//! sheds instead of wedging. The engine `Server`'s own worker drives
//! the fused batcher exactly as in-process callers use it — the front
//! end is strictly additive.

pub mod admission;
pub mod client;
mod conn;
pub mod drain;
pub mod http;
pub mod json;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::Server;

use admission::AdmissionControl;
use drain::{DrainReport, Lifecycle};

/// Front-end knobs (`mc-moe serve --host/--port/...`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub host: String,
    /// 0 = OS-assigned (tests); read back via [`HttpServer::addr`]
    pub port: u16,
    /// connection-pool size; further connections get an inline 503
    pub max_conns: usize,
    /// concurrent streams per `X-Tenant` value (0 = unlimited)
    pub max_streams_per_tenant: usize,
    /// queued-stream depth at which Normal priority sheds (0 = off);
    /// Low sheds at half this, High at twice (DESIGN.md §6)
    pub shed_queue_depth: usize,
    /// fused-batcher slot count (queue depth = streams beyond this)
    pub max_batch: usize,
    pub max_head_bytes: usize,
    pub max_body_bytes: usize,
    /// socket read/write timeout (slow-client guard)
    pub read_timeout: Duration,
    /// how long `shutdown` waits for in-flight streams to finish
    pub drain_timeout: Duration,
    /// deadline applied to generate requests that don't carry a
    /// `timeout_ms` of their own (None = unlimited)
    pub default_timeout: Option<Duration>,
    /// how long a kept-alive connection may sit idle between requests
    /// before the worker closes it (frees its pool slot)
    pub keep_alive_idle: Duration,
    /// requests served per connection before the server closes it even
    /// if the client asked for keep-alive (bounds per-socket state)
    pub max_requests_per_conn: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 8080,
            max_conns: 256,
            max_streams_per_tenant: 32,
            shed_queue_depth: 64,
            max_batch: 4,
            max_head_bytes: 8 << 10,
            max_body_bytes: 1 << 20,
            read_timeout: Duration::from_secs(10),
            drain_timeout: Duration::from_secs(30),
            default_timeout: None,
            keep_alive_idle: Duration::from_secs(5),
            max_requests_per_conn: 100,
        }
    }
}

/// State shared by the acceptor, connection threads, and the owner.
pub(crate) struct Shared {
    pub engine: Arc<Server>,
    pub metrics: Arc<Metrics>,
    pub admission: Arc<AdmissionControl>,
    pub lifecycle: Lifecycle,
    pub cfg: ServeConfig,
}

pub struct HttpServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// held so dropping it closes the pool's intake after the
    /// acceptor exits
    conn_tx: Option<Sender<TcpStream>>,
}

impl HttpServer {
    /// Bind and start serving. The engine `Server` should have been
    /// spawned with `cfg.max_batch` slots so admission's queue-depth
    /// estimate matches the batcher's capacity.
    pub fn bind(engine: Server, cfg: ServeConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("bind {}:{}", cfg.host, cfg.port))?;
        let addr = listener.local_addr()?;
        listener
            .set_nonblocking(true)
            .context("nonblocking accept loop")?;

        let metrics = engine.metrics.clone();
        let admission = Arc::new(AdmissionControl::new(
            cfg.max_batch,
            cfg.shed_queue_depth,
            cfg.max_streams_per_tenant,
            metrics.clone(),
        ));
        let shared = Arc::new(Shared {
            engine: Arc::new(engine),
            metrics,
            admission,
            lifecycle: Lifecycle::new(),
            cfg: cfg.clone(),
        });

        let (conn_tx, conn_rx): (Sender<TcpStream>, Receiver<TcpStream>) =
            channel();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let workers = (0..cfg.max_conns.max(1))
            .map(|i| {
                let rx = conn_rx.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mc-conn-{i}"))
                    .stack_size(256 << 10)
                    .spawn(move || worker_loop(rx, shared))
                    .expect("spawn connection worker")
            })
            .collect();

        let acceptor = {
            let shared = shared.clone();
            let tx = conn_tx.clone();
            std::thread::Builder::new()
                .name("mc-accept".to_string())
                .spawn(move || accept_loop(listener, tx, shared))
                .expect("spawn acceptor")
        };

        Ok(HttpServer {
            shared,
            addr,
            acceptor: Some(acceptor),
            workers,
            conn_tx: Some(conn_tx),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Enter draining: health reports "draining", new generate
    /// requests get 503, in-flight streams run to completion.
    pub fn begin_drain(&self) {
        self.shared.lifecycle.begin_drain();
    }

    pub fn draining(&self) -> bool {
        self.shared.lifecycle.draining()
    }

    /// Live admitted generate streams.
    pub fn inflight(&self) -> u64 {
        self.shared.admission.inflight()
    }

    /// Block until a drain has been requested (via [`begin_drain`],
    /// `/admin/drain`, or SIGTERM once [`drain::install_sigterm_hook`]
    /// ran) and every in-flight stream has terminated, then tear
    /// down. This is `mc-moe serve`'s main loop.
    pub fn serve_until_drained(self) -> DrainReport {
        loop {
            if drain::sigterm_seen() {
                self.shared.lifecycle.begin_drain();
            }
            if self.shared.lifecycle.draining()
                && self.shared.admission.inflight() == 0
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        self.shutdown()
    }

    /// Graceful stop: drain (bounded by `cfg.drain_timeout`), stop
    /// accepting, join every thread, shut the engine down. The
    /// measured drain latency lands in `Metrics::last_drain_ns`.
    pub fn shutdown(mut self) -> DrainReport {
        let shared = &self.shared;
        let inflight_at_start = shared.admission.inflight();
        shared.lifecycle.begin_drain();
        let deadline = std::time::Instant::now() + shared.cfg.drain_timeout;
        while shared.admission.inflight() > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        let drained = shared.admission.inflight() == 0;
        let drain_ms = shared.lifecycle.drain_elapsed_ms();
        Metrics::set_gauge(&shared.metrics.last_drain_ns,
                           (drain_ms * 1e6) as u64);

        shared.lifecycle.stop_accepting();
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // close the pool intake; workers exit once the queue drains
        drop(self.conn_tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // the engine Server's Drop sends Shutdown and joins its worker
        DrainReport { drained, drain_ms, inflight_at_start }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<TcpStream>>>, shared: Arc<Shared>) {
    loop {
        // hold the lock only for the recv, not while handling
        let mut stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return, // intake closed: shutdown
        };
        // panic isolation: a poisoned request must not take the worker
        // (and its pool slot) down with it. Admission permits and
        // stream guards release during unwind, so accounting holds;
        // the client gets a 500 instead of a wedged socket.
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                conn::handle(&mut stream, &shared)
            }),
        );
        if outcome.is_err() {
            Metrics::inc(&shared.metrics.panics_recovered, 1);
            // flight recorder: freeze the last events around the panic
            crate::obs::instant(crate::obs::Cat::Serve, "panic_recovered",
                                crate::obs::NO_ARGS);
            crate::obs::dump_now("panic");
            let _ = http::write_response(
                &mut stream, 500, "Internal Server Error",
                "application/json", &[],
                json::error_body("internal error (request aborted)")
                    .as_bytes());
        }
        let active = shared
            .metrics
            .http_conns_active
            .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
        debug_assert!(active > 0, "conn gauge underflow");
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<TcpStream>,
               shared: Arc<Shared>) {
    use std::sync::atomic::Ordering;
    loop {
        if shared.lifecycle.accepting_stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let active =
                    shared.metrics.http_conns_active.load(Ordering::Relaxed);
                if active >= shared.cfg.max_conns as u64 {
                    // inline 503: over-capacity connections are told
                    // to back off instead of queueing unserved
                    Metrics::inc(&shared.metrics.http_conns_rejected, 1);
                    let mut stream = stream;
                    let _ = stream.set_write_timeout(
                        Some(Duration::from_secs(1)));
                    let _ = http::write_response(
                        &mut stream, 503, "Service Unavailable",
                        "application/json",
                        &[("Retry-After", "1".to_string())],
                        json::error_body("connection limit reached")
                            .as_bytes());
                    continue;
                }
                Metrics::inc(&shared.metrics.http_conns_accepted, 1);
                shared
                    .metrics
                    .http_conns_active
                    .fetch_add(1, Ordering::Relaxed);
                if tx.send(stream).is_err() {
                    return; // pool gone: shutting down
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                // transient accept failure (EMFILE, reset during
                // handshake): brief backoff, keep serving
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.max_conns >= 1);
        assert!(cfg.max_body_bytes >= 1024);
        assert!(cfg.shed_queue_depth > 0);
        assert_eq!(cfg.host, "127.0.0.1");
    }
}
