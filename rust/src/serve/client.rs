//! Minimal blocking HTTP/SSE client for the front end's own tests,
//! the soak bench, and examples — the other half of the wire format
//! in `serve::http`, kept in-tree so every consumer speaks exactly
//! the dialect the server serves (one request per connection, sized
//! responses except SSE).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// A complete sized response.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// One parsed SSE frame.
#[derive(Debug, Clone, PartialEq)]
pub struct SseEvent {
    pub name: String,
    pub data: String,
}

fn connect(addr: SocketAddr, timeout: Duration) -> std::io::Result<TcpStream> {
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

fn write_request(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Parse `HTTP/1.1 <status> ...` + headers from `head` (the bytes up
/// to and excluding the blank line).
fn parse_head(head: &[u8]) -> std::io::Result<(u16, Vec<(String, String)>)> {
    let text = std::str::from_utf8(head).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "non-utf8 head")
    })?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData,
                                format!("bad status line {status_line:?}"))
        })?;
    let mut headers = Vec::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(),
                          v.trim().to_string()));
        }
    }
    Ok((status, headers))
}

/// Read from `stream` until the header/body separator; returns
/// (head bytes, already-read body prefix).
fn read_head(stream: &mut TcpStream) -> std::io::Result<(Vec<u8>, Vec<u8>)> {
    let mut buf = Vec::with_capacity(512);
    loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let rest = buf[pos + 4..].to_vec();
            buf.truncate(pos);
            return Ok((buf, rest));
        }
        let mut chunk = [0u8; 512];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before response head",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Issue one request and read the full response (the server always
/// closes after the body, so read-to-EOF is the framing).
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    timeout: Duration,
) -> std::io::Result<HttpResponse> {
    let mut stream = connect(addr, timeout)?;
    write_request(&mut stream, method, path, headers, body)?;
    let (head, mut resp_body) = read_head(&mut stream)?;
    let (status, headers) = parse_head(&head)?;
    stream.read_to_end(&mut resp_body)?;
    Ok(HttpResponse { status, headers, body: resp_body })
}

/// `POST /v1/generate` that did not become a stream (non-200, or a
/// `"stream":false` JSON reply) vs. a live SSE stream.
pub enum GenerateReply {
    Stream(SseStream),
    Response(HttpResponse),
}

/// A live SSE connection; pull frames with `next_event` until `None`
/// (server closed the stream after its terminal frame).
pub struct SseStream {
    stream: TcpStream,
    buf: Vec<u8>,
    eof: bool,
}

impl SseStream {
    /// Next frame, blocking up to the connect timeout per read.
    /// `Ok(None)` once the server has closed the stream.
    pub fn next_event(&mut self) -> std::io::Result<Option<SseEvent>> {
        loop {
            if let Some(pos) = self.buf.windows(2).position(|w| w == b"\n\n") {
                let frame: Vec<u8> = self.buf.drain(..pos + 2).collect();
                if let Some(ev) = parse_sse_frame(&frame[..pos]) {
                    return Ok(Some(ev));
                }
                continue; // comment/blank frame: keep reading
            }
            if self.eof {
                return Ok(None);
            }
            let mut chunk = [0u8; 512];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                self.eof = true;
                continue;
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }

    /// Drop the connection without reading further — from the
    /// server's point of view this is a mid-stream client disconnect.
    pub fn abort(self) {}
}

fn parse_sse_frame(frame: &[u8]) -> Option<SseEvent> {
    let text = std::str::from_utf8(frame).ok()?;
    let mut name = String::new();
    let mut data = String::new();
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("event:") {
            name = v.trim().to_string();
        } else if let Some(v) = line.strip_prefix("data:") {
            if !data.is_empty() {
                data.push('\n');
            }
            data.push_str(v.trim());
        }
    }
    if name.is_empty() && data.is_empty() {
        None
    } else {
        Some(SseEvent { name, data })
    }
}

/// Open a generate request. 200 + `text/event-stream` becomes a
/// `SseStream`; anything else is returned as a complete response.
pub fn open_generate(
    addr: SocketAddr,
    body: &[u8],
    headers: &[(&str, &str)],
    timeout: Duration,
) -> std::io::Result<GenerateReply> {
    let mut stream = connect(addr, timeout)?;
    write_request(&mut stream, "POST", "/v1/generate", headers, body)?;
    let (head, prefix) = read_head(&mut stream)?;
    let (status, resp_headers) = parse_head(&head)?;
    let is_sse = status == 200
        && resp_headers.iter().any(|(k, v)| {
            k == "content-type" && v.starts_with("text/event-stream")
        });
    if is_sse {
        return Ok(GenerateReply::Stream(SseStream {
            stream,
            buf: prefix,
            eof: false,
        }));
    }
    let mut body = prefix;
    stream.read_to_end(&mut body)?;
    Ok(GenerateReply::Response(HttpResponse {
        status,
        headers: resp_headers,
        body,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_response_head() {
        let (status, headers) = parse_head(
            b"HTTP/1.1 429 Too Many Requests\r\nRetry-After: 2\r\n\
              Content-Type: application/json").unwrap();
        assert_eq!(status, 429);
        assert_eq!(headers[0], ("retry-after".to_string(), "2".to_string()));
    }

    #[test]
    fn parses_sse_frames() {
        let ev = parse_sse_frame(b"event: token\ndata: {\"token\":7}").unwrap();
        assert_eq!(ev.name, "token");
        assert_eq!(ev.data, "{\"token\":7}");
        assert!(parse_sse_frame(b": keep-alive comment").is_none());
    }
}
