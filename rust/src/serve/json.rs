//! JSON bodies for the HTTP front end: `POST /v1/generate` request
//! decoding and completion / SSE event encoding, built on the
//! dependency-free `util::json` parser (no serde offline).
//!
//! Request schema (everything but `prompt` optional):
//!
//! ```json
//! {
//!   "prompt": [1, 5, 80, 3],
//!   "max_new_tokens": 16,
//!   "temperature": 0.8, "top_k": 40, "top_p": 0.95, "seed": 7,
//!   "stop": "eos" | "max_len" | [17, 9],
//!   "priority": "high" | "normal" | "low",
//!   "stream": true,
//!   "timeout_ms": 5000
//! }
//! ```
//!
//! As on the CLI, passing a truncation knob (`top_k`/`top_p`) without
//! `temperature` implies temperature 1.0 — otherwise the greedy
//! short-circuit would silently ignore the knobs.

use crate::coordinator::request::{
    Completion, FinishReason, GenerateRequest, Priority, SamplingParams,
    StopCondition,
};
use crate::util::json::{arr, num, obj, s, Json};

/// Decode a generate body. The error string is sent back verbatim in
/// a 400 response, so messages name the offending field.
pub fn parse_generate(body: &[u8]) -> Result<(GenerateRequest, bool), String> {
    let text = std::str::from_utf8(body)
        .map_err(|_| "body is not utf-8".to_string())?;
    if text.trim().is_empty() {
        return Err("empty body (expected a JSON object)".to_string());
    }
    let json = Json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let prompt_json = json
        .opt("prompt")
        .ok_or_else(|| "missing required field \"prompt\"".to_string())?;
    let mut prompt = Vec::new();
    for (i, v) in prompt_json
        .as_arr()
        .map_err(|_| "\"prompt\" must be an array of token ids".to_string())?
        .iter()
        .enumerate()
    {
        let t = v
            .as_usize()
            .map_err(|_| format!("prompt[{i}] is not a token id"))?;
        let t = u32::try_from(t)
            .map_err(|_| format!("prompt[{i}] out of u32 range"))?;
        prompt.push(t);
    }

    let field_usize = |name: &str, default: usize| -> Result<usize, String> {
        match json.opt(name) {
            None => Ok(default),
            Some(v) => v
                .as_usize()
                .map_err(|_| format!("\"{name}\" must be a non-negative integer")),
        }
    };
    let field_f32 = |name: &str, default: f32| -> Result<f32, String> {
        match json.opt(name) {
            None => Ok(default),
            Some(v) => {
                Ok(v.as_f64()
                    .map_err(|_| format!("\"{name}\" must be a number"))?
                    as f32)
            }
        }
    };

    let max_new_tokens = field_usize("max_new_tokens", 16)?;
    let top_k = field_usize("top_k", 0)?;
    let top_p = field_f32("top_p", 1.0)?;
    let wants_sampling =
        json.opt("top_k").is_some() || json.opt("top_p").is_some();
    let default_temp = if wants_sampling { 1.0 } else { 0.0 };
    let temperature = field_f32("temperature", default_temp)?;
    let seed = field_usize("seed", 5)? as u64;

    let stop = match json.opt("stop") {
        None => StopCondition::Eos,
        Some(Json::Str(mode)) => match mode.as_str() {
            "eos" => StopCondition::Eos,
            "max_len" => StopCondition::MaxLen,
            other => {
                return Err(format!(
                    "\"stop\" must be \"eos\", \"max_len\", or a token \
                     array, got {other:?}"
                ))
            }
        },
        Some(Json::Arr(tokens)) => {
            let mut set = Vec::new();
            for (i, v) in tokens.iter().enumerate() {
                let t = v
                    .as_usize()
                    .map_err(|_| format!("stop[{i}] is not a token id"))?;
                set.push(t as u32);
            }
            StopCondition::StopTokens(set)
        }
        Some(_) => {
            return Err("\"stop\" must be \"eos\", \"max_len\", or a token \
                        array"
                .to_string())
        }
    };

    let priority = match json.opt("priority") {
        None => Priority::Normal,
        Some(v) => {
            let name = v
                .as_str()
                .map_err(|_| "\"priority\" must be a string".to_string())?;
            parse_priority(name).ok_or_else(|| {
                format!("\"priority\" must be high|normal|low, got {name:?}")
            })?
        }
    };

    let stream = match json.opt("stream") {
        None => true,
        Some(v) => v
            .as_bool()
            .map_err(|_| "\"stream\" must be a boolean".to_string())?,
    };

    // wall-clock budget; requests without one fall back to the
    // server's configured default deadline (conn::generate)
    let deadline = match json.opt("timeout_ms") {
        None => None,
        Some(v) => {
            let ms = v.as_usize().map_err(|_| {
                "\"timeout_ms\" must be a non-negative integer".to_string()
            })?;
            Some(std::time::Duration::from_millis(ms as u64))
        }
    };

    let req = GenerateRequest {
        prompt,
        max_new_tokens,
        sampling: SamplingParams { temperature, top_k, top_p, seed },
        stop,
        priority,
        deadline,
        // the connection layer attaches the memory-governor grant
        // after admission (conn::generate)
        grant: None,
    };
    Ok((req, stream))
}

pub fn parse_priority(name: &str) -> Option<Priority> {
    match name {
        "high" => Some(Priority::High),
        "normal" => Some(Priority::Normal),
        "low" => Some(Priority::Low),
        _ => None,
    }
}

/// `finish` as wire strings; a `Stop` carries the stopping token in a
/// sibling `stop_token` field.
fn finish_fields(f: &FinishReason) -> (&'static str, Option<u32>) {
    match f {
        FinishReason::Stop(t) => ("stop", Some(*t)),
        FinishReason::MaxTokens => ("max_tokens", None),
        FinishReason::Cancelled => ("cancelled", None),
        FinishReason::Rejected => ("rejected", None),
        FinishReason::DeadlineExceeded => ("deadline_exceeded", None),
    }
}

/// The completion object: the non-streaming response body and the
/// `done` SSE event's data.
pub fn completion_body(c: &Completion) -> String {
    let (finish, stop_token) = finish_fields(&c.finish);
    let mut pairs = vec![
        ("id", num(c.id as f64)),
        ("tokens", arr(c.tokens.iter().map(|&t| num(t as f64)))),
        ("finish", s(finish)),
        ("ttft_ms", num(c.ttft_ns as f64 / 1e6)),
        ("total_ms", num(c.total_ns as f64 / 1e6)),
    ];
    if let Some(t) = stop_token {
        pairs.push(("stop_token", num(t as f64)));
    }
    obj(pairs).to_string()
}

/// One streamed token: `{"token":t,"index":i}`.
pub fn token_body(token: u32, index: usize) -> String {
    obj(vec![("token", num(token as f64)), ("index", num(index as f64))])
        .to_string()
}

pub fn cancelled_body(id: u64) -> String {
    obj(vec![("id", num(id as f64))]).to_string()
}

pub fn error_body(message: &str) -> String {
    obj(vec![("error", s(message))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_request_roundtrip() {
        let body = br#"{"prompt":[1,5,80,3],"max_new_tokens":8,
            "temperature":0.8,"top_k":4,"top_p":0.9,"seed":7,
            "stop":[9,17],"priority":"high","stream":false}"#;
        let (req, stream) = parse_generate(body).unwrap();
        assert_eq!(req.prompt, vec![1, 5, 80, 3]);
        assert_eq!(req.max_new_tokens, 8);
        assert_eq!(req.sampling.temperature, 0.8);
        assert_eq!(req.sampling.top_k, 4);
        assert_eq!(req.sampling.seed, 7);
        assert_eq!(req.stop, StopCondition::StopTokens(vec![9, 17]));
        assert_eq!(req.priority, Priority::High);
        assert!(!stream);
    }

    #[test]
    fn defaults_are_greedy_streaming_eos() {
        let (req, stream) = parse_generate(br#"{"prompt":[2,3]}"#).unwrap();
        assert_eq!(req.max_new_tokens, 16);
        assert!(req.sampling.is_greedy());
        assert_eq!(req.stop, StopCondition::Eos);
        assert_eq!(req.priority, Priority::Normal);
        assert!(stream);
    }

    #[test]
    fn truncation_knobs_imply_sampling() {
        let (req, _) =
            parse_generate(br#"{"prompt":[1],"top_k":5}"#).unwrap();
        assert_eq!(req.sampling.temperature, 1.0);
        assert_eq!(req.sampling.top_k, 5);
    }

    #[test]
    fn timeout_ms_becomes_deadline() {
        let (req, _) = parse_generate(br#"{"prompt":[1]}"#).unwrap();
        assert_eq!(req.deadline, None);
        let (req, _) =
            parse_generate(br#"{"prompt":[1],"timeout_ms":250}"#).unwrap();
        assert_eq!(req.deadline,
                   Some(std::time::Duration::from_millis(250)));
        assert!(parse_generate(br#"{"prompt":[1],"timeout_ms":-5}"#)
            .unwrap_err()
            .contains("timeout_ms"));
    }

    #[test]
    fn named_stop_modes() {
        let (req, _) =
            parse_generate(br#"{"prompt":[1],"stop":"max_len"}"#).unwrap();
        assert_eq!(req.stop, StopCondition::MaxLen);
        let (req, _) =
            parse_generate(br#"{"prompt":[1],"stop":"eos"}"#).unwrap();
        assert_eq!(req.stop, StopCondition::Eos);
    }

    #[test]
    fn errors_name_the_field() {
        assert!(parse_generate(b"").unwrap_err().contains("empty body"));
        assert!(parse_generate(b"not json").unwrap_err().contains("JSON"));
        assert!(parse_generate(br#"{"max_new_tokens":4}"#)
            .unwrap_err()
            .contains("prompt"));
        assert!(parse_generate(br#"{"prompt":[1.5]}"#)
            .unwrap_err()
            .contains("prompt[0]"));
        assert!(parse_generate(br#"{"prompt":[1],"priority":"vip"}"#)
            .unwrap_err()
            .contains("priority"));
        assert!(parse_generate(br#"{"prompt":[1],"stop":5}"#)
            .unwrap_err()
            .contains("stop"));
        assert!(parse_generate(br#"{"prompt":[1],"stream":"yes"}"#)
            .unwrap_err()
            .contains("stream"));
    }

    #[test]
    fn completion_and_event_bodies() {
        let c = Completion {
            id: 3,
            tokens: vec![10, 11],
            finish: FinishReason::Stop(11),
            ttft_ns: 2_000_000,
            total_ns: 5_000_000,
        };
        let body = completion_body(&c);
        assert!(body.contains("\"tokens\":[10,11]"), "{body}");
        assert!(body.contains("\"finish\":\"stop\""), "{body}");
        assert!(body.contains("\"stop_token\":11"), "{body}");
        assert!(body.contains("\"ttft_ms\":2"), "{body}");
        assert_eq!(token_body(7, 0), r#"{"index":0,"token":7}"#);
        assert_eq!(cancelled_body(9), r#"{"id":9}"#);
        assert_eq!(error_body("nope"), r#"{"error":"nope"}"#);
    }
}
