//! MC-MoE: Mixture Compressor for Mixture-of-Experts LLMs (ICLR 2025).
//!
//! Training-free mixture compression: PMQ (pre-loading mixed-precision
//! quantization via integer-programmed expert bit allocation) + ODP
//! (online dynamic pruning with significance-aware token protection),
//! implemented as a three-layer rust + JAX + Pallas stack. See
//! DESIGN.md for the architecture and experiment index.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod moe;
pub mod obs;
pub mod odp;
pub mod offload;
pub mod pmq;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
