//! x86-64 SIMD backends: AVX2+FMA (8 f32 lanes) and AVX-512F (16
//! lanes). Lane groups always map to *output columns*, so SIMD never
//! changes any element's K-accumulation order — per-element results
//! differ from scalar only where FMA fuses a mul+add rounding step
//! (axpy/axpy4 and the packed/attention accumulators; see the
//! tolerance contract in `tests/kernel_parity.rs`). The scale/zero
//! application and dequant stages replicate the scalar op sequence
//! with separate mul/sub/add (no FMA), so they are bit-exact.
//!
//! Unsafe boundary (DESIGN.md §4): every `#[target_feature]` fn is
//! private and reachable only through the safe wrappers below, which
//! the dispatch tables in `kernels::` hand out strictly after
//! `is_x86_feature_detected!` confirms the ISA at runtime.
//!
//! Two deliberate non-uses:
//!  * variable shifts go through `_mm*_srl_epi32` with the count in an
//!    xmm register (`_mm*_srli_epi32` needs a const immediate, but the
//!    packed bit-field offset is runtime data);
//!  * the binary kernel does NOT use popcount: activations are f32, so
//!    a popcount would only count bits, not weight the sum by x. The
//!    mask-select lanes below (cmpeq -> and_ps -> add) keep the exact
//!    masked-add semantics of the scalar kernel.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

/// Safe wrapper over a `#[target_feature]` impl fn.
/// Safety argument, shared by every expansion: the enclosing table is
/// only returned by `kernels::table_for` after runtime detection of
/// the features the impl fn enables.
macro_rules! wrap {
    ($name:ident => $imp:ident ( $($arg:ident : $ty:ty),* ) $(-> $ret:ty)?) => {
        pub fn $name($($arg: $ty),*) $(-> $ret)? {
            unsafe { $imp($($arg),*) }
        }
    };
}

pub mod avx2 {
    use super::*;

    wrap!(axpy => axpy_imp(y: &mut [f32], w: &[f32], a: f32));
    wrap!(axpy4 => axpy4_imp(y: &mut [f32], w0: &[f32], w1: &[f32],
                             w2: &[f32], w3: &[f32], a: [f32; 4]));
    wrap!(packed_word_acc => packed_word_acc_imp(
        acc: &mut [f32], words: &[u32], xs: &[f32], shift: u32, bits: u32));
    wrap!(packed_scale_apply => packed_scale_apply_imp(
        y: &mut [f32], acc: &[f32], scales: &[f32], zeros: &[f32], xsum: f32));
    wrap!(packed_dequant_row => packed_dequant_row_imp(
        wrow: &mut [f32], words: &[u32], scales: &[f32], zeros: &[f32],
        field: u32, bits: u32));
    wrap!(binary_word_acc => binary_word_acc_imp(
        y: &mut [f32], words: &[u32], xs: &[f32]));
    wrap!(binary_scale_apply => binary_scale_apply_imp(
        y: &mut [f32], scales: &[f32], xsum: f32));
    wrap!(vmax => vmax_imp(x: &[f32]) -> f32);
    wrap!(vscale => vscale_imp(x: &mut [f32], s: f32));

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_imp(y: &mut [f32], w: &[f32], a: f32) {
        let n = y.len().min(w.len());
        let yp = y.as_mut_ptr();
        let wp = w.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let wv = _mm256_loadu_ps(wp.add(i));
            _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(av, wv, yv));
            i += 8;
        }
        while i < n {
            *yp.add(i) += a * *wp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy4_imp(
        y: &mut [f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        a: [f32; 4],
    ) {
        let n = y
            .len()
            .min(w0.len())
            .min(w1.len())
            .min(w2.len())
            .min(w3.len());
        let yp = y.as_mut_ptr();
        let a0 = _mm256_set1_ps(a[0]);
        let a1 = _mm256_set1_ps(a[1]);
        let a2 = _mm256_set1_ps(a[2]);
        let a3 = _mm256_set1_ps(a[3]);
        let mut i = 0;
        while i + 8 <= n {
            let mut acc = _mm256_loadu_ps(yp.add(i));
            acc = _mm256_fmadd_ps(a0, _mm256_loadu_ps(w0.as_ptr().add(i)), acc);
            acc = _mm256_fmadd_ps(a1, _mm256_loadu_ps(w1.as_ptr().add(i)), acc);
            acc = _mm256_fmadd_ps(a2, _mm256_loadu_ps(w2.as_ptr().add(i)), acc);
            acc = _mm256_fmadd_ps(a3, _mm256_loadu_ps(w3.as_ptr().add(i)), acc);
            _mm256_storeu_ps(yp.add(i), acc);
            i += 8;
        }
        while i < n {
            *yp.add(i) +=
                a[0] * w0[i] + a[1] * w1[i] + a[2] * w2[i] + a[3] * w3[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn packed_word_acc_imp(
        acc: &mut [f32],
        words: &[u32],
        xs: &[f32],
        shift: u32,
        bits: u32,
    ) {
        let n = acc.len().min(words.len());
        let mask = (1u32 << bits) - 1;
        let maskv = _mm256_set1_epi32(mask as i32);
        let ap = acc.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 8 <= n {
            let wv = _mm256_loadu_si256(wp.add(c) as *const __m256i);
            let mut s = _mm256_setzero_ps();
            for (j, &xv) in xs.iter().enumerate() {
                let sh = shift + j as u32 * bits;
                let q = _mm256_and_si256(
                    _mm256_srl_epi32(wv, _mm_cvtsi32_si128(sh as i32)),
                    maskv,
                );
                s = _mm256_fmadd_ps(
                    _mm256_set1_ps(xv),
                    _mm256_cvtepi32_ps(q),
                    s,
                );
            }
            let av = _mm256_loadu_ps(ap.add(c));
            _mm256_storeu_ps(ap.add(c), _mm256_add_ps(av, s));
            c += 8;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            for (j, &xv) in xs.iter().enumerate() {
                let q = (word >> (shift + j as u32 * bits)) & mask;
                s += xv * q as f32;
            }
            *ap.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn packed_scale_apply_imp(
        y: &mut [f32],
        acc: &[f32],
        scales: &[f32],
        zeros: &[f32],
        xsum: f32,
    ) {
        let n = y.len().min(acc.len()).min(scales.len()).min(zeros.len());
        let yp = y.as_mut_ptr();
        let xv = _mm256_set1_ps(xsum);
        let mut c = 0;
        // mul/sub/mul/add exactly as scalar (no FMA) => bit-exact
        while c + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(c));
            let s = _mm256_loadu_ps(scales.as_ptr().add(c));
            let z = _mm256_loadu_ps(zeros.as_ptr().add(c));
            let t = _mm256_sub_ps(a, _mm256_mul_ps(z, xv));
            let yv = _mm256_loadu_ps(yp.add(c));
            _mm256_storeu_ps(yp.add(c), _mm256_add_ps(yv, _mm256_mul_ps(s, t)));
            c += 8;
        }
        while c < n {
            *yp.add(c) += scales[c] * (acc[c] - zeros[c] * xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn packed_dequant_row_imp(
        wrow: &mut [f32],
        words: &[u32],
        scales: &[f32],
        zeros: &[f32],
        field: u32,
        bits: u32,
    ) {
        let n = wrow.len().min(words.len()).min(scales.len()).min(zeros.len());
        let mask = (1u32 << bits) - 1;
        let maskv = _mm256_set1_epi32(mask as i32);
        let count = _mm_cvtsi32_si128(field as i32);
        let wp = wrow.as_mut_ptr();
        let mut c = 0;
        // cvt/sub/mul exactly as scalar (no FMA) => bit-exact
        while c + 8 <= n {
            let words8 =
                _mm256_loadu_si256(words.as_ptr().add(c) as *const __m256i);
            let q = _mm256_cvtepi32_ps(_mm256_and_si256(
                _mm256_srl_epi32(words8, count),
                maskv,
            ));
            let z = _mm256_loadu_ps(zeros.as_ptr().add(c));
            let s = _mm256_loadu_ps(scales.as_ptr().add(c));
            _mm256_storeu_ps(wp.add(c), _mm256_mul_ps(_mm256_sub_ps(q, z), s));
            c += 8;
        }
        while c < n {
            let q = (words[c] >> field) & mask;
            *wp.add(c) = (q as f32 - zeros[c]) * scales[c];
            c += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn binary_word_acc_imp(y: &mut [f32], words: &[u32], xs: &[f32]) {
        let n = y.len().min(words.len());
        let yp = y.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 8 <= n {
            let wv = _mm256_loadu_si256(wp.add(c) as *const __m256i);
            let mut s = _mm256_setzero_ps();
            for (j, &xv) in xs.iter().enumerate() {
                let bitv = _mm256_set1_epi32((1u32 << j) as i32);
                let hit =
                    _mm256_cmpeq_epi32(_mm256_and_si256(wv, bitv), bitv);
                s = _mm256_add_ps(
                    s,
                    _mm256_and_ps(
                        _mm256_castsi256_ps(hit),
                        _mm256_set1_ps(xv),
                    ),
                );
            }
            let yv = _mm256_loadu_ps(yp.add(c));
            _mm256_storeu_ps(yp.add(c), _mm256_add_ps(yv, s));
            c += 8;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs {
                s += xv * (bits & 1) as f32;
                bits >>= 1;
            }
            *yp.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn binary_scale_apply_imp(y: &mut [f32], scales: &[f32], xsum: f32) {
        let n = y.len().min(scales.len());
        let yp = y.as_mut_ptr();
        let two = _mm256_set1_ps(2.0);
        let xv = _mm256_set1_ps(xsum);
        let mut c = 0;
        // mul/sub/mul exactly as scalar (no FMA) => bit-exact
        while c + 8 <= n {
            let yv = _mm256_loadu_ps(yp.add(c));
            let s = _mm256_loadu_ps(scales.as_ptr().add(c));
            let t = _mm256_sub_ps(_mm256_mul_ps(two, yv), xv);
            _mm256_storeu_ps(yp.add(c), _mm256_mul_ps(s, t));
            c += 8;
        }
        while c < n {
            *yp.add(c) = scales[c] * (2.0 * *yp.add(c) - xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn vmax_imp(x: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        let n = x.len();
        let xp = x.as_ptr();
        let mut i = 0;
        if n >= 8 {
            let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
            while i + 8 <= n {
                mv = _mm256_max_ps(mv, _mm256_loadu_ps(xp.add(i)));
                i += 8;
            }
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), mv);
            for &l in &lanes {
                m = m.max(l);
            }
        }
        while i < n {
            m = m.max(*xp.add(i));
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn vscale_imp(x: &mut [f32], s: f32) {
        let n = x.len();
        let xp = x.as_mut_ptr();
        let sv = _mm256_set1_ps(s);
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(xp.add(i), _mm256_mul_ps(_mm256_loadu_ps(xp.add(i)), sv));
            i += 8;
        }
        while i < n {
            *xp.add(i) *= s;
            i += 1;
        }
    }
}

pub mod avx512 {
    use super::*;

    wrap!(axpy => axpy_imp(y: &mut [f32], w: &[f32], a: f32));
    wrap!(axpy4 => axpy4_imp(y: &mut [f32], w0: &[f32], w1: &[f32],
                             w2: &[f32], w3: &[f32], a: [f32; 4]));
    wrap!(packed_word_acc => packed_word_acc_imp(
        acc: &mut [f32], words: &[u32], xs: &[f32], shift: u32, bits: u32));
    wrap!(packed_scale_apply => packed_scale_apply_imp(
        y: &mut [f32], acc: &[f32], scales: &[f32], zeros: &[f32], xsum: f32));
    wrap!(packed_dequant_row => packed_dequant_row_imp(
        wrow: &mut [f32], words: &[u32], scales: &[f32], zeros: &[f32],
        field: u32, bits: u32));
    wrap!(binary_word_acc => binary_word_acc_imp(
        y: &mut [f32], words: &[u32], xs: &[f32]));
    wrap!(binary_scale_apply => binary_scale_apply_imp(
        y: &mut [f32], scales: &[f32], xsum: f32));
    wrap!(vmax => vmax_imp(x: &[f32]) -> f32);
    wrap!(vscale => vscale_imp(x: &mut [f32], s: f32));

    /// Unaligned 16-lane integer load (packed words are u32 streams).
    #[target_feature(enable = "avx512f")]
    unsafe fn load_si512(p: *const u32) -> __m512i {
        p.cast::<__m512i>().read_unaligned()
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn axpy_imp(y: &mut [f32], w: &[f32], a: f32) {
        let n = y.len().min(w.len());
        let yp = y.as_mut_ptr();
        let wp = w.as_ptr();
        let av = _mm512_set1_ps(a);
        let mut i = 0;
        while i + 16 <= n {
            let yv = _mm512_loadu_ps(yp.add(i));
            let wv = _mm512_loadu_ps(wp.add(i));
            _mm512_storeu_ps(yp.add(i), _mm512_fmadd_ps(av, wv, yv));
            i += 16;
        }
        while i < n {
            *yp.add(i) += a * *wp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn axpy4_imp(
        y: &mut [f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        a: [f32; 4],
    ) {
        let n = y
            .len()
            .min(w0.len())
            .min(w1.len())
            .min(w2.len())
            .min(w3.len());
        let yp = y.as_mut_ptr();
        let a0 = _mm512_set1_ps(a[0]);
        let a1 = _mm512_set1_ps(a[1]);
        let a2 = _mm512_set1_ps(a[2]);
        let a3 = _mm512_set1_ps(a[3]);
        let mut i = 0;
        while i + 16 <= n {
            let mut acc = _mm512_loadu_ps(yp.add(i));
            acc = _mm512_fmadd_ps(a0, _mm512_loadu_ps(w0.as_ptr().add(i)), acc);
            acc = _mm512_fmadd_ps(a1, _mm512_loadu_ps(w1.as_ptr().add(i)), acc);
            acc = _mm512_fmadd_ps(a2, _mm512_loadu_ps(w2.as_ptr().add(i)), acc);
            acc = _mm512_fmadd_ps(a3, _mm512_loadu_ps(w3.as_ptr().add(i)), acc);
            _mm512_storeu_ps(yp.add(i), acc);
            i += 16;
        }
        while i < n {
            *yp.add(i) +=
                a[0] * w0[i] + a[1] * w1[i] + a[2] * w2[i] + a[3] * w3[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn packed_word_acc_imp(
        acc: &mut [f32],
        words: &[u32],
        xs: &[f32],
        shift: u32,
        bits: u32,
    ) {
        let n = acc.len().min(words.len());
        let mask = (1u32 << bits) - 1;
        let maskv = _mm512_set1_epi32(mask as i32);
        let ap = acc.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 16 <= n {
            let wv = load_si512(wp.add(c));
            let mut s = _mm512_setzero_ps();
            for (j, &xv) in xs.iter().enumerate() {
                let sh = shift + j as u32 * bits;
                let q = _mm512_and_si512(
                    _mm512_srl_epi32(wv, _mm_cvtsi32_si128(sh as i32)),
                    maskv,
                );
                s = _mm512_fmadd_ps(
                    _mm512_set1_ps(xv),
                    _mm512_cvtepi32_ps(q),
                    s,
                );
            }
            let av = _mm512_loadu_ps(ap.add(c));
            _mm512_storeu_ps(ap.add(c), _mm512_add_ps(av, s));
            c += 16;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            for (j, &xv) in xs.iter().enumerate() {
                let q = (word >> (shift + j as u32 * bits)) & mask;
                s += xv * q as f32;
            }
            *ap.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn packed_scale_apply_imp(
        y: &mut [f32],
        acc: &[f32],
        scales: &[f32],
        zeros: &[f32],
        xsum: f32,
    ) {
        let n = y.len().min(acc.len()).min(scales.len()).min(zeros.len());
        let yp = y.as_mut_ptr();
        let xv = _mm512_set1_ps(xsum);
        let mut c = 0;
        while c + 16 <= n {
            let a = _mm512_loadu_ps(acc.as_ptr().add(c));
            let s = _mm512_loadu_ps(scales.as_ptr().add(c));
            let z = _mm512_loadu_ps(zeros.as_ptr().add(c));
            let t = _mm512_sub_ps(a, _mm512_mul_ps(z, xv));
            let yv = _mm512_loadu_ps(yp.add(c));
            _mm512_storeu_ps(yp.add(c), _mm512_add_ps(yv, _mm512_mul_ps(s, t)));
            c += 16;
        }
        while c < n {
            *yp.add(c) += scales[c] * (acc[c] - zeros[c] * xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn packed_dequant_row_imp(
        wrow: &mut [f32],
        words: &[u32],
        scales: &[f32],
        zeros: &[f32],
        field: u32,
        bits: u32,
    ) {
        let n = wrow.len().min(words.len()).min(scales.len()).min(zeros.len());
        let mask = (1u32 << bits) - 1;
        let maskv = _mm512_set1_epi32(mask as i32);
        let count = _mm_cvtsi32_si128(field as i32);
        let wp = wrow.as_mut_ptr();
        let mut c = 0;
        while c + 16 <= n {
            let words16 = load_si512(words.as_ptr().add(c));
            let q = _mm512_cvtepi32_ps(_mm512_and_si512(
                _mm512_srl_epi32(words16, count),
                maskv,
            ));
            let z = _mm512_loadu_ps(zeros.as_ptr().add(c));
            let s = _mm512_loadu_ps(scales.as_ptr().add(c));
            _mm512_storeu_ps(wp.add(c), _mm512_mul_ps(_mm512_sub_ps(q, z), s));
            c += 16;
        }
        while c < n {
            let q = (words[c] >> field) & mask;
            *wp.add(c) = (q as f32 - zeros[c]) * scales[c];
            c += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn binary_word_acc_imp(y: &mut [f32], words: &[u32], xs: &[f32]) {
        let n = y.len().min(words.len());
        let yp = y.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 16 <= n {
            let wv = load_si512(wp.add(c));
            let mut s = _mm512_setzero_ps();
            for (j, &xv) in xs.iter().enumerate() {
                let bitv = _mm512_set1_epi32((1u32 << j) as i32);
                let hit: __mmask16 =
                    _mm512_cmpeq_epi32_mask(_mm512_and_si512(wv, bitv), bitv);
                s = _mm512_mask_add_ps(s, hit, s, _mm512_set1_ps(xv));
            }
            let yv = _mm512_loadu_ps(yp.add(c));
            _mm512_storeu_ps(yp.add(c), _mm512_add_ps(yv, s));
            c += 16;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs {
                s += xv * (bits & 1) as f32;
                bits >>= 1;
            }
            *yp.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn binary_scale_apply_imp(y: &mut [f32], scales: &[f32], xsum: f32) {
        let n = y.len().min(scales.len());
        let yp = y.as_mut_ptr();
        let two = _mm512_set1_ps(2.0);
        let xv = _mm512_set1_ps(xsum);
        let mut c = 0;
        while c + 16 <= n {
            let yv = _mm512_loadu_ps(yp.add(c));
            let s = _mm512_loadu_ps(scales.as_ptr().add(c));
            let t = _mm512_sub_ps(_mm512_mul_ps(two, yv), xv);
            _mm512_storeu_ps(yp.add(c), _mm512_mul_ps(s, t));
            c += 16;
        }
        while c < n {
            *yp.add(c) = scales[c] * (2.0 * *yp.add(c) - xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn vmax_imp(x: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        let n = x.len();
        let xp = x.as_ptr();
        let mut i = 0;
        if n >= 16 {
            let mut mv = _mm512_set1_ps(f32::NEG_INFINITY);
            while i + 16 <= n {
                mv = _mm512_max_ps(mv, _mm512_loadu_ps(xp.add(i)));
                i += 16;
            }
            let mut lanes = [0.0f32; 16];
            _mm512_storeu_ps(lanes.as_mut_ptr(), mv);
            for &l in &lanes {
                m = m.max(l);
            }
        }
        while i < n {
            m = m.max(*xp.add(i));
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn vscale_imp(x: &mut [f32], s: f32) {
        let n = x.len();
        let xp = x.as_mut_ptr();
        let sv = _mm512_set1_ps(s);
        let mut i = 0;
        while i + 16 <= n {
            _mm512_storeu_ps(
                xp.add(i),
                _mm512_mul_ps(_mm512_loadu_ps(xp.add(i)), sv),
            );
            i += 16;
        }
        while i < n {
            *xp.add(i) *= s;
            i += 1;
        }
    }
}
