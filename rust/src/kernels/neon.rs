//! aarch64 NEON backend (4 f32 lanes). Same structure and the same
//! numerical contract as `kernels::x86`: lane groups map to output
//! columns, accumulation stages use `vfmaq_f32` (fused, so they carry
//! the FMA tolerance documented in `tests/kernel_parity.rs`), and the
//! scale/zero application stages replicate the scalar op sequence
//! exactly (separate mul/sub/add — bit-exact). Variable right shifts
//! go through `vshlq_u32` with a negated shift count, NEON's idiom
//! for a runtime shift amount.
//!
//! This file only compiles on aarch64; CI's x86 runners gate it via
//! `cfg`, so the parity suite on an aarch64 host is the compile and
//! correctness check for this backend.

#![cfg(target_arch = "aarch64")]

pub mod neon {
    use std::arch::aarch64::*;

    /// Safe wrapper over a `#[target_feature(enable = "neon")]` impl.
    /// Safety: the `kernels::` dispatch table containing these
    /// wrappers is only handed out after
    /// `is_aarch64_feature_detected!("neon")` succeeds.
    macro_rules! wrap {
        ($name:ident => $imp:ident ( $($arg:ident : $ty:ty),* ) $(-> $ret:ty)?) => {
            pub fn $name($($arg: $ty),*) $(-> $ret)? {
                unsafe { $imp($($arg),*) }
            }
        };
    }

    wrap!(axpy => axpy_imp(y: &mut [f32], w: &[f32], a: f32));
    wrap!(axpy4 => axpy4_imp(y: &mut [f32], w0: &[f32], w1: &[f32],
                             w2: &[f32], w3: &[f32], a: [f32; 4]));
    wrap!(packed_word_acc => packed_word_acc_imp(
        acc: &mut [f32], words: &[u32], xs: &[f32], shift: u32, bits: u32));
    wrap!(packed_scale_apply => packed_scale_apply_imp(
        y: &mut [f32], acc: &[f32], scales: &[f32], zeros: &[f32], xsum: f32));
    wrap!(packed_dequant_row => packed_dequant_row_imp(
        wrow: &mut [f32], words: &[u32], scales: &[f32], zeros: &[f32],
        field: u32, bits: u32));
    wrap!(binary_word_acc => binary_word_acc_imp(
        y: &mut [f32], words: &[u32], xs: &[f32]));
    wrap!(binary_scale_apply => binary_scale_apply_imp(
        y: &mut [f32], scales: &[f32], xsum: f32));
    wrap!(vmax => vmax_imp(x: &[f32]) -> f32);
    wrap!(vscale => vscale_imp(x: &mut [f32], s: f32));

    #[target_feature(enable = "neon")]
    unsafe fn axpy_imp(y: &mut [f32], w: &[f32], a: f32) {
        let n = y.len().min(w.len());
        let yp = y.as_mut_ptr();
        let wp = w.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let yv = vld1q_f32(yp.add(i));
            let wv = vld1q_f32(wp.add(i));
            vst1q_f32(yp.add(i), vfmaq_f32(yv, av, wv));
            i += 4;
        }
        while i < n {
            *yp.add(i) += a * *wp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy4_imp(
        y: &mut [f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        a: [f32; 4],
    ) {
        let n = y
            .len()
            .min(w0.len())
            .min(w1.len())
            .min(w2.len())
            .min(w3.len());
        let yp = y.as_mut_ptr();
        let a0 = vdupq_n_f32(a[0]);
        let a1 = vdupq_n_f32(a[1]);
        let a2 = vdupq_n_f32(a[2]);
        let a3 = vdupq_n_f32(a[3]);
        let mut i = 0;
        while i + 4 <= n {
            let mut acc = vld1q_f32(yp.add(i));
            acc = vfmaq_f32(acc, a0, vld1q_f32(w0.as_ptr().add(i)));
            acc = vfmaq_f32(acc, a1, vld1q_f32(w1.as_ptr().add(i)));
            acc = vfmaq_f32(acc, a2, vld1q_f32(w2.as_ptr().add(i)));
            acc = vfmaq_f32(acc, a3, vld1q_f32(w3.as_ptr().add(i)));
            vst1q_f32(yp.add(i), acc);
            i += 4;
        }
        while i < n {
            *yp.add(i) +=
                a[0] * w0[i] + a[1] * w1[i] + a[2] * w2[i] + a[3] * w3[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn packed_word_acc_imp(
        acc: &mut [f32],
        words: &[u32],
        xs: &[f32],
        shift: u32,
        bits: u32,
    ) {
        let n = acc.len().min(words.len());
        let mask = (1u32 << bits) - 1;
        let maskv = vdupq_n_u32(mask);
        let ap = acc.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 4 <= n {
            let wv = vld1q_u32(wp.add(c));
            let mut s = vdupq_n_f32(0.0);
            for (j, &xv) in xs.iter().enumerate() {
                let sh = shift + j as u32 * bits;
                // NEON right shift by a runtime amount: left shift by
                // the negated count.
                let q = vandq_u32(
                    vshlq_u32(wv, vdupq_n_s32(-(sh as i32))),
                    maskv,
                );
                s = vfmaq_f32(s, vdupq_n_f32(xv), vcvtq_f32_u32(q));
            }
            let av = vld1q_f32(ap.add(c));
            vst1q_f32(ap.add(c), vaddq_f32(av, s));
            c += 4;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            for (j, &xv) in xs.iter().enumerate() {
                let q = (word >> (shift + j as u32 * bits)) & mask;
                s += xv * q as f32;
            }
            *ap.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn packed_scale_apply_imp(
        y: &mut [f32],
        acc: &[f32],
        scales: &[f32],
        zeros: &[f32],
        xsum: f32,
    ) {
        let n = y.len().min(acc.len()).min(scales.len()).min(zeros.len());
        let yp = y.as_mut_ptr();
        let xv = vdupq_n_f32(xsum);
        let mut c = 0;
        // mul/sub/mul/add exactly as scalar (no FMA) => bit-exact
        while c + 4 <= n {
            let a = vld1q_f32(acc.as_ptr().add(c));
            let s = vld1q_f32(scales.as_ptr().add(c));
            let z = vld1q_f32(zeros.as_ptr().add(c));
            let t = vsubq_f32(a, vmulq_f32(z, xv));
            let yv = vld1q_f32(yp.add(c));
            vst1q_f32(yp.add(c), vaddq_f32(yv, vmulq_f32(s, t)));
            c += 4;
        }
        while c < n {
            *yp.add(c) += scales[c] * (acc[c] - zeros[c] * xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn packed_dequant_row_imp(
        wrow: &mut [f32],
        words: &[u32],
        scales: &[f32],
        zeros: &[f32],
        field: u32,
        bits: u32,
    ) {
        let n = wrow.len().min(words.len()).min(scales.len()).min(zeros.len());
        let mask = (1u32 << bits) - 1;
        let maskv = vdupq_n_u32(mask);
        let shv = vdupq_n_s32(-(field as i32));
        let wp = wrow.as_mut_ptr();
        let mut c = 0;
        // cvt/sub/mul exactly as scalar (no FMA) => bit-exact
        while c + 4 <= n {
            let words4 = vld1q_u32(words.as_ptr().add(c));
            let q = vcvtq_f32_u32(vandq_u32(vshlq_u32(words4, shv), maskv));
            let z = vld1q_f32(zeros.as_ptr().add(c));
            let s = vld1q_f32(scales.as_ptr().add(c));
            vst1q_f32(wp.add(c), vmulq_f32(vsubq_f32(q, z), s));
            c += 4;
        }
        while c < n {
            let q = (words[c] >> field) & mask;
            *wp.add(c) = (q as f32 - zeros[c]) * scales[c];
            c += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn binary_word_acc_imp(y: &mut [f32], words: &[u32], xs: &[f32]) {
        let n = y.len().min(words.len());
        let yp = y.as_mut_ptr();
        let wp = words.as_ptr();
        let mut c = 0;
        while c + 4 <= n {
            let wv = vld1q_u32(wp.add(c));
            let mut s = vdupq_n_f32(0.0);
            for (j, &xv) in xs.iter().enumerate() {
                let bitv = vdupq_n_u32(1u32 << j);
                let hit = vceqq_u32(vandq_u32(wv, bitv), bitv);
                let sel = vandq_u32(hit, vreinterpretq_u32_f32(vdupq_n_f32(xv)));
                s = vaddq_f32(s, vreinterpretq_f32_u32(sel));
            }
            let yv = vld1q_f32(yp.add(c));
            vst1q_f32(yp.add(c), vaddq_f32(yv, s));
            c += 4;
        }
        while c < n {
            let word = *wp.add(c);
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs {
                s += xv * (bits & 1) as f32;
                bits >>= 1;
            }
            *yp.add(c) += s;
            c += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn binary_scale_apply_imp(y: &mut [f32], scales: &[f32], xsum: f32) {
        let n = y.len().min(scales.len());
        let yp = y.as_mut_ptr();
        let two = vdupq_n_f32(2.0);
        let xv = vdupq_n_f32(xsum);
        let mut c = 0;
        // mul/sub/mul exactly as scalar (no FMA) => bit-exact
        while c + 4 <= n {
            let yv = vld1q_f32(yp.add(c));
            let s = vld1q_f32(scales.as_ptr().add(c));
            let t = vsubq_f32(vmulq_f32(two, yv), xv);
            vst1q_f32(yp.add(c), vmulq_f32(s, t));
            c += 4;
        }
        while c < n {
            *yp.add(c) = scales[c] * (2.0 * *yp.add(c) - xsum);
            c += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn vmax_imp(x: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        let n = x.len();
        let xp = x.as_ptr();
        let mut i = 0;
        if n >= 4 {
            let mut mv = vdupq_n_f32(f32::NEG_INFINITY);
            while i + 4 <= n {
                mv = vmaxq_f32(mv, vld1q_f32(xp.add(i)));
                i += 4;
            }
            let mut lanes = [0.0f32; 4];
            vst1q_f32(lanes.as_mut_ptr(), mv);
            for &l in &lanes {
                m = m.max(l);
            }
        }
        while i < n {
            m = m.max(*xp.add(i));
            i += 1;
        }
        m
    }

    #[target_feature(enable = "neon")]
    unsafe fn vscale_imp(x: &mut [f32], s: f32) {
        let n = x.len();
        let xp = x.as_mut_ptr();
        let sv = vdupq_n_f32(s);
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(xp.add(i), vmulq_f32(vld1q_f32(xp.add(i)), sv));
            i += 4;
        }
        while i < n {
            *xp.add(i) *= s;
            i += 1;
        }
    }
}
