//! Scalar reference backend: the PR-3 kernel inner loops, kept
//! verbatim (same accumulation order, same static word unrolls) so
//! every SIMD backend has a fixed numerical reference to be tested
//! against. `MC_KERNEL=scalar` pins the whole engine to this path.

/// y[c] += a * w[c]
pub fn axpy(y: &mut [f32], w: &[f32], a: f32) {
    for (yv, &wv) in y.iter_mut().zip(w) {
        *yv += a * wv;
    }
}

/// y[c] += a0*w0[c] + a1*w1[c] + a2*w2[c] + a3*w3[c]
/// (4 independent FMA streams; the tiled GEMM's K-unrolled inner loop)
pub fn axpy4(
    y: &mut [f32],
    w0: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
    a: [f32; 4],
) {
    let [a0, a1, a2, a3] = a;
    for ((((yv, &b0), &b1), &b2), &b3) in
        y.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
    {
        *yv += a0 * b0 + a1 * b1 + a2 * b2 + a3 * b3;
    }
}

/// Fused word-decode accumulation for the packed small-M kernel:
///   acc[c] += Σ_j xs[j] * ((words[c] >> (shift + j*bits)) & mask)
/// Full words (shift == 0, xs.len() == vals-per-word) take a
/// statically-unrolled path per bit-width, exactly as the PR-3
/// const-generic kernel did; each word contributes one partial sum
/// `s` that is added to `acc[c]` in a single rounding step.
pub fn packed_word_acc(
    acc: &mut [f32],
    words: &[u32],
    xs: &[f32],
    shift: u32,
    bits: u32,
) {
    match bits {
        2 => word_acc::<2, 16>(acc, words, xs, shift),
        3 => word_acc::<3, 10>(acc, words, xs, shift),
        4 => word_acc::<4, 8>(acc, words, xs, shift),
        other => panic!("unsupported packed bit-width {other}"),
    }
}

fn word_acc<const BITS: u32, const VPW: usize>(
    acc: &mut [f32],
    words: &[u32],
    xs: &[f32],
    shift: u32,
) {
    let mask = (1u32 << BITS) - 1;
    if shift == 0 && xs.len() == VPW {
        // full word: statically-unrolled decode
        let xs: &[f32; VPW] = xs.try_into().unwrap();
        for (a, &word) in acc.iter_mut().zip(words) {
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs.iter() {
                s += xv * (bits & mask) as f32;
                bits >>= BITS;
            }
            *a += s;
        }
    } else {
        // group edge inside a word
        for (a, &word) in acc.iter_mut().zip(words) {
            let mut s = 0.0f32;
            let mut bits = word >> shift;
            for &xv in xs {
                s += xv * (bits & mask) as f32;
                bits >>= BITS;
            }
            *a += s;
        }
    }
}

/// Group-factored scale/zero application (paper Eq. in qmatmul.rs):
///   y[c] += scales[c] * (acc[c] - zeros[c] * xsum)
/// Every backend replicates this exact mul/sub/mul/add sequence (no
/// FMA contraction), so the application stage is bit-exact across
/// ISAs; only the accumulation stages carry FMA tolerances.
pub fn packed_scale_apply(
    y: &mut [f32],
    acc: &[f32],
    scales: &[f32],
    zeros: &[f32],
    xsum: f32,
) {
    for (((yv, &a), &s), &z) in
        y.iter_mut().zip(acc).zip(scales).zip(zeros)
    {
        *yv += s * (a - z * xsum);
    }
}

/// Decode one packed weight row (bit-field `field` of each word) into
/// dequantized f32: wrow[c] = (q - zeros[c]) * scales[c].
pub fn packed_dequant_row(
    wrow: &mut [f32],
    words: &[u32],
    scales: &[f32],
    zeros: &[f32],
    field: u32,
    bits: u32,
) {
    let mask = (1u32 << bits) - 1;
    for (((wv, &word), &s), &z) in
        wrow.iter_mut().zip(words).zip(scales).zip(zeros)
    {
        let q = (word >> field) & mask;
        *wv = (q as f32 - z) * s;
    }
}

/// Binary word accumulation: y[c] += Σ_j xs[j] * bit_j(words[c]),
/// statically unrolled for full 32-bit words.
pub fn binary_word_acc(y: &mut [f32], words: &[u32], xs: &[f32]) {
    if xs.len() == 32 {
        let xs: &[f32; 32] = xs.try_into().unwrap();
        for (yv, &word) in y.iter_mut().zip(words) {
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs.iter() {
                s += xv * (bits & 1) as f32;
                bits >>= 1;
            }
            *yv += s;
        }
    } else {
        for (yv, &word) in y.iter_mut().zip(words) {
            let mut s = 0.0f32;
            let mut bits = word;
            for &xv in xs {
                s += xv * (bits & 1) as f32;
                bits >>= 1;
            }
            *yv += s;
        }
    }
}

/// Binary reconstruction: y[c] = scales[c] * (2*y[c] - xsum)
/// (paper Eq. 10; same exact op sequence on every backend).
pub fn binary_scale_apply(y: &mut [f32], scales: &[f32], xsum: f32) {
    for (yv, &s) in y.iter_mut().zip(scales) {
        *yv = s * (2.0 * *yv - xsum);
    }
}

/// Row max (softmax stabilizer).
pub fn vmax(x: &[f32]) -> f32 {
    x.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

/// x[c] *= s (softmax normalization / score scaling).
pub fn vscale(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}
