//! Runtime-dispatched SIMD kernel backends.
//!
//! Every hot inner loop in the decode path (fused dequantize-GEMM,
//! binary matmul, f32 GEMM panels, attention score/softmax/AV) calls
//! through a [`KernelOps`] function table instead of a concrete
//! implementation. One table exists per instruction set:
//!
//!  * `scalar`  — the PR-3 register-blocked loops, kept verbatim; the
//!    numerical reference every other backend is tested against.
//!  * `avx2`    — AVX2 + FMA, 8 f32 lanes (any x86-64 since ~2013).
//!  * `avx512`  — AVX-512F, 16 f32 lanes.
//!  * `neon`    — aarch64 NEON, 4 f32 lanes.
//!
//! The active table is chosen **once** per process: the first call to
//! [`active`] runs CPU feature detection (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`) and picks the widest available ISA,
//! unless overridden by the `MC_KERNEL` environment variable or an
//! earlier [`force`] call (the `--kernel-backend` CLI flag). After
//! that the choice is immutable — callers cache `&'static KernelOps`
//! and fn-pointer calls are branch-predicted perfectly in the hot
//! loop.
//!
//! Soundness contract: the non-scalar tables are **only** reachable
//! through [`table_for`], which returns them strictly after runtime
//! detection confirms the features their `#[target_feature]` impls
//! enable. Tests and benches that want to exercise every compiled
//! backend side-by-side use [`available`] plus the `*_ops` kernel
//! entry points rather than the global selection.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

use std::sync::{Once, OnceLock};

/// Instruction-set families a kernel table can target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse a backend name (`MC_KERNEL` / `--kernel-backend`).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// One entry per hot primitive; see `kernels::scalar` for the
/// reference semantics of each. All entries are plain safe `fn`
/// pointers — SIMD tables hold safe wrappers around their
/// `#[target_feature]` implementations.
pub struct KernelOps {
    pub isa: Isa,
    /// y[c] += a * w[c]
    pub axpy: fn(&mut [f32], &[f32], f32),
    /// y[c] += a0*w0[c] + a1*w1[c] + a2*w2[c] + a3*w3[c]
    pub axpy4: fn(&mut [f32], &[f32], &[f32], &[f32], &[f32], [f32; 4]),
    /// acc[c] += Σ_j xs[j] * ((words[c] >> (shift + j*bits)) & mask)
    pub packed_word_acc: fn(&mut [f32], &[u32], &[f32], u32, u32),
    /// y[c] += scales[c] * (acc[c] - zeros[c] * xsum)
    pub packed_scale_apply: fn(&mut [f32], &[f32], &[f32], &[f32], f32),
    /// wrow[c] = ((words[c] >> field) & mask  - zeros[c]) * scales[c]
    pub packed_dequant_row: fn(&mut [f32], &[u32], &[f32], &[f32], u32, u32),
    /// y[c] += Σ_j xs[j] * bit_j(words[c])
    pub binary_word_acc: fn(&mut [f32], &[u32], &[f32]),
    /// y[c] = scales[c] * (2*y[c] - xsum)
    pub binary_scale_apply: fn(&mut [f32], &[f32], f32),
    /// max(x) (softmax stabilizer)
    pub vmax: fn(&[f32]) -> f32,
    /// x[c] *= s
    pub vscale: fn(&mut [f32], f32),
}

pub static SCALAR: KernelOps = KernelOps {
    isa: Isa::Scalar,
    axpy: scalar::axpy,
    axpy4: scalar::axpy4,
    packed_word_acc: scalar::packed_word_acc,
    packed_scale_apply: scalar::packed_scale_apply,
    packed_dequant_row: scalar::packed_dequant_row,
    binary_word_acc: scalar::binary_word_acc,
    binary_scale_apply: scalar::binary_scale_apply,
    vmax: scalar::vmax,
    vscale: scalar::vscale,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelOps = KernelOps {
    isa: Isa::Avx2,
    axpy: x86::avx2::axpy,
    axpy4: x86::avx2::axpy4,
    packed_word_acc: x86::avx2::packed_word_acc,
    packed_scale_apply: x86::avx2::packed_scale_apply,
    packed_dequant_row: x86::avx2::packed_dequant_row,
    binary_word_acc: x86::avx2::binary_word_acc,
    binary_scale_apply: x86::avx2::binary_scale_apply,
    vmax: x86::avx2::vmax,
    vscale: x86::avx2::vscale,
};

#[cfg(target_arch = "x86_64")]
static AVX512: KernelOps = KernelOps {
    isa: Isa::Avx512,
    axpy: x86::avx512::axpy,
    axpy4: x86::avx512::axpy4,
    packed_word_acc: x86::avx512::packed_word_acc,
    packed_scale_apply: x86::avx512::packed_scale_apply,
    packed_dequant_row: x86::avx512::packed_dequant_row,
    binary_word_acc: x86::avx512::binary_word_acc,
    binary_scale_apply: x86::avx512::binary_scale_apply,
    vmax: x86::avx512::vmax,
    vscale: x86::avx512::vscale,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelOps = KernelOps {
    isa: Isa::Neon,
    axpy: neon::neon::axpy,
    axpy4: neon::neon::axpy4,
    packed_word_acc: neon::neon::packed_word_acc,
    packed_scale_apply: neon::neon::packed_scale_apply,
    packed_dequant_row: neon::neon::packed_dequant_row,
    binary_word_acc: neon::neon::binary_word_acc,
    binary_scale_apply: neon::neon::binary_scale_apply,
    vmax: neon::neon::vmax,
    vscale: neon::neon::vscale,
};

/// The table for `isa`, if it is both compiled for this target AND
/// supported by the CPU we are running on (the soundness gate for
/// every `#[target_feature]` path).
pub fn table_for(isa: Isa) -> Option<&'static KernelOps> {
    match isa {
        Isa::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                Some(&AVX2)
            } else {
                None
            }
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => {
            if std::arch::is_x86_feature_detected!("avx512f") {
                Some(&AVX512)
            } else {
                None
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                Some(&NEON)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Widest ISA the running CPU supports.
fn detect_best() -> &'static KernelOps {
    for isa in [Isa::Avx512, Isa::Avx2, Isa::Neon] {
        if let Some(t) = table_for(isa) {
            return t;
        }
    }
    &SCALAR
}

fn choose() -> &'static KernelOps {
    match std::env::var("MC_KERNEL") {
        Ok(name) if !name.is_empty() => match Isa::parse(&name) {
            Some(isa) => match table_for(isa) {
                Some(t) => t,
                None => {
                    eprintln!(
                        "[kernels] MC_KERNEL={name}: backend not available \
                         on this CPU; auto-detecting"
                    );
                    detect_best()
                }
            },
            None => {
                eprintln!(
                    "[kernels] MC_KERNEL={name}: unknown backend \
                     (scalar|avx2|avx512|neon); auto-detecting"
                );
                detect_best()
            }
        },
        _ => detect_best(),
    }
}

static SELECTED: OnceLock<&'static KernelOps> = OnceLock::new();

/// The process-wide kernel table. First call selects (env override,
/// else detection) and the choice never changes afterwards.
pub fn active() -> &'static KernelOps {
    SELECTED.get_or_init(choose)
}

/// Pin the process-wide selection to `isa` (the `--kernel-backend`
/// flag). Errors if `isa` is unavailable on this CPU or if a
/// different backend has already been selected.
pub fn force(isa: Isa) -> Result<(), String> {
    let Some(t) = table_for(isa) else {
        return Err(format!(
            "kernel backend '{}' is not available on this CPU ({})",
            isa.name(),
            detected_summary(),
        ));
    };
    let got = SELECTED.get_or_init(|| t);
    if got.isa == isa {
        Ok(())
    } else {
        Err(format!(
            "kernel backend already selected as '{}'; cannot switch to '{}'",
            got.isa.name(),
            isa.name()
        ))
    }
}

/// [`force`] by name; errors on unknown names.
pub fn force_named(name: &str) -> Result<(), String> {
    match Isa::parse(name) {
        Some(isa) => force(isa),
        None => Err(format!(
            "unknown kernel backend '{name}' (expected scalar|avx2|avx512|neon)"
        )),
    }
}

/// Every table runnable on this machine, scalar reference first.
/// Parity tests and the roofline bench iterate this.
pub fn available() -> Vec<&'static KernelOps> {
    let mut v = vec![&SCALAR];
    for isa in [Isa::Avx2, Isa::Avx512, Isa::Neon] {
        if let Some(t) = table_for(isa) {
            v.push(t);
        }
    }
    v
}

/// Human-readable CPU feature summary for logs and bench metadata.
pub fn detected_summary() -> String {
    fn yn(b: bool) -> &'static str {
        if b {
            "yes"
        } else {
            "no"
        }
    }
    #[cfg(target_arch = "x86_64")]
    return format!(
        "x86_64 avx2={} fma={} avx512f={}",
        yn(std::arch::is_x86_feature_detected!("avx2")),
        yn(std::arch::is_x86_feature_detected!("fma")),
        yn(std::arch::is_x86_feature_detected!("avx512f")),
    );
    #[cfg(target_arch = "aarch64")]
    return format!(
        "aarch64 neon={}",
        yn(std::arch::is_aarch64_feature_detected!("neon")),
    );
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = yn;
        String::from(std::env::consts::ARCH)
    }
}

static BANNER: Once = Once::new();

/// Resolve the active table and log the detection + selection once
/// per process (engine/server startup).
pub fn log_selection() -> &'static KernelOps {
    let ops = active();
    BANNER.call_once(|| {
        eprintln!(
            "[kernels] cpu: {} | selected backend: {}",
            detected_summary(),
            ops.isa.name()
        );
    });
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("AVX512F"), Some(Isa::Avx512));
        assert_eq!(Isa::parse("Scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("sse9"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn available_starts_with_scalar_and_all_tables_run() {
        let v = available();
        assert_eq!(v[0].isa, Isa::Scalar);
        for ops in &v {
            // smoke: every advertised table must actually execute here
            let w: Vec<f32> = (0..37).map(|i| i as f32).collect();
            let mut y = vec![1.0f32; 37];
            (ops.axpy)(&mut y, &w, 2.0);
            assert_eq!(y[0], 1.0, "{}", ops.isa.name());
            assert_eq!(y[36], 73.0, "{}", ops.isa.name());
            assert_eq!((ops.vmax)(&w), 36.0, "{}", ops.isa.name());
        }
    }

    #[test]
    fn force_after_selection_is_consistent() {
        // Deterministic under any MC_KERNEL env (CI runs a scalar leg):
        // re-forcing the already-selected backend succeeds, forcing any
        // other backend errors (either unavailable or already pinned).
        let sel = active();
        assert!(force(sel.isa).is_ok());
        assert!(force_named(sel.isa.name()).is_ok());
        let other = if sel.isa == Isa::Scalar {
            Isa::Avx2
        } else {
            Isa::Scalar
        };
        assert!(force(other).is_err());
        assert!(force_named("not-an-isa").is_err());
    }
}
