//! Persistent worker pool (DESIGN.md §4).
//!
//! Dependency-free (no rayon) replacement for the per-layer
//! `std::thread::scope` spawns the dispatch path used before: threads
//! are spawned once (`WorkerPool::global()`, sized from
//! `available_parallelism`) and parallel regions are broadcast to them
//! over a condvar — no channel, no per-region heap allocation, so the
//! pool is usable from the zero-allocation decode hot path.
//!
//! The only primitive is [`WorkerPool::for_each`]: run `f(i)` for
//! `i in 0..n`, with indices claimed dynamically from a shared atomic
//! counter and the *caller participating* as one of the workers. Each
//! index runs exactly once, so tasks that write disjoint output
//! regions (expert batches, attention heads, GEMM column strips) are
//! bit-exact with serial execution — parallelism never changes a
//! reduction order, it only partitions writes (DESIGN.md §4 ownership
//! rules).
//!
//! Nested regions degrade to serial: a task that calls `for_each`
//! while running on a pool worker executes inline (checked via a
//! thread-local), which both bounds oversubscription and makes the
//! pool deadlock-free under composition (expert FFN → GEMM strips).
//! [`WorkerPool::run_inline`] exposes the same mechanism to callers
//! whose contract forbids parallelism (`DispatchMode::Serial`).
//!
//! Trade-off: a region is broadcast to *every* worker (each wakes,
//! claims what it can, and acknowledges), so region latency includes
//! one wake + mutex round per worker even when `n` is small. That
//! keeps the protocol allocation-free and the job lifetime trivially
//! sound; callers bound the cost by gating regions on work volume
//! (the `*_MIN_FLOPS`/`*_MIN_WORK` thresholds at each call site).

use std::any::Any;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

type PanicPayload = Box<dyn Any + Send>;

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a panic inside a task is re-raised on the caller; the pool's own
    // state is always consistent, so poisoning is ignorable
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Raw-pointer handle for pool tasks that write disjoint regions of a
/// shared buffer (GEMM column strips, attention head columns, expert
/// batches, per-task scratch rows). Constructing one asserts the
/// DESIGN.md §4 ownership rule: no two concurrent tasks may touch the
/// same index, and the pointee outlives the region (guaranteed by
/// `for_each` blocking until every worker exits).
pub struct SendPtr<T>(pub *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// Safety: see the ownership rule above — disjoint writes only, within
// a region whose lifetime is bounded by the caller's stack frame.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// One parallel region, broadcast to every worker. The references are
/// lifetime-erased borrows of the caller's stack frame; `for_each`
/// does not return until every worker has exited the region, so they
/// never dangle (see the transmute in `for_each`).
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    next: &'static AtomicUsize,
    panicked: &'static AtomicBool,
    /// first caught panic payload, re-raised on the caller so assert
    /// messages from pooled tasks survive the hop between threads
    payload: &'static Mutex<Option<PanicPayload>>,
    n: usize,
}

struct State {
    /// bumped once per region; workers run each generation exactly once
    gen: u64,
    job: Option<Job>,
    /// workers still inside the current region
    active: usize,
    stop: bool,
}

struct Inner {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

pub struct WorkerPool {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// serializes regions from concurrent callers (server thread vs
    /// an engine thread); waiting here is the back-pressure
    region: Mutex<()>,
}

fn run_job(job: &Job) {
    loop {
        if job.panicked.load(Ordering::Relaxed) {
            break;
        }
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        let f = job.f;
        if let Err(p) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)))
        {
            let mut slot = lock(job.payload);
            if slot.is_none() {
                *slot = Some(p);
            }
            drop(slot);
            job.panicked.store(true, Ordering::Relaxed);
            break;
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&inner.state);
            loop {
                if st.stop {
                    return;
                }
                if st.gen != seen {
                    seen = st.gen;
                    break st.job.expect("job set with generation bump");
                }
                st = inner
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            }
        };
        run_job(&job);
        let mut st = lock(&inner.state);
        st.active -= 1;
        if st.active == 0 {
            inner.done_cv.notify_all();
        }
    }
}

impl WorkerPool {
    /// Spawn a pool with `workers` persistent threads. The caller of
    /// `for_each` always participates, so the parallel width is
    /// `workers + 1`.
    pub fn new(workers: usize) -> WorkerPool {
        let inner = Arc::new(Inner {
            state: Mutex::new(State { gen: 0, job: None, active: 0, stop: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("mc-pool-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { inner, workers: handles, region: Mutex::new(()) }
    }

    /// The process-wide pool, started once on first use and sized from
    /// `available_parallelism` (N-1 workers + the participating
    /// caller). `McEngine` and `Batcher` touch this at construction so
    /// the spawn cost is paid at startup, not on the first request.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1);
            WorkerPool::new(cores.saturating_sub(1))
        })
    }

    /// Parallel width: worker threads plus the participating caller.
    pub fn width(&self) -> usize {
        self.workers.len() + 1
    }

    /// True when the current thread is a pool worker — callers use
    /// this to keep nested parallel regions serial.
    pub fn on_worker() -> bool {
        IN_POOL.with(|c| c.get())
    }

    /// Run `f` with the current thread flagged as a pool worker, so
    /// nested `for_each` calls and kernel auto-parallel heuristics
    /// execute inline for its duration. `DispatchMode::Serial` and
    /// `SpawnScope` use this to honor their in-thread contract —
    /// without it the GEMM layer would silently re-introduce the pool
    /// under a mode that promises not to use it (and would corrupt
    /// the serial baselines in `benches/hotpath.rs`).
    pub fn run_inline<R>(f: impl FnOnce() -> R) -> R {
        struct Restore(bool);
        impl Drop for Restore {
            fn drop(&mut self) {
                IN_POOL.with(|c| c.set(self.0));
            }
        }
        let _restore = Restore(IN_POOL.with(|c| c.replace(true)));
        f()
    }

    /// Run `f(i)` for every `i in 0..n` across the pool, returning
    /// once all indices have completed. Each index runs exactly once;
    /// the caller participates. Runs inline (serial) when the pool has
    /// no workers, `n < 2`, or the caller is itself a pool worker.
    pub fn for_each<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        if self.workers.is_empty() || n == 1 || Self::on_worker() {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let _region = lock(&self.region);
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let payload: Mutex<Option<PanicPayload>> = Mutex::new(None);
        let fref: &(dyn Fn(usize) + Sync) = &f;
        // Safety: lifetime erasure only — this function does not
        // return until every worker has left the region (the `active`
        // wait below), so the erased borrows never outlive the frame.
        let job = Job {
            f: unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(fref)
            },
            next: unsafe {
                std::mem::transmute::<&AtomicUsize, &'static AtomicUsize>(
                    &next,
                )
            },
            panicked: unsafe {
                std::mem::transmute::<&AtomicBool, &'static AtomicBool>(
                    &panicked,
                )
            },
            payload: unsafe {
                std::mem::transmute::<
                    &Mutex<Option<PanicPayload>>,
                    &'static Mutex<Option<PanicPayload>>,
                >(&payload)
            },
            n,
        };
        {
            let mut st = lock(&self.inner.state);
            st.gen = st.gen.wrapping_add(1);
            st.job = Some(job);
            st.active = self.workers.len();
            self.inner.work_cv.notify_all();
        }
        // the caller is one of the workers; it is flagged as such for
        // the duration so its own tasks' nested for_each calls run
        // inline instead of re-entering the (non-reentrant) region
        // lock. run_job never unwinds (tasks are caught), so the flag
        // is always restored.
        IN_POOL.with(|c| c.set(true));
        run_job(&job);
        IN_POOL.with(|c| c.set(false));
        let mut st = lock(&self.inner.state);
        while st.active > 0 {
            st = self
                .inner
                .done_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        drop(st);
        if panicked.load(Ordering::Relaxed) {
            let p = lock(&payload).take();
            match p {
                Some(p) => std::panic::resume_unwind(p),
                None => panic!("WorkerPool task panicked"),
            }
        }
    }

    /// Contiguous strip bounds for splitting `len` items into `tasks`
    /// near-equal ranges: returns `(start, end)` of strip `t`.
    pub fn strip(len: usize, tasks: usize, t: usize) -> (usize, usize) {
        (t * len / tasks, (t + 1) * len / tasks)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.state);
            st.stop = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_index_runs_exactly_once() {
        let pool = WorkerPool::new(3);
        for n in [1usize, 2, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.for_each(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn zero_workers_runs_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.width(), 1);
        let sum = AtomicUsize::new(0);
        pool.for_each(10, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_regions_run_serially() {
        let pool = WorkerPool::global();
        let total = AtomicUsize::new(0);
        pool.for_each(4, |_| {
            // nested call from (possibly) a worker thread must inline
            WorkerPool::global().for_each(8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn reusable_across_many_regions() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.for_each(5, |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 100 * 15);
    }

    #[test]
    fn disjoint_writes_match_serial() {
        let pool = WorkerPool::new(3);
        let n = 257usize;
        let mut par = vec![0.0f32; n];
        let base = SendPtr(par.as_mut_ptr());
        pool.for_each(n, |i| unsafe {
            *base.0.add(i) = (i as f32).sqrt();
        });
        let serial: Vec<f32> = (0..n).map(|i| (i as f32).sqrt()).collect();
        assert_eq!(par, serial, "pool writes must be bit-exact");
    }

    #[test]
    fn run_inline_suppresses_regions_and_restores() {
        assert!(!WorkerPool::on_worker());
        let hits = AtomicUsize::new(0);
        WorkerPool::run_inline(|| {
            assert!(WorkerPool::on_worker());
            // a region started under run_inline executes inline
            WorkerPool::global().for_each(5, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        assert!(!WorkerPool::on_worker(), "flag must be restored");
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_each(8, |i| {
                if i == 3 {
                    panic!("boom at index {i}");
                }
            });
        }));
        // the original payload is re-raised, not a generic message
        let payload = r.unwrap_err();
        let msg = payload.downcast_ref::<String>().expect("String payload");
        assert!(msg.contains("boom at index 3"), "{msg}");
        // pool still works after a panicked region
        let sum = AtomicUsize::new(0);
        pool.for_each(4, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn strip_bounds_cover_range() {
        let (len, tasks) = (103usize, 4usize);
        let mut covered = 0;
        for t in 0..tasks {
            let (s, e) = WorkerPool::strip(len, tasks, t);
            covered += e - s;
            if t > 0 {
                assert_eq!(s, WorkerPool::strip(len, tasks, t - 1).1);
            }
        }
        assert_eq!(covered, len);
    }

}
