//! Small statistics helpers used across calibration, ODP and benches.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32
}

/// Excess kurtosis (Fisher); 0 for normal data. Used by the Tab-11
/// token-metric pruning baselines.
pub fn kurtosis(xs: &[f32]) -> f32 {
    let m = mean(xs);
    let v = variance(xs).max(1e-12);
    let m4 = xs.iter().map(|x| (x - m).powi(4)).sum::<f32>() / xs.len().max(1) as f32;
    m4 / (v * v) - 3.0
}

/// Median by sorting a copy (calibration-time only, not on hot path).
pub fn median(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0..=100), linear interpolation.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f32;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f32) * (v[hi] - v[lo])
    }
}

/// Index of max element (ties -> first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest elements, descending by value.
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Running timing statistics for the bench harness.
#[derive(Debug, Default, Clone)]
pub struct Timings {
    pub samples_ns: Vec<u64>,
}

impl Timings {
    pub fn push(&mut self, ns: u64) {
        self.samples_ns.push(ns);
    }

    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64
    }

    pub fn p50_ns(&self) -> f64 {
        let xs: Vec<f32> = self.samples_ns.iter().map(|&n| n as f32).collect();
        median(&xs) as f64
    }

    pub fn min_ns(&self) -> u64 {
        self.samples_ns.iter().copied().min().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn topk() {
        assert_eq!(top_k_indices(&[1.0, 5.0, 3.0, 4.0], 2), vec![1, 3]);
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
    }

    #[test]
    fn kurtosis_uniformish_negative() {
        // uniform distribution has negative excess kurtosis (-1.2)
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        assert!(kurtosis(&xs) < -1.0);
    }
}
