//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven
//! and dependency-free. Used as the per-expert segment checksum in the
//! `.mcqz` v2 expert directory: `ExpertStore::fetch` re-hashes every
//! segment it reads from disk so a short read or flipped bit surfaces
//! as a typed error instead of a garbage expert.
//!
//! One 256-entry table, built once behind a `OnceLock`; throughput is
//! a non-issue next to the disk read it guards.

use std::sync::OnceLock;

const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { (c >> 1) ^ POLY } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 of `bytes` (init 0xFFFF_FFFF, final xor 0xFFFF_FFFF — the
/// common zlib/PNG convention).
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = table();
    let mut c = !0u32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical check value for the IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn detects_single_bit_flips() {
        let base: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let want = crc32(&base);
        for pos in [0usize, 1, 63, 64, 2048, 4095] {
            for bit in 0..8 {
                let mut corrupt = base.clone();
                corrupt[pos] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), want,
                           "flip at byte {pos} bit {bit} undetected");
            }
        }
    }
}
