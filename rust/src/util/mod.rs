//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only `xla` + `anyhow`
//! vendored, so the usual ecosystem crates (serde/serde_json, rand,
//! clap, criterion, rayon) are re-implemented here at the scale this
//! project needs. Each submodule is unit-tested in place.

pub mod alloc;
pub mod cli;
pub mod crc32;
pub mod faults;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod bench;
