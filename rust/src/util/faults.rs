//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is parsed from the `MC_FAULTS` environment variable
//! (or installed programmatically by tests and the chaos-soak bench)
//! and consulted at a small set of named injection sites:
//!
//!   * `Site::Demand`   — demand-path `ExpertStore` fetches
//!   * `Site::Prefetch` — speculative prefetch fetches
//!   * `Site::Conn`     — HTTP connection workers
//!   * `Site::Oom`      — memory-governor reservations
//!
//! Spec grammar (comma-separated, all fields optional):
//!
//! ```text
//! MC_FAULTS="io_err=0.05,corrupt=0.02,delay_ms=50@0.1,panic=0.01,\
//!            prefetch_drop=0.1,oom=0.02,seed=42"
//! ```
//!
//! `io_err` fails a demand fetch before the read, `corrupt` flips one
//! byte of the segment after the read (caught by the crc32 check),
//! `delay_ms=N@P` sleeps N ms with probability P, `panic` poisons a
//! connection worker, `prefetch_drop` makes the prefetcher skip a
//! speculative load, `oom` fails a memory-governor reservation as if
//! the byte ceiling refused it. Every decision is a pure function of
//! `(seed, site, n-th draw at that site)` via a splitmix64 finalizer,
//! so a plan replays the same fault sequence per site regardless of
//! wall clock. When `MC_FAULTS` is unset the fast path is one relaxed
//! atomic load — no locks, no allocation.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, Once};
use std::time::Duration;

use anyhow::{bail, Result};

/// Injection sites. Each site draws from its own deterministic
/// sub-sequence so (for example) prefetch traffic cannot perturb the
/// fault pattern seen by demand fetches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Demand-path expert fetch (`ExpertCache` miss).
    Demand = 0,
    /// Speculative prefetch fetch.
    Prefetch = 1,
    /// HTTP connection worker handling a request.
    Conn = 2,
    /// Memory-governor byte reservation (`memgov::try_reserve`).
    Oom = 3,
}

const N_SITES: usize = 4;

/// A seeded, deterministic fault schedule.
#[derive(Debug)]
pub struct FaultPlan {
    /// P(demand fetch fails with an injected I/O error).
    pub io_err: f64,
    /// P(one byte of a fetched segment is flipped post-read).
    pub corrupt: f64,
    /// Injected fetch latency and its probability (`delay_ms=N@P`).
    pub delay: Duration,
    pub delay_p: f64,
    /// P(a connection worker panics at the top of a request).
    pub panic_p: f64,
    /// P(the prefetcher silently skips a speculative load).
    pub prefetch_drop: f64,
    /// P(a memory-governor reservation is refused).
    pub oom: f64,
    /// Seed for the per-site decision sequences.
    pub seed: u64,
    draws: [AtomicU64; N_SITES],
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            io_err: 0.0,
            corrupt: 0.0,
            delay: Duration::ZERO,
            delay_p: 0.0,
            panic_p: 0.0,
            prefetch_drop: 0.0,
            oom: 0.0,
            seed: 0x6D63_6661_756C_7473, // "mcfaults"
            draws: Default::default(),
        }
    }
}

fn mix(mut z: u64) -> u64 {
    // splitmix64 finalizer
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Parse the `MC_FAULTS` grammar. Probabilities must lie in
    /// `[0, 1]`; unknown keys are an error so typos fail loudly.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for field in spec.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (key, val) = field
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!(
                    "fault field {field:?} is not key=value"))?;
            let prob = |v: &str| -> Result<f64> {
                let p: f64 = v.parse().map_err(|_| anyhow::anyhow!(
                    "fault {key}: {v:?} is not a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("fault {key}: probability {p} outside [0, 1]");
                }
                Ok(p)
            };
            match key {
                "io_err" => plan.io_err = prob(val)?,
                "corrupt" => plan.corrupt = prob(val)?,
                "panic" => plan.panic_p = prob(val)?,
                "prefetch_drop" => plan.prefetch_drop = prob(val)?,
                "oom" => plan.oom = prob(val)?,
                "seed" => {
                    plan.seed = val.parse().map_err(|_| anyhow::anyhow!(
                        "fault seed: {val:?} is not a u64"))?;
                }
                "delay_ms" => {
                    let (ms, p) = match val.split_once('@') {
                        Some((ms, p)) => (ms, prob(p)?),
                        None => (val, 1.0),
                    };
                    let ms: u64 = ms.parse().map_err(|_| anyhow::anyhow!(
                        "fault delay_ms: {ms:?} is not a u64"))?;
                    plan.delay = Duration::from_millis(ms);
                    plan.delay_p = p;
                }
                other => bail!("unknown fault key {other:?} \
                                (io_err, corrupt, delay_ms, panic, \
                                 prefetch_drop, oom, seed)"),
            }
        }
        Ok(plan)
    }

    /// Next uniform draw in `[0, 1)` for `site`. Deterministic in the
    /// per-site draw index.
    fn roll(&self, site: Site) -> f64 {
        let n = self.draws[site as usize].fetch_add(1, Relaxed);
        let h = mix(mix(self.seed ^ ((site as u64) << 56)) ^ n);
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn io_error(&self, site: Site) -> bool {
        self.io_err > 0.0 && self.roll(site) < self.io_err
    }

    pub fn corrupt(&self, site: Site) -> bool {
        self.corrupt > 0.0 && self.roll(site) < self.corrupt
    }

    pub fn panic_now(&self, site: Site) -> bool {
        self.panic_p > 0.0 && self.roll(site) < self.panic_p
    }

    pub fn drop_prefetch(&self) -> bool {
        self.prefetch_drop > 0.0
            && self.roll(Site::Prefetch) < self.prefetch_drop
    }

    /// Should this memory-governor reservation be refused?
    pub fn oom_now(&self) -> bool {
        self.oom > 0.0 && self.roll(Site::Oom) < self.oom
    }

    /// Injected latency for this draw, if the delay fault fires.
    pub fn delay(&self, site: Site) -> Option<Duration> {
        if self.delay_p > 0.0 && !self.delay.is_zero()
            && self.roll(site) < self.delay_p
        {
            Some(self.delay)
        } else {
            None
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

/// The active fault plan, if any. First call reads `MC_FAULTS`; a
/// malformed spec is reported once and ignored (serving with no
/// faults beats refusing to start over a chaos knob).
pub fn plan() -> Option<Arc<FaultPlan>> {
    ENV_INIT.call_once(|| {
        let Ok(spec) = std::env::var("MC_FAULTS") else { return };
        if spec.trim().is_empty() {
            return;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => {
                *PLAN.lock().unwrap() = Some(Arc::new(p));
                ENABLED.store(true, Relaxed);
            }
            Err(e) => eprintln!("MC_FAULTS ignored: {e}"),
        }
    });
    if !ENABLED.load(Relaxed) {
        return None;
    }
    PLAN.lock().unwrap().clone()
}

/// Install (or clear, with `None`) the active plan, overriding
/// `MC_FAULTS`. Used by tests and the chaos-soak bench.
pub fn install(p: Option<FaultPlan>) {
    ENV_INIT.call_once(|| {}); // consume env init so it cannot override
    let mut guard = PLAN.lock().unwrap();
    ENABLED.store(p.is_some(), Relaxed);
    *guard = p.map(Arc::new);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse(
            "io_err=0.05,corrupt=0.02,delay_ms=50@0.1,panic=0.01,\
             prefetch_drop=0.2,oom=0.03,seed=42").unwrap();
        assert_eq!(p.io_err, 0.05);
        assert_eq!(p.corrupt, 0.02);
        assert_eq!(p.delay, Duration::from_millis(50));
        assert_eq!(p.delay_p, 0.1);
        assert_eq!(p.panic_p, 0.01);
        assert_eq!(p.prefetch_drop, 0.2);
        assert_eq!(p.oom, 0.03);
        assert_eq!(p.seed, 42);
        // bare delay_ms means always-on
        let q = FaultPlan::parse("delay_ms=5").unwrap();
        assert_eq!((q.delay, q.delay_p), (Duration::from_millis(5), 1.0));
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FaultPlan::parse("io_err=1.5").is_err());
        assert!(FaultPlan::parse("io_err").is_err());
        assert!(FaultPlan::parse("warp_core_breach=0.1").is_err());
        assert!(FaultPlan::parse("delay_ms=xx@0.5").is_err());
        assert!(FaultPlan::parse("seed=-3").is_err());
    }

    #[test]
    fn draws_are_deterministic_and_per_site() {
        let mk = || FaultPlan::parse("io_err=0.5,seed=7").unwrap();
        let (a, b) = (mk(), mk());
        let seq_a: Vec<bool> =
            (0..64).map(|_| a.io_error(Site::Demand)).collect();
        let seq_b: Vec<bool> =
            (0..64).map(|_| b.io_error(Site::Demand)).collect();
        assert_eq!(seq_a, seq_b, "same seed, same site, same sequence");
        // a different site draws a different sequence from the same seed
        let c = mk();
        let seq_c: Vec<bool> =
            (0..64).map(|_| c.io_error(Site::Prefetch)).collect();
        assert_ne!(seq_a, seq_c, "sites draw independent sequences");
    }

    #[test]
    fn empirical_rate_tracks_probability() {
        let p = FaultPlan::parse("io_err=0.25,seed=1234").unwrap();
        let n = 20_000;
        let hits = (0..n).filter(|_| p.io_error(Site::Demand)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate} far from 0.25");
    }

    #[test]
    fn zero_probability_never_fires_and_never_draws() {
        let p = FaultPlan::default();
        for _ in 0..32 {
            assert!(!p.io_error(Site::Demand));
            assert!(!p.corrupt(Site::Demand));
            assert!(!p.panic_now(Site::Conn));
            assert!(!p.drop_prefetch());
            assert!(!p.oom_now());
            assert!(p.delay(Site::Demand).is_none());
        }
        // zero-rate checks must not consume draws, so enabling a rate
        // later replays from the start of the sequence
        assert_eq!(p.draws[Site::Demand as usize].load(Relaxed), 0);
        assert_eq!(p.draws[Site::Oom as usize].load(Relaxed), 0);
    }

    #[test]
    fn oom_site_draws_deterministically() {
        let mk = || FaultPlan::parse("oom=0.5,seed=11").unwrap();
        let (a, b) = (mk(), mk());
        let seq_a: Vec<bool> = (0..64).map(|_| a.oom_now()).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.oom_now()).collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.iter().any(|&x| x), "0.5 rate fires within 64 draws");
        assert!(seq_a.iter().any(|&x| !x));
        // always-on refuses every reservation
        let c = FaultPlan::parse("oom=1.0").unwrap();
        assert!((0..16).all(|_| c.oom_now()));
    }

    #[test]
    fn install_overrides_and_clears() {
        // an all-zero plan: exercises the toggle without perturbing any
        // concurrently-running test that consults the global plan
        install(Some(FaultPlan::default()));
        let got = plan().expect("installed plan is visible");
        assert_eq!(got.io_err, 0.0);
        install(None);
        assert!(plan().is_none(), "cleared plan stays cleared");
    }
}
