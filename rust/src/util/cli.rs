//! Tiny CLI argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; each binary declares its options by querying an `Args`
//! instance.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{name} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{name} expects a number, got {v:?}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("serve --port 8080 --verbose --mode=fast input.txt");
        assert_eq!(a.positional, vec!["serve", "input.txt"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--x 1 --dry-run");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 12 --r 0.5");
        assert_eq!(a.usize_or("n", 0).unwrap(), 12);
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert!((a.f64_or("r", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert!(parse("--n x").usize_or("n", 0).is_err());
    }
}
