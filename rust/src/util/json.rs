//! Minimal JSON parser/serializer (serde_json is not vendored offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used for
//! `artifacts/config.json`, `manifest.json`, MCWT headers, and metrics
//! output. Numbers parse as f64; helpers coerce to integer types.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// Serialize. Not pretty-printed; stable (BTreeMap) key order.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // surrogate pairs: only BMP needed for our files
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                b => {
                    // collect the full UTF-8 sequence starting at b
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":3}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
    }
}
