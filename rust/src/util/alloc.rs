//! 64-byte-aligned backing buffers for kernel-facing tensors.
//!
//! `Vec<f32>` only guarantees 4-byte alignment, so SIMD loads in the
//! `kernels::` backends could straddle cache lines (and an `_mm512`
//! lane group could straddle two). [`AVec`] is a minimal Vec-alike
//! whose allocation is always 64-byte aligned (one x86 cache line /
//! one AVX-512 register), used as the storage of `Mat`,
//! `PackedTensor`, `BinaryTensor`, and the weight-file `Tensor`.
//!
//! Restricted to `T: Copy` (f32/u32 here), which keeps drop handling
//! trivial: no element destructors, deallocate the block and done.
//! Everything slice-shaped is inherited through `Deref<Target = [T]>`;
//! only the Vec-specific growth API (`resize`, `reserve`, `push`,
//! `extend_from_slice`) is re-implemented, with the same amortized
//! doubling so the scratch-arena contract (shrink + regrow within
//! capacity never reallocates) carries over unchanged.

use std::alloc::{alloc, alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::fmt;
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Allocation alignment: one cache line == one AVX-512 register.
pub const BUF_ALIGN: usize = 64;

pub struct AVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
    _marker: PhantomData<T>,
}

// Safety: AVec owns its buffer exclusively, like Vec<T>.
unsafe impl<T: Copy + Send> Send for AVec<T> {}
unsafe impl<T: Copy + Sync> Sync for AVec<T> {}

impl<T: Copy> AVec<T> {
    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<T>(), BUF_ALIGN)
            .expect("AVec layout overflow")
    }

    pub fn new() -> AVec<T> {
        AVec {
            ptr: NonNull::dangling(),
            len: 0,
            cap: 0,
            _marker: PhantomData,
        }
    }

    pub fn with_capacity(cap: usize) -> AVec<T> {
        let mut v = AVec::new();
        if cap > 0 {
            v.ptr = Self::raw_alloc(cap, false);
            v.cap = cap;
        }
        v
    }

    /// `len` zero-initialized elements (valid for f32/u32: all-zero
    /// bits are 0.0 / 0).
    pub fn zeroed(len: usize) -> AVec<T> {
        let mut v = AVec::new();
        if len > 0 {
            v.ptr = Self::raw_alloc(len, true);
            v.cap = len;
            v.len = len;
        }
        v
    }

    /// `len` copies of `value`.
    pub fn from_elem(value: T, len: usize) -> AVec<T> {
        let mut v = AVec::with_capacity(len);
        for i in 0..len {
            // Safety: i < cap, freshly allocated.
            unsafe { v.ptr.as_ptr().add(i).write(value) };
        }
        v.len = len;
        v
    }

    fn raw_alloc(cap: usize, zero: bool) -> NonNull<T> {
        let layout = Self::layout(cap);
        // Safety: cap > 0 at every call site, so layout.size() > 0.
        let p = unsafe {
            if zero {
                alloc_zeroed(layout)
            } else {
                alloc(layout)
            }
        };
        let Some(nn) = NonNull::new(p.cast::<T>()) else {
            handle_alloc_error(layout);
        };
        debug_assert_eq!(
            nn.as_ptr() as usize % BUF_ALIGN,
            0,
            "AVec allocation must be {BUF_ALIGN}-byte aligned"
        );
        nn
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Grow capacity to at least `need` (amortized doubling).
    fn grow_to(&mut self, need: usize) {
        if need <= self.cap {
            return;
        }
        let new_cap = need.max(self.cap * 2).max(4);
        let new_ptr = Self::raw_alloc(new_cap, false);
        if self.cap > 0 {
            // Safety: both buffers hold at least self.len elements and
            // cannot overlap (new_ptr is a fresh allocation).
            unsafe {
                std::ptr::copy_nonoverlapping(
                    self.ptr.as_ptr(),
                    new_ptr.as_ptr(),
                    self.len,
                );
                dealloc(self.ptr.as_ptr().cast::<u8>(), Self::layout(self.cap));
            }
        }
        self.ptr = new_ptr;
        self.cap = new_cap;
    }

    pub fn reserve(&mut self, additional: usize) {
        self.grow_to(self.len + additional);
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn resize(&mut self, new_len: usize, value: T) {
        if new_len > self.cap {
            self.grow_to(new_len);
        }
        if new_len > self.len {
            for i in self.len..new_len {
                // Safety: i < cap after grow_to.
                unsafe { self.ptr.as_ptr().add(i).write(value) };
            }
        }
        self.len = new_len;
    }

    pub fn push(&mut self, value: T) {
        if self.len == self.cap {
            self.grow_to(self.len + 1);
        }
        // Safety: len < cap after grow_to.
        unsafe { self.ptr.as_ptr().add(self.len).write(value) };
        self.len += 1;
    }

    pub fn extend_from_slice(&mut self, other: &[T]) {
        self.grow_to(self.len + other.len());
        // Safety: capacity reserved above; slices cannot overlap the
        // spare tail of a uniquely-owned buffer.
        unsafe {
            std::ptr::copy_nonoverlapping(
                other.as_ptr(),
                self.ptr.as_ptr().add(self.len),
                other.len(),
            );
        }
        self.len += other.len();
    }
}

impl<T: Copy> Drop for AVec<T> {
    fn drop(&mut self) {
        if self.cap > 0 {
            // Safety: allocated in raw_alloc with the identical layout.
            unsafe {
                dealloc(self.ptr.as_ptr().cast::<u8>(), Self::layout(self.cap));
            }
        }
    }
}

impl<T: Copy> Deref for AVec<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        // Safety: first `len` elements are initialized; for len == 0
        // the dangling pointer is non-null and T-aligned.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> DerefMut for AVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // Safety: as Deref, plus exclusive access through &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> Default for AVec<T> {
    fn default() -> AVec<T> {
        AVec::new()
    }
}

impl<T: Copy> Clone for AVec<T> {
    fn clone(&self) -> AVec<T> {
        AVec::from(&self[..])
    }
}

impl<T: Copy + fmt::Debug> fmt::Debug for AVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: Copy + PartialEq> PartialEq for AVec<T> {
    fn eq(&self, other: &AVec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: Copy + PartialEq> PartialEq<Vec<T>> for AVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: Copy + PartialEq> PartialEq<AVec<T>> for Vec<T> {
    fn eq(&self, other: &AVec<T>) -> bool {
        self[..] == other[..]
    }
}

impl<T: Copy> From<&[T]> for AVec<T> {
    fn from(s: &[T]) -> AVec<T> {
        let mut v = AVec::with_capacity(s.len());
        v.extend_from_slice(s);
        v
    }
}

impl<T: Copy> From<Vec<T>> for AVec<T> {
    fn from(s: Vec<T>) -> AVec<T> {
        AVec::from(&s[..])
    }
}

impl<T: Copy> FromIterator<T> for AVec<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> AVec<T> {
        let it = iter.into_iter();
        let mut v = AVec::with_capacity(it.size_hint().0);
        for x in it {
            v.push(x);
        }
        v
    }
}

impl<'a, T: Copy> IntoIterator for &'a AVec<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> std::slice::Iter<'a, T> {
        self.iter()
    }
}

impl<'a, T: Copy> IntoIterator for &'a mut AVec<T> {
    type Item = &'a mut T;
    type IntoIter = std::slice::IterMut<'a, T>;

    fn into_iter(self) -> std::slice::IterMut<'a, T> {
        self.iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_64_bytes() {
        for len in [1usize, 7, 64, 1000] {
            let v: AVec<f32> = AVec::zeroed(len);
            assert_eq!(v.as_ptr() as usize % BUF_ALIGN, 0, "len={len}");
            assert!(v.iter().all(|&x| x == 0.0));
        }
        let v: AVec<u32> = AVec::from(vec![1u32, 2, 3]);
        assert_eq!(v.as_ptr() as usize % BUF_ALIGN, 0);
    }

    #[test]
    fn vec_roundtrip_and_eq() {
        let v: AVec<f32> = vec![1.0f32, 2.0, 3.0].into();
        assert_eq!(v.len(), 3);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert_eq!(v[1], 2.0);
        assert_eq!(&v[1..], &[2.0, 3.0]);
        let w = v.clone();
        assert_eq!(v, w);
        assert_ne!(v.as_ptr(), w.as_ptr());
    }

    #[test]
    fn shrink_and_regrow_within_capacity_is_stable() {
        let mut v: AVec<f32> = AVec::zeroed(64);
        let p = v.as_ptr();
        v.resize(6, 0.0);
        assert_eq!(v.len(), 6);
        v.resize(64, 1.0);
        assert_eq!(v.as_ptr(), p, "regrow within capacity must not realloc");
        assert_eq!(v[5], 0.0);
        assert_eq!(v[6], 1.0);
    }

    #[test]
    fn growth_preserves_contents_and_alignment() {
        let mut v: AVec<u32> = AVec::new();
        for i in 0..100u32 {
            v.push(i);
        }
        assert_eq!(v.as_ptr() as usize % BUF_ALIGN, 0);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u32));
        v.extend_from_slice(&[100, 101]);
        assert_eq!(v.len(), 102);
        assert_eq!(v[101], 101);
    }

    #[test]
    fn collect_and_iterate() {
        let v: AVec<f32> = (0..5).map(|i| i as f32).collect();
        let sum: f32 = v.iter().sum();
        assert_eq!(sum, 10.0);
        let mut v = v;
        for x in &mut v {
            *x *= 2.0;
        }
        assert_eq!(v, vec![0.0, 2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn reserve_then_fill_is_pointer_stable() {
        let mut v: AVec<f32> = AVec::new();
        v.reserve(128);
        let p = v.as_ptr();
        for _ in 0..128 {
            v.push(0.5);
        }
        assert_eq!(v.as_ptr(), p);
        assert_eq!(v.capacity(), 128);
    }
}
