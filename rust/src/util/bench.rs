//! Minimal benchmark harness (criterion is not vendored offline).
//!
//! Each `cargo bench` target sets `harness = false` and drives this:
//! warmup, timed iterations with outlier-robust reporting, and a table
//! printer whose rows mirror the paper's tables (DESIGN.md §10).

use std::time::Instant;

use super::stats::Timings;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub timings: Timings,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.timings.mean_ns() / 1e6
    }

    pub fn p50_ms(&self) -> f64 {
        self.timings.p50_ns() / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut timings = Timings::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        timings.push(t0.elapsed().as_nanos() as u64);
    }
    BenchResult { name: name.to_string(), iters, timings }
}

/// Run `f` repeatedly until `min_total_ms` elapsed (at least 3 iters),
/// for benches whose single-iteration cost is unknown up front.
pub fn bench_for<F: FnMut()>(name: &str, min_total_ms: u64, mut f: F) -> BenchResult {
    f(); // warmup
    let mut timings = Timings::default();
    let start = Instant::now();
    let mut iters = 0;
    while iters < 3 || start.elapsed().as_millis() < min_total_ms as u128 {
        let t0 = Instant::now();
        f();
        timings.push(t0.elapsed().as_nanos() as u64);
        iters += 1;
        if iters > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters, timings }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("x", 2, 5, || n += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(n, 7); // warmup + timed
        assert_eq!(r.timings.samples_ns.len(), 5);
    }

    #[test]
    fn table_arity_enforced() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_bad_row_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
