//! Deterministic PRNG (no `rand` crate offline): splitmix64 core with
//! convenience samplers. Also hosts the LCG used by the TextChannel
//! transition table, which must match `python/compile/datagen.py` bit
//! for bit (asserted in tests and by the cross-language corpus test).

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// splitmix64 next
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// uniform in [0, n)
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// uniform in [lo, hi)
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// uniform f64 in [0, 1)
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// standard normal via Box-Muller
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct values from 0..n (partial Fisher-Yates)
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    /// sample an index from unnormalized weights
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// The LCG used for the TextChannel successor table — constants must
/// match datagen.py (`LCG_MUL`/`LCG_INC`).
pub const LCG_MUL: u64 = 6364136223846793005;
pub const LCG_INC: u64 = 1442695040888963407;

pub fn lcg_next(state: u64) -> u64 {
    state.wrapping_mul(LCG_MUL).wrapping_add(LCG_INC)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(4);
        for _ in 0..50 {
            let v = r.choose_distinct(20, 10);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10);
        }
    }

    #[test]
    fn weighted_respects_zero() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(r.weighted(&[0.0, 1.0, 0.0]), 1);
        }
    }

    #[test]
    fn lcg_matches_python_constants() {
        // first output from state 0xC0FFEE, cross-checked against the
        // numpy uint64 arithmetic in datagen.TextChannel
        assert_eq!(lcg_next(0xC0FFEE), 0xf4690d0475d19025);
    }
}
