//! The MoE-LLM substrate: weight loading (MCWT), the shared
//! layer-execution core (`exec`: attention / router / dispatch —
//! DESIGN.md §2), and the native f32 / quantized forward engine that
//! PMQ calibrates against and ODP prunes.

pub mod exec;
pub mod model;
pub mod qz;
pub mod weights;

pub use model::{ForwardOpts, ForwardOut, MoeModel, RunStats};
pub use weights::{Tensor, WeightFile};
