//! Native MoE transformer engine (rust twin of `python/compile/model.py`).
//!
//! One engine serves every representation: experts are `QTensor`s, so
//! the same forward runs the FP32 reference, RTN/GPTQ-quantized, and
//! binary models. ODP (paper Sec. 3.3) is applied inline during routing;
//! calibration sinks observe expert inputs for GPTQ Hessians and
//! significance statistics (Sec. 3.2.1).
//!
//! The per-layer math lives in the shared execution core `moe::exec`
//! (attention / router / dispatch — DESIGN.md §2); `forward` is a thin
//! driver over it, as are the KV-cache decode and fused batcher paths
//! in `coordinator`. Numerical parity with the JAX model is asserted
//! against `artifacts/golden.mcwt` in `tests/golden_parity.rs`.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::offload::ExpertResolver;
use crate::quant::QTensor;
use crate::tensor::{add_inplace, log_softmax_into, rmsnorm, Mat};

use super::exec::dispatch::ExpertsRef;
use super::exec::{attention, dispatch, router};
use super::weights::WeightFile;

// Re-exports: these types moved into the execution core but remain
// part of this module's public API.
pub use super::exec::attention::eq6_importance;
pub use super::exec::router::{select_top_k, RunStats};

pub const RMS_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Weights
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Expert {
    pub w1: QTensor,
    pub w3: QTensor,
    pub w2: QTensor,
}

impl Expert {
    /// SwiGLU FFN on a token batch x[T, D] -> y[T, D].
    pub fn forward(&self, x: &Mat) -> Mat {
        let g = self.gated_hidden(x);
        self.w2.matmul(&g)
    }

    /// silu(x@w1) * (x@w3) — exposed so calibration can capture the
    /// w2-input Hessian.
    pub fn gated_hidden(&self, x: &Mat) -> Mat {
        let mut gated = Mat::zeros(0, 0);
        let mut tmp = Mat::zeros(0, 0);
        let mut qs = crate::quant::QmScratch::new();
        self.gated_hidden_into(x, &mut gated, &mut tmp, &mut qs);
        gated
    }

    /// `gated_hidden` into reused buffers: `gated` receives the
    /// result, `tmp` holds the x@w3 intermediate, `qs` feeds the
    /// packed kernels — the zero-allocation dispatch path.
    pub fn gated_hidden_into(&self, x: &Mat, gated: &mut Mat, tmp: &mut Mat,
                             qs: &mut crate::quant::QmScratch) {
        self.w1.matmul_into(x, gated, qs);
        self.w3.matmul_into(x, tmp, qs);
        for (a, &b) in gated.data.iter_mut().zip(&tmp.data) {
            *a = crate::tensor::silu(*a) * b;
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.w1.storage_bytes() + self.w3.storage_bytes() + self.w2.storage_bytes()
    }

    pub fn param_count(&self) -> usize {
        let (k1, n1) = self.w1.shape();
        let (k3, n3) = self.w3.shape();
        let (k2, n2) = self.w2.shape();
        k1 * n1 + k3 * n3 + k2 * n2
    }
}

#[derive(Debug, Clone)]
pub struct Layer {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub gate: Mat,
    pub wq: QTensor,
    pub wk: QTensor,
    pub wv: QTensor,
    pub wo: QTensor,
    pub experts: Vec<Expert>,
}

#[derive(Debug, Clone)]
pub struct MoeModel {
    pub cfg: ModelConfig,
    pub tok_emb: Mat,
    pub pos_emb: Mat,
    pub final_norm: Vec<f32>,
    pub lm_head: Mat,
    pub layers: Vec<Layer>,
    /// How expert weights materialize for execution:
    /// `offload::resident()` (eagerly owned in `Layer::experts`,
    /// today's zero-cost default) or a byte-budgeted
    /// `offload::CachedResolver` over an on-disk store, in which case
    /// the layers' expert vecs are empty (DESIGN.md §5).
    pub resolver: Arc<dyn ExpertResolver>,
}

impl MoeModel {
    /// Load the FP32 model from an MCWT weight file. Consumes the
    /// file: each tensor's payload is moved (not cloned) into the
    /// model, so load-time peak memory is one copy of the weights,
    /// not two.
    pub fn load_f32(cfg: &ModelConfig, mut wf: WeightFile) -> Result<MoeModel> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut experts = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                let p = |m: &str| format!("layers.{i}.experts.{e}.{m}");
                experts.push(Expert {
                    w1: QTensor::F32(wf.take_mat(&p("w1"))?),
                    w3: QTensor::F32(wf.take_mat(&p("w3"))?),
                    w2: QTensor::F32(wf.take_mat(&p("w2"))?),
                });
            }
            layers.push(Layer {
                attn_norm: wf.take_vec1(&format!("layers.{i}.attn_norm"))?,
                ffn_norm: wf.take_vec1(&format!("layers.{i}.ffn_norm"))?,
                gate: wf.take_mat(&format!("layers.{i}.gate"))?,
                wq: QTensor::F32(wf.take_mat(&format!("layers.{i}.attn.wq"))?),
                wk: QTensor::F32(wf.take_mat(&format!("layers.{i}.attn.wk"))?),
                wv: QTensor::F32(wf.take_mat(&format!("layers.{i}.attn.wv"))?),
                wo: QTensor::F32(wf.take_mat(&format!("layers.{i}.attn.wo"))?),
                experts,
            });
        }
        Ok(MoeModel {
            cfg: cfg.clone(),
            tok_emb: wf.take_mat("tok_emb")?,
            pos_emb: wf.take_mat("pos_emb")?,
            final_norm: wf.take_vec1("final_norm")?,
            lm_head: wf.take_mat("lm_head")?,
            layers,
            resolver: crate::offload::resident(),
        })
    }

    /// Total weight storage in bytes (the paper's "Params" column).
    /// Cache-resolved models count their experts from the store
    /// directory (the layers' expert vecs are empty).
    pub fn storage_bytes(&self) -> usize {
        let mut total = (self.tok_emb.data.len()
            + self.pos_emb.data.len()
            + self.final_norm.len()
            + self.lm_head.data.len())
            * 4;
        for l in &self.layers {
            total += (l.attn_norm.len() + l.ffn_norm.len() + l.gate.data.len()) * 4;
            total += l.wq.storage_bytes()
                + l.wk.storage_bytes()
                + l.wv.storage_bytes()
                + l.wo.storage_bytes();
            for e in &l.experts {
                total += e.storage_bytes();
            }
        }
        if self.layers.iter().all(|l| l.experts.is_empty()) {
            total += self.resolver.expert_bytes().unwrap_or(0);
        }
        total
    }

    /// Sum of expert storage bytes, resident or store-resolved.
    pub fn expert_storage_bytes(&self) -> usize {
        if let Some(b) = self.resolver.expert_bytes() {
            return b;
        }
        self.layers
            .iter()
            .flat_map(|l| &l.experts)
            .map(|e| e.storage_bytes())
            .sum()
    }

    /// Average bits per *expert* weight (the paper's "Bits" axis).
    pub fn expert_avg_bits(&self) -> f64 {
        let elems = self.cfg.expert_param_count() as f64;
        self.expert_storage_bytes() as f64 * 8.0 / elems
    }

    /// Token + positional embedding of one token at `pos`, written
    /// into `xrow` — the single embed implementation every path
    /// (scoring, KV-cache append, fused step) drives, so they cannot
    /// drift. Writes in place: usable from the zero-alloc decode loop.
    pub(crate) fn embed_row(&self, tok: u32, pos: usize, xrow: &mut [f32]) {
        let emb = self.tok_emb.row(tok as usize);
        let p = self.pos_emb.row(pos);
        for ((xv, &e), &pv) in xrow.iter_mut().zip(emb).zip(p) {
            *xv = e + pv;
        }
    }

    /// Token + positional embedding for `tokens` placed at positions
    /// `pos0..pos0 + tokens.len()` (pos0 > 0 on KV-cache appends).
    pub(crate) fn embed(&self, tokens: &[u32], pos0: usize) -> Mat {
        let mut x = Mat::zeros(tokens.len(), self.cfg.d_model);
        for (t, &tok) in tokens.iter().enumerate() {
            self.embed_row(tok, pos0 + t, x.row_mut(t));
        }
        x
    }
}

// ---------------------------------------------------------------------------
// ODP policy (paper Sec. 3.3; calibrated by `odp::calibrate`)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenMetric {
    /// paper Eq. 6: ||t||_1 * attention-received column mean
    Eq6Importance,
    /// Tab. 11 baselines over the token hidden state
    Kurtosis,
    Variance,
    MeanAbs,
}

#[derive(Debug, Clone)]
pub enum OdpPolicy {
    /// no dynamic pruning
    None,
    /// Lu et al. 2024: drop the secondary expert when w1/w0 < mu[layer]
    WeightOnly { mu: Vec<f32> },
    /// ODP: weight pruning + protect the top `protect_ratio` tokens by
    /// Eq.-6 importance (their experts are never pruned)
    Protected { mu: Vec<f32>, protect_ratio: f32 },
    /// Fig. 8 mode: Protected + additionally mask *all* experts of the
    /// bottom `drop_ratio` tokens
    ProtectedDropAll { mu: Vec<f32>, protect_ratio: f32, drop_ratio: f32 },
    /// Tab. 11 baselines: prune the secondary expert of the bottom
    /// `prune_frac` tokens ranked by `metric`
    TokenMetric { metric: TokenMetric, prune_frac: f32 },
}

impl OdpPolicy {
    fn needs_importance(&self) -> bool {
        matches!(
            self,
            OdpPolicy::Protected { .. }
                | OdpPolicy::ProtectedDropAll { .. }
                | OdpPolicy::TokenMetric { metric: TokenMetric::Eq6Importance, .. }
        )
    }
}

// ---------------------------------------------------------------------------
// Forward options / outputs
// ---------------------------------------------------------------------------

/// Observer for calibration passes (GPTQ Hessians, significance).
pub trait CalibSink {
    /// Rows of the post-norm hidden state routed to (layer, expert),
    /// plus the gated hidden (input of w2).
    fn expert_batch(&mut self, _layer: usize, _expert: usize, _x: &Mat, _gated: &Mat) {}
    /// Full router distribution for one layer ([S, E]) and the selected
    /// (renormalized) top-k weights per token.
    fn routing(&mut self, _layer: usize, _probs: &Mat, _topk: &[Vec<(usize, f32)>]) {}
    /// Attention inputs of one layer (for quantizing wq/wk/wv).
    fn attn_batch(&mut self, _layer: usize, _x: &Mat) {}
    /// Concatenated head outputs (input of wo).
    fn attn_out_batch(&mut self, _layer: usize, _x: &Mat) {}
    /// Post-ffn-norm hidden states (input of the gate and experts).
    fn moe_input(&mut self, _layer: usize, _x: &Mat) {}
}

/// No-op sink.
pub struct NullSink;
impl CalibSink for NullSink {}

#[derive(Default)]
pub struct ForwardOpts<'a> {
    pub odp: Option<&'a OdpPolicy>,
    /// exclude this (layer, expert) from routing entirely (drop-F-norm)
    pub mask_expert: Option<(usize, usize)>,
    /// substitute this expert at (layer, expert) (PMQ's eps_{i,j} probe)
    pub override_expert: Option<(usize, usize, &'a Expert)>,
    pub collect_probs: bool,
    pub collect_importance: bool,
    pub collect_ratio_samples: bool,
}

pub struct ForwardOut {
    pub logits: Mat,
    pub stats: RunStats,
    pub probs: Vec<Mat>,
    pub importance: Vec<Vec<f32>>,
    pub ratio_samples: Vec<Vec<f32>>,
}

// ---------------------------------------------------------------------------
// Forward
// ---------------------------------------------------------------------------

impl MoeModel {
    /// Full-sequence scoring forward. `tokens` length <= cfg.max_seq.
    ///
    /// A thin driver over `moe::exec`: per layer it runs the shared
    /// causal attention (materializing the Eq.-6 map only when the
    /// policy or the caller needs it), the shared router, and the
    /// shared expert dispatch (auto-threaded when the batch is large
    /// enough to pay for it).
    pub fn forward(&self, tokens: &[u32], opts: &ForwardOpts,
                   sink: &mut dyn CalibSink) -> ForwardOut {
        let s = tokens.len();
        let d = self.cfg.d_model;
        assert!(s <= self.cfg.max_seq, "sequence too long: {s}");

        let mut x = self.embed(tokens, 0);
        let mut stats = RunStats::new(self.cfg.n_layers, self.cfg.n_experts);
        stats.tokens_seen = s;
        let mut all_probs = Vec::new();
        let mut all_importance = Vec::new();
        let mut all_ratio_samples = Vec::new();

        let odp = opts.odp.unwrap_or(&OdpPolicy::None);
        let needs_imp = odp.needs_importance() || opts.collect_importance;
        // cache-resolved pin buffers, reused across layers
        let mut needed = Vec::new();
        let mut pins = Vec::new();

        for (li, layer) in self.layers.iter().enumerate() {
            // ---- attention ----
            let h = rmsnorm(&x, &layer.attn_norm, RMS_EPS);
            sink.attn_batch(li, &h);
            let q = layer.wq.matmul(&h);
            let k = layer.wk.matmul(&h);
            let v = layer.wv.matmul(&h);
            let attn = attention::causal_attention(
                &q, &k, &v, s, self.cfg.n_heads, needs_imp,
            );
            sink.attn_out_batch(li, &attn.out);
            let attn_proj = layer.wo.matmul(&attn.out);
            add_inplace(&mut x, &attn_proj);

            // ---- MoE FFN ----
            let h = rmsnorm(&x, &layer.ffn_norm, RMS_EPS);
            sink.moe_input(li, &h);
            let importance = match &attn.a_mean {
                Some(am) => eq6_importance(&h, am),
                None => Vec::new(),
            };
            let masked = opts
                .mask_expert
                .filter(|&(l, _)| l == li)
                .map(|(_, e)| e);
            let mut routed = router::score_route(
                &h,
                &layer.gate,
                self.cfg.top_k,
                li,
                odp,
                &importance,
                masked,
                opts.collect_ratio_samples,
                &mut stats,
            );
            sink.routing(li, &routed.probs, &routed.topk);

            let ovr = opts
                .override_expert
                .filter(|&(l, _, _)| l == li)
                .map(|(_, e, repl)| (e, repl));
            let batches = if self.resolver.is_resident() {
                dispatch::dispatch_experts(
                    &h,
                    &routed.topk,
                    ExpertsRef::resident(&layer.experts),
                    ovr,
                    dispatch::DispatchMode::Auto,
                )
            } else {
                // cache-resolved experts: pin the routed set for the
                // dispatch, feed the prefetcher, unpin after
                crate::offload::unique_experts(&routed.topk, &mut needed);
                let unavailable = self.resolver.pin_layer(li, &needed, &mut pins);
                self.resolver.note_routing(li, &needed);
                if unavailable > 0
                    && crate::offload::degrade_topk(&mut routed.topk, &pins) > 0
                {
                    self.resolver.note_degraded();
                }
                let batches = dispatch::dispatch_experts(
                    &h,
                    &routed.topk,
                    ExpertsRef::pinned(&pins),
                    ovr,
                    dispatch::DispatchMode::Auto,
                );
                self.resolver.unpin_layer(li, &needed);
                batches
            };
            for b in &batches {
                sink.expert_batch(li, b.expert, &b.x, &b.gated);
            }
            let y = dispatch::scatter(&batches, s, d);
            add_inplace(&mut x, &y);

            if opts.collect_probs {
                all_probs.push(routed.probs);
            }
            if opts.collect_importance {
                all_importance.push(importance);
            }
            if opts.collect_ratio_samples {
                all_ratio_samples.push(routed.ratio_samples);
            }
        }

        let xf = rmsnorm(&x, &self.final_norm, RMS_EPS);
        ForwardOut {
            logits: xf.matmul(&self.lm_head),
            stats,
            probs: all_probs,
            importance: all_importance,
            ratio_samples: all_ratio_samples,
        }
    }

    /// Convenience: plain scoring logits, no ODP, no collection.
    pub fn score(&self, tokens: &[u32]) -> Mat {
        self.forward(tokens, &ForwardOpts::default(), &mut NullSink).logits
    }

    /// Sum of next-token log-likelihoods of `targets` given the logits
    /// computed at positions [start-1 .. start-1+len).
    pub fn continuation_logprob(logits: &Mat, tokens: &[u32], start: usize) -> f32 {
        let mut total = 0.0;
        let mut lp = Vec::new();
        for (i, &tok) in tokens.iter().enumerate().skip(start) {
            log_softmax_into(logits.row(i - 1), &mut lp);
            total += lp[tok as usize];
        }
        total
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Randomly-initialized model for unit tests across modules.
    pub fn random_model(cfg: &ModelConfig, seed: u64) -> MoeModel {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let mk = |rng: &mut Rng, r: usize, c: usize| {
            QTensor::F32(Mat::randn(rng, r, c, (r as f32).powf(-0.5)))
        };
        let layers = (0..cfg.n_layers)
            .map(|_| Layer {
                attn_norm: vec![1.0; d],
                ffn_norm: vec![1.0; d],
                gate: Mat::randn(&mut rng, d, cfg.n_experts, (d as f32).powf(-0.5)),
                wq: mk(&mut rng, d, d),
                wk: mk(&mut rng, d, d),
                wv: mk(&mut rng, d, d),
                wo: mk(&mut rng, d, d),
                experts: (0..cfg.n_experts)
                    .map(|_| Expert {
                        w1: mk(&mut rng, d, cfg.d_ff),
                        w3: mk(&mut rng, d, cfg.d_ff),
                        w2: mk(&mut rng, cfg.d_ff, d),
                    })
                    .collect(),
            })
            .collect();
        MoeModel {
            cfg: cfg.clone(),
            tok_emb: Mat::randn(&mut rng, cfg.vocab_size, d, 0.02),
            pos_emb: Mat::randn(&mut rng, cfg.max_seq, d, 0.02),
            final_norm: vec![1.0; d],
            lm_head: Mat::randn(&mut rng, d, cfg.vocab_size, (d as f32).powf(-0.5)),
            layers,
            resolver: crate::offload::resident(),
        }
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n).map(|i| ((i * 37) % 200 + 1) as u32).collect()
    }

    #[test]
    fn forward_shapes_and_stats() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 0);
        let out = m.forward(&toks(24), &ForwardOpts::default(), &mut NullSink);
        assert_eq!((out.logits.rows, out.logits.cols), (24, cfg.vocab_size));
        assert_eq!(out.stats.expert_possible, 24 * 2 * cfg.n_layers);
        assert_eq!(out.stats.expert_calls, out.stats.expert_possible);
        assert_eq!(out.stats.compression_ratio(), 0.0);
    }

    #[test]
    fn forward_is_causal() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 1);
        let t1 = toks(20);
        let mut t2 = t1.clone();
        t2[15] = 42;
        let l1 = m.score(&t1);
        let l2 = m.score(&t2);
        for i in 0..15 {
            for c in 0..cfg.vocab_size {
                assert!((l1.at(i, c) - l2.at(i, c)).abs() < 1e-5);
            }
        }
        // position 15 onward must differ
        assert!((0..cfg.vocab_size).any(|c| (l1.at(15, c) - l2.at(15, c)).abs() > 1e-6));
    }

    #[test]
    fn mask_expert_reroutes() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 2);
        let opts = ForwardOpts {
            mask_expert: Some((0, 1)),
            ..Default::default()
        };
        let out = m.forward(&toks(16), &opts, &mut NullSink);
        assert_eq!(out.stats.activation_counts[0][1], 0);
        // all tokens still get top_k experts
        assert_eq!(out.stats.expert_calls, out.stats.expert_possible);
        // other layers unaffected
        assert!(out.stats.activation_counts[1].iter().sum::<u64>() > 0);
    }

    #[test]
    fn weight_only_pruning_reduces_calls() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 3);
        let policy = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let opts = ForwardOpts { odp: Some(&policy), ..Default::default() };
        let out = m.forward(&toks(32), &opts, &mut NullSink);
        // mu=2.0 > any ratio -> every secondary pruned
        assert_eq!(out.stats.dropped_secondary, 32 * cfg.n_layers);
        assert!((out.stats.compression_ratio() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn protection_spares_tokens() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 4);
        let all = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let prot = OdpPolicy::Protected { mu: vec![2.0; cfg.n_layers], protect_ratio: 0.25 };
        let o1 = m.forward(&toks(32), &ForwardOpts { odp: Some(&all), ..Default::default() }, &mut NullSink);
        let o2 = m.forward(&toks(32), &ForwardOpts { odp: Some(&prot), ..Default::default() }, &mut NullSink);
        let spared = (32.0 * 0.25f32).ceil() as usize * cfg.n_layers;
        assert_eq!(o1.stats.dropped_secondary - o2.stats.dropped_secondary, spared);
    }

    #[test]
    fn drop_all_masks_experts() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 5);
        let policy = OdpPolicy::ProtectedDropAll {
            mu: vec![0.0; cfg.n_layers],
            protect_ratio: 0.0,
            drop_ratio: 0.5,
        };
        let out = m.forward(&toks(32), &ForwardOpts { odp: Some(&policy), ..Default::default() }, &mut NullSink);
        assert_eq!(out.stats.dropped_all, 16 * 2 * cfg.n_layers);
    }

    #[test]
    fn override_expert_changes_output() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 6);
        let mut rng = Rng::new(99);
        let repl = Expert {
            w1: QTensor::F32(Mat::randn(&mut rng, cfg.d_model, cfg.d_ff, 0.1)),
            w3: QTensor::F32(Mat::randn(&mut rng, cfg.d_model, cfg.d_ff, 0.1)),
            w2: QTensor::F32(Mat::randn(&mut rng, cfg.d_ff, cfg.d_model, 0.1)),
        };
        let base = m.score(&toks(16));
        let opts = ForwardOpts {
            override_expert: Some((0, 0, &repl)),
            ..Default::default()
        };
        let swapped = m.forward(&toks(16), &opts, &mut NullSink).logits;
        assert!(base.sub(&swapped).fro_norm() > 1e-3);
    }

    #[test]
    fn importance_collection() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 7);
        let opts = ForwardOpts {
            collect_importance: true,
            collect_probs: true,
            ..Default::default()
        };
        let out = m.forward(&toks(16), &opts, &mut NullSink);
        assert_eq!(out.importance.len(), cfg.n_layers);
        assert_eq!(out.importance[0].len(), 16);
        assert!(out.importance[0].iter().all(|v| *v >= 0.0));
        assert_eq!(out.probs[0].rows, 16);
        for t in 0..16 {
            let s: f32 = out.probs[0].row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn routing_sink_sees_all_layers() {
        struct Counter(Vec<usize>);
        impl CalibSink for Counter {
            fn routing(&mut self, layer: usize, _p: &Mat, _t: &[Vec<(usize, f32)>]) {
                self.0[layer] += 1;
            }
        }
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 8);
        let mut sink = Counter(vec![0; cfg.n_layers]);
        m.forward(&toks(8), &ForwardOpts::default(), &mut sink);
        assert!(sink.0.iter().all(|&c| c == 1));
    }
}
