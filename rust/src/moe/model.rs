//! Native MoE transformer engine (rust twin of `python/compile/model.py`).
//!
//! One engine serves every representation: experts are `QTensor`s, so
//! the same forward runs the FP32 reference, RTN/GPTQ-quantized, and
//! binary models. ODP (paper Sec. 3.3) is applied inline during routing;
//! calibration sinks observe expert inputs for GPTQ Hessians and
//! significance statistics (Sec. 3.2.1).
//!
//! Numerical parity with the JAX model is asserted against
//! `artifacts/golden.mcwt` in `tests/golden_parity.rs`.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::quant::QTensor;
use crate::tensor::{add_inplace, log_softmax, rmsnorm, softmax_rows, Mat};
use crate::util::stats::{kurtosis, mean, top_k_indices, variance};

use super::weights::WeightFile;

pub const RMS_EPS: f32 = 1e-5;
const NEG_INF: f32 = -1e30;

// ---------------------------------------------------------------------------
// Weights
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Expert {
    pub w1: QTensor,
    pub w3: QTensor,
    pub w2: QTensor,
}

impl Expert {
    /// SwiGLU FFN on a token batch x[T, D] -> y[T, D].
    pub fn forward(&self, x: &Mat) -> Mat {
        let g = self.gated_hidden(x);
        self.w2.matmul(&g)
    }

    /// silu(x@w1) * (x@w3) — exposed so calibration can capture the
    /// w2-input Hessian.
    pub fn gated_hidden(&self, x: &Mat) -> Mat {
        let mut h1 = self.w1.matmul(x);
        let h3 = self.w3.matmul(x);
        for (a, &b) in h1.data.iter_mut().zip(&h3.data) {
            *a = crate::tensor::silu(*a) * b;
        }
        h1
    }

    pub fn storage_bytes(&self) -> usize {
        self.w1.storage_bytes() + self.w3.storage_bytes() + self.w2.storage_bytes()
    }

    pub fn param_count(&self) -> usize {
        let (k1, n1) = self.w1.shape();
        let (k3, n3) = self.w3.shape();
        let (k2, n2) = self.w2.shape();
        k1 * n1 + k3 * n3 + k2 * n2
    }
}

#[derive(Debug, Clone)]
pub struct Layer {
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub gate: Mat,
    pub wq: QTensor,
    pub wk: QTensor,
    pub wv: QTensor,
    pub wo: QTensor,
    pub experts: Vec<Expert>,
}

#[derive(Debug, Clone)]
pub struct MoeModel {
    pub cfg: ModelConfig,
    pub tok_emb: Mat,
    pub pos_emb: Mat,
    pub final_norm: Vec<f32>,
    pub lm_head: Mat,
    pub layers: Vec<Layer>,
}

impl MoeModel {
    /// Load the FP32 model from an MCWT weight file.
    pub fn load_f32(cfg: &ModelConfig, wf: &WeightFile) -> Result<MoeModel> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut experts = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                let p = |m: &str| format!("layers.{i}.experts.{e}.{m}");
                experts.push(Expert {
                    w1: QTensor::F32(wf.mat(&p("w1"))?),
                    w3: QTensor::F32(wf.mat(&p("w3"))?),
                    w2: QTensor::F32(wf.mat(&p("w2"))?),
                });
            }
            layers.push(Layer {
                attn_norm: wf.vec1(&format!("layers.{i}.attn_norm"))?,
                ffn_norm: wf.vec1(&format!("layers.{i}.ffn_norm"))?,
                gate: wf.mat(&format!("layers.{i}.gate"))?,
                wq: QTensor::F32(wf.mat(&format!("layers.{i}.attn.wq"))?),
                wk: QTensor::F32(wf.mat(&format!("layers.{i}.attn.wk"))?),
                wv: QTensor::F32(wf.mat(&format!("layers.{i}.attn.wv"))?),
                wo: QTensor::F32(wf.mat(&format!("layers.{i}.attn.wo"))?),
                experts,
            });
        }
        Ok(MoeModel {
            cfg: cfg.clone(),
            tok_emb: wf.mat("tok_emb")?,
            pos_emb: wf.mat("pos_emb")?,
            final_norm: wf.vec1("final_norm")?,
            lm_head: wf.mat("lm_head")?,
            layers,
        })
    }

    /// Total weight storage in bytes (the paper's "Params" column).
    pub fn storage_bytes(&self) -> usize {
        let mut total = (self.tok_emb.data.len()
            + self.pos_emb.data.len()
            + self.final_norm.len()
            + self.lm_head.data.len())
            * 4;
        for l in &self.layers {
            total += (l.attn_norm.len() + l.ffn_norm.len() + l.gate.data.len()) * 4;
            total += l.wq.storage_bytes()
                + l.wk.storage_bytes()
                + l.wv.storage_bytes()
                + l.wo.storage_bytes();
            for e in &l.experts {
                total += e.storage_bytes();
            }
        }
        total
    }

    /// Average bits per *expert* weight (the paper's "Bits" axis).
    pub fn expert_avg_bits(&self) -> f64 {
        let mut bits = 0.0;
        let mut elems = 0.0;
        for l in &self.layers {
            for e in &l.experts {
                for t in [&e.w1, &e.w3, &e.w2] {
                    let (k, n) = t.shape();
                    bits += t.storage_bytes() as f64 * 8.0;
                    elems += (k * n) as f64;
                }
            }
        }
        bits / elems
    }
}

// ---------------------------------------------------------------------------
// ODP policy (paper Sec. 3.3; calibrated by `odp::calibrate`)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenMetric {
    /// paper Eq. 6: ||t||_1 * attention-received column mean
    Eq6Importance,
    /// Tab. 11 baselines over the token hidden state
    Kurtosis,
    Variance,
    MeanAbs,
}

#[derive(Debug, Clone)]
pub enum OdpPolicy {
    /// no dynamic pruning
    None,
    /// Lu et al. 2024: drop the secondary expert when w1/w0 < mu[layer]
    WeightOnly { mu: Vec<f32> },
    /// ODP: weight pruning + protect the top `protect_ratio` tokens by
    /// Eq.-6 importance (their experts are never pruned)
    Protected { mu: Vec<f32>, protect_ratio: f32 },
    /// Fig. 8 mode: Protected + additionally mask *all* experts of the
    /// bottom `drop_ratio` tokens
    ProtectedDropAll { mu: Vec<f32>, protect_ratio: f32, drop_ratio: f32 },
    /// Tab. 11 baselines: prune the secondary expert of the bottom
    /// `prune_frac` tokens ranked by `metric`
    TokenMetric { metric: TokenMetric, prune_frac: f32 },
}

impl OdpPolicy {
    fn needs_importance(&self) -> bool {
        matches!(
            self,
            OdpPolicy::Protected { .. }
                | OdpPolicy::ProtectedDropAll { .. }
                | OdpPolicy::TokenMetric { metric: TokenMetric::Eq6Importance, .. }
        )
    }
}

// ---------------------------------------------------------------------------
// Forward options / outputs
// ---------------------------------------------------------------------------

/// Observer for calibration passes (GPTQ Hessians, significance).
pub trait CalibSink {
    /// Rows of the post-norm hidden state routed to (layer, expert),
    /// plus the gated hidden (input of w2).
    fn expert_batch(&mut self, _layer: usize, _expert: usize, _x: &Mat, _gated: &Mat) {}
    /// Full router distribution for one layer ([S, E]) and the selected
    /// (renormalized) top-k weights per token.
    fn routing(&mut self, _layer: usize, _probs: &Mat, _topk: &[Vec<(usize, f32)>]) {}
    /// Attention inputs of one layer (for quantizing wq/wk/wv).
    fn attn_batch(&mut self, _layer: usize, _x: &Mat) {}
    /// Concatenated head outputs (input of wo).
    fn attn_out_batch(&mut self, _layer: usize, _x: &Mat) {}
    /// Post-ffn-norm hidden states (input of the gate and experts).
    fn moe_input(&mut self, _layer: usize, _x: &Mat) {}
}

/// No-op sink.
pub struct NullSink;
impl CalibSink for NullSink {}

#[derive(Default)]
pub struct ForwardOpts<'a> {
    pub odp: Option<&'a OdpPolicy>,
    /// exclude this (layer, expert) from routing entirely (drop-F-norm)
    pub mask_expert: Option<(usize, usize)>,
    /// substitute this expert at (layer, expert) (PMQ's eps_{i,j} probe)
    pub override_expert: Option<(usize, usize, &'a Expert)>,
    pub collect_probs: bool,
    pub collect_importance: bool,
    pub collect_ratio_samples: bool,
}

#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// expert invocations actually executed
    pub expert_calls: usize,
    /// S * top_k summed over layers (the no-pruning count)
    pub expert_possible: usize,
    pub dropped_secondary: usize,
    pub dropped_all: usize,
    /// per [layer][expert] activation counts (significance phi)
    pub activation_counts: Vec<Vec<u64>>,
    /// per [layer][expert] summed renormalized routing weights (w_i)
    pub weight_sums: Vec<Vec<f64>>,
    pub tokens_seen: usize,
}

impl RunStats {
    pub fn new(n_layers: usize, n_experts: usize) -> RunStats {
        RunStats {
            activation_counts: vec![vec![0; n_experts]; n_layers],
            weight_sums: vec![vec![0.0; n_experts]; n_layers],
            ..Default::default()
        }
    }

    pub fn merge(&mut self, other: &RunStats) {
        self.expert_calls += other.expert_calls;
        self.expert_possible += other.expert_possible;
        self.dropped_secondary += other.dropped_secondary;
        self.dropped_all += other.dropped_all;
        self.tokens_seen += other.tokens_seen;
        for (a, b) in self.activation_counts.iter_mut().zip(&other.activation_counts) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        for (a, b) in self.weight_sums.iter_mut().zip(&other.weight_sums) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    /// Fraction of expert compute saved by pruning (paper's "CR").
    pub fn compression_ratio(&self) -> f64 {
        if self.expert_possible == 0 {
            return 0.0;
        }
        (self.dropped_secondary + self.dropped_all) as f64 / self.expert_possible as f64
    }
}

pub struct ForwardOut {
    pub logits: Mat,
    pub stats: RunStats,
    pub probs: Vec<Mat>,
    pub importance: Vec<Vec<f32>>,
    pub ratio_samples: Vec<Vec<f32>>,
}

// ---------------------------------------------------------------------------
// Forward
// ---------------------------------------------------------------------------

impl MoeModel {
    /// Full-sequence scoring forward. `tokens` length <= cfg.max_seq.
    pub fn forward(&self, tokens: &[u32], opts: &ForwardOpts,
                   sink: &mut dyn CalibSink) -> ForwardOut {
        let s = tokens.len();
        let (d, nh) = (self.cfg.d_model, self.cfg.n_heads);
        let hd = d / nh;
        assert!(s <= self.cfg.max_seq, "sequence too long: {s}");

        let mut x = Mat::zeros(s, d);
        for (t, &tok) in tokens.iter().enumerate() {
            let emb = self.tok_emb.row(tok as usize);
            let pos = self.pos_emb.row(t);
            for c in 0..d {
                x.data[t * d + c] = emb[c] + pos[c];
            }
        }

        let mut stats = RunStats::new(self.cfg.n_layers, self.cfg.n_experts);
        let mut out = ForwardOut {
            logits: Mat::zeros(0, 0),
            stats: RunStats::new(self.cfg.n_layers, self.cfg.n_experts),
            probs: Vec::new(),
            importance: Vec::new(),
            ratio_samples: Vec::new(),
        };
        stats.tokens_seen = s;

        for (li, layer) in self.layers.iter().enumerate() {
            // ---- attention ----
            let h = rmsnorm(&x, &layer.attn_norm, RMS_EPS);
            sink.attn_batch(li, &h);
            let q = layer.wq.matmul(&h);
            let k = layer.wk.matmul(&h);
            let v = layer.wv.matmul(&h);
            // head-averaged attention map, accumulated for Eq. 6
            let mut a_mean = Mat::zeros(s, s);
            let mut attn_out = Mat::zeros(s, d);
            let scale = 1.0 / (hd as f32).sqrt();
            // transposed K per head so the score loop vectorizes over j
            // (EXPERIMENTS.md §Perf: ikj axpy instead of per-pair dots)
            let mut kht = vec![0.0f32; hd * s];
            for head in 0..nh {
                let c0 = head * hd;
                for j in 0..s {
                    let krow = &k.row(j)[c0..c0 + hd];
                    for (d, &kv) in krow.iter().enumerate() {
                        kht[d * s + j] = kv;
                    }
                }
                let mut scores = Mat::zeros(s, s);
                for i in 0..s {
                    let qrow = &q.row(i)[c0..c0 + hd];
                    let srow = &mut scores.data[i * s..i * s + s];
                    for (d, &qv) in qrow.iter().enumerate() {
                        let kr = &kht[d * s..d * s + i + 1];
                        for (sv, &kv) in srow[..=i].iter_mut().zip(kr) {
                            *sv += qv * kv;
                        }
                    }
                    for sv in srow[..=i].iter_mut() {
                        *sv *= scale;
                    }
                    for sv in srow[i + 1..].iter_mut() {
                        *sv = NEG_INF;
                    }
                }
                softmax_rows(&mut scores);
                for (am, sc) in a_mean.data.iter_mut().zip(&scores.data) {
                    *am += sc / nh as f32;
                }
                // attn_out[:, c0..c0+hd] = scores @ v[:, c0..c0+hd]
                for i in 0..s {
                    for j in 0..=i {
                        let a = scores.data[i * s + j];
                        if a == 0.0 {
                            continue;
                        }
                        let vrow = &v.row(j)[c0..c0 + hd];
                        let orow = &mut attn_out.data[i * d + c0..i * d + c0 + hd];
                        for (o, &vv) in orow.iter_mut().zip(vrow) {
                            *o += a * vv;
                        }
                    }
                }
            }
            sink.attn_out_batch(li, &attn_out);
            let attn_proj = layer.wo.matmul(&attn_out);
            add_inplace(&mut x, &attn_proj);

            // ---- MoE FFN ----
            let h = rmsnorm(&x, &layer.ffn_norm, RMS_EPS);
            sink.moe_input(li, &h);

            // router
            let mut probs = h.matmul(&layer.gate);
            softmax_rows(&mut probs);

            // token metric for ODP
            let odp = opts.odp.unwrap_or(&OdpPolicy::None);
            let needs_imp = odp.needs_importance() || opts.collect_importance;
            let importance: Vec<f32> = if needs_imp {
                eq6_importance(&h, &a_mean)
            } else {
                Vec::new()
            };
            let metric_vals: Vec<f32> = match odp {
                OdpPolicy::TokenMetric { metric, .. } => match metric {
                    TokenMetric::Eq6Importance => importance.clone(),
                    TokenMetric::Kurtosis => {
                        (0..s).map(|t| kurtosis(h.row(t))).collect()
                    }
                    TokenMetric::Variance => {
                        (0..s).map(|t| variance(h.row(t))).collect()
                    }
                    TokenMetric::MeanAbs => (0..s)
                        .map(|t| mean(&h.row(t).iter().map(|v| v.abs()).collect::<Vec<_>>()))
                        .collect(),
                },
                _ => Vec::new(),
            };

            // protected / dropped token sets
            let protected = match odp {
                OdpPolicy::Protected { protect_ratio, .. }
                | OdpPolicy::ProtectedDropAll { protect_ratio, .. } => {
                    let n_prot = ((s as f32) * protect_ratio).ceil() as usize;
                    let mut mask = vec![false; s];
                    for idx in top_k_indices(&importance, n_prot.min(s)) {
                        mask[idx] = true;
                    }
                    mask
                }
                _ => vec![false; s],
            };
            let drop_all = match odp {
                OdpPolicy::ProtectedDropAll { drop_ratio, .. } => {
                    let n_drop = ((s as f32) * drop_ratio).floor() as usize;
                    let neg: Vec<f32> = importance.iter().map(|v| -v).collect();
                    let mut mask = vec![false; s];
                    for idx in top_k_indices(&neg, n_drop.min(s)) {
                        if !protected[idx] {
                            mask[idx] = true;
                        }
                    }
                    mask
                }
                _ => vec![false; s],
            };
            let metric_pruned = match odp {
                OdpPolicy::TokenMetric { prune_frac, .. } => {
                    let n_prune = ((s as f32) * prune_frac).round() as usize;
                    let neg: Vec<f32> = metric_vals.iter().map(|v| -v).collect();
                    let mut mask = vec![false; s];
                    for idx in top_k_indices(&neg, n_prune.min(s)) {
                        mask[idx] = true;
                    }
                    mask
                }
                _ => vec![false; s],
            };

            // per-token top-k selection (+ ODP decisions)
            let mut topk: Vec<Vec<(usize, f32)>> = Vec::with_capacity(s);
            let mut ratio_samples = Vec::new();
            stats.expert_possible += s * self.cfg.top_k;
            for t in 0..s {
                let row = probs.row(t);
                let mut sel = select_top_k(row, self.cfg.top_k, |e| {
                    opts.mask_expert != Some((li, e))
                });
                // renormalize
                let sum: f32 = sel.iter().map(|&(_, w)| w).sum();
                for se in sel.iter_mut() {
                    se.1 /= sum;
                }
                for &(e, w) in &sel {
                    stats.activation_counts[li][e] += 1;
                    stats.weight_sums[li][e] += w as f64;
                }
                let ratio = if sel.len() >= 2 { sel[1].1 / sel[0].1 } else { 0.0 };
                if opts.collect_ratio_samples {
                    ratio_samples.push(ratio);
                }
                // ODP decision
                if drop_all[t] {
                    stats.dropped_all += sel.len();
                    sel.clear();
                } else {
                    let prune_secondary = match odp {
                        OdpPolicy::None => false,
                        OdpPolicy::WeightOnly { mu } => ratio < mu[li],
                        OdpPolicy::Protected { mu, .. }
                        | OdpPolicy::ProtectedDropAll { mu, .. } => {
                            !protected[t] && ratio < mu[li]
                        }
                        OdpPolicy::TokenMetric { .. } => metric_pruned[t],
                    };
                    if prune_secondary && sel.len() >= 2 {
                        sel.truncate(1);
                        sel[0].1 = 1.0;
                        stats.dropped_secondary += 1;
                    }
                }
                stats.expert_calls += sel.len();
                topk.push(sel);
            }
            sink.routing(li, &probs, &topk);

            // gather tokens per expert, run expert FFN batched, scatter
            let mut y = Mat::zeros(s, d);
            for e in 0..self.cfg.n_experts {
                let rows: Vec<(usize, f32)> = (0..s)
                    .flat_map(|t| {
                        topk[t].iter().filter(|&&(ex, _)| ex == e).map(move |&(_, w)| (t, w))
                    })
                    .collect();
                if rows.is_empty() {
                    continue;
                }
                let mut xe = Mat::zeros(rows.len(), d);
                for (ri, &(t, _)) in rows.iter().enumerate() {
                    xe.row_mut(ri).copy_from_slice(h.row(t));
                }
                let expert: &Expert = match opts.override_expert {
                    Some((l, ex, repl)) if l == li && ex == e => repl,
                    _ => &layer.experts[e],
                };
                let gated = expert.gated_hidden(&xe);
                sink.expert_batch(li, e, &xe, &gated);
                let ye = expert.w2.matmul(&gated);
                for (ri, &(t, w)) in rows.iter().enumerate() {
                    let yrow = ye.row(ri);
                    let orow = &mut y.data[t * d..(t + 1) * d];
                    for (o, &v) in orow.iter_mut().zip(yrow) {
                        *o += w * v;
                    }
                }
            }
            add_inplace(&mut x, &y);

            if opts.collect_probs {
                out.probs.push(probs);
            }
            if opts.collect_importance {
                out.importance.push(importance);
            }
            if opts.collect_ratio_samples {
                out.ratio_samples.push(ratio_samples);
            }
        }

        let xf = rmsnorm(&x, &self.final_norm, RMS_EPS);
        out.logits = xf.matmul(&self.lm_head);
        out.stats = stats;
        out
    }

    /// Convenience: plain scoring logits, no ODP, no collection.
    pub fn score(&self, tokens: &[u32]) -> Mat {
        self.forward(tokens, &ForwardOpts::default(), &mut NullSink).logits
    }

    /// Sum of next-token log-likelihoods of `targets` given the logits
    /// computed at positions [start-1 .. start-1+len).
    pub fn continuation_logprob(logits: &Mat, tokens: &[u32], start: usize) -> f32 {
        let mut total = 0.0;
        for (i, &tok) in tokens.iter().enumerate().skip(start) {
            let lp = log_softmax(logits.row(i - 1));
            total += lp[tok as usize];
        }
        total
    }
}

/// Eq. 6: I_j = ||t_j||_1 * mean_{i >= j} A[i, j] (head-averaged A).
pub fn eq6_importance(h: &Mat, a_mean: &Mat) -> Vec<f32> {
    let s = h.rows;
    let mut out = vec![0.0f32; s];
    for j in 0..s {
        let mut col = 0.0;
        for i in j..s {
            col += a_mean.data[i * s + j];
        }
        let denom = (s - j).max(1) as f32;
        let l1: f32 = h.row(j).iter().map(|v| v.abs()).sum();
        out[j] = l1 * (col / denom);
    }
    out
}

/// Top-k expert selection over a router row, honoring an eligibility
/// filter; ties break toward the lower index (matches jax.lax.top_k).
pub fn select_top_k(row: &[f32], k: usize, eligible: impl Fn(usize) -> bool)
                    -> Vec<(usize, f32)> {
    let mut sel: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for (e, &w) in row.iter().enumerate() {
        if !eligible(e) {
            continue;
        }
        sel.push((e, w));
        sel.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        sel.truncate(k);
    }
    sel
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Randomly-initialized model for unit tests across modules.
    pub fn random_model(cfg: &ModelConfig, seed: u64) -> MoeModel {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let mk = |rng: &mut Rng, r: usize, c: usize| {
            QTensor::F32(Mat::randn(rng, r, c, (r as f32).powf(-0.5)))
        };
        let layers = (0..cfg.n_layers)
            .map(|_| Layer {
                attn_norm: vec![1.0; d],
                ffn_norm: vec![1.0; d],
                gate: Mat::randn(&mut rng, d, cfg.n_experts, (d as f32).powf(-0.5)),
                wq: mk(&mut rng, d, d),
                wk: mk(&mut rng, d, d),
                wv: mk(&mut rng, d, d),
                wo: mk(&mut rng, d, d),
                experts: (0..cfg.n_experts)
                    .map(|_| Expert {
                        w1: mk(&mut rng, d, cfg.d_ff),
                        w3: mk(&mut rng, d, cfg.d_ff),
                        w2: mk(&mut rng, cfg.d_ff, d),
                    })
                    .collect(),
            })
            .collect();
        MoeModel {
            cfg: cfg.clone(),
            tok_emb: Mat::randn(&mut rng, cfg.vocab_size, d, 0.02),
            pos_emb: Mat::randn(&mut rng, cfg.max_seq, d, 0.02),
            final_norm: vec![1.0; d],
            lm_head: Mat::randn(&mut rng, d, cfg.vocab_size, (d as f32).powf(-0.5)),
            layers,
        }
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n).map(|i| ((i * 37) % 200 + 1) as u32).collect()
    }

    #[test]
    fn forward_shapes_and_stats() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 0);
        let out = m.forward(&toks(24), &ForwardOpts::default(), &mut NullSink);
        assert_eq!((out.logits.rows, out.logits.cols), (24, cfg.vocab_size));
        assert_eq!(out.stats.expert_possible, 24 * 2 * cfg.n_layers);
        assert_eq!(out.stats.expert_calls, out.stats.expert_possible);
        assert_eq!(out.stats.compression_ratio(), 0.0);
    }

    #[test]
    fn forward_is_causal() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 1);
        let t1 = toks(20);
        let mut t2 = t1.clone();
        t2[15] = 42;
        let l1 = m.score(&t1);
        let l2 = m.score(&t2);
        for i in 0..15 {
            for c in 0..cfg.vocab_size {
                assert!((l1.at(i, c) - l2.at(i, c)).abs() < 1e-5);
            }
        }
        // position 15 onward must differ
        assert!((0..cfg.vocab_size).any(|c| (l1.at(15, c) - l2.at(15, c)).abs() > 1e-6));
    }

    #[test]
    fn select_top_k_ties_prefer_lower_index() {
        let sel = select_top_k(&[0.25, 0.25, 0.4, 0.1], 2, |_| true);
        assert_eq!(sel[0].0, 2);
        assert_eq!(sel[1].0, 0); // tie 0 vs 1 -> lower index
    }

    #[test]
    fn mask_expert_reroutes() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 2);
        let opts = ForwardOpts {
            mask_expert: Some((0, 1)),
            ..Default::default()
        };
        let out = m.forward(&toks(16), &opts, &mut NullSink);
        assert_eq!(out.stats.activation_counts[0][1], 0);
        // all tokens still get top_k experts
        assert_eq!(out.stats.expert_calls, out.stats.expert_possible);
        // other layers unaffected
        assert!(out.stats.activation_counts[1].iter().sum::<u64>() > 0);
    }

    #[test]
    fn weight_only_pruning_reduces_calls() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 3);
        let policy = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let opts = ForwardOpts { odp: Some(&policy), ..Default::default() };
        let out = m.forward(&toks(32), &opts, &mut NullSink);
        // mu=2.0 > any ratio -> every secondary pruned
        assert_eq!(out.stats.dropped_secondary, 32 * cfg.n_layers);
        assert!((out.stats.compression_ratio() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn protection_spares_tokens() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 4);
        let all = OdpPolicy::WeightOnly { mu: vec![2.0; cfg.n_layers] };
        let prot = OdpPolicy::Protected { mu: vec![2.0; cfg.n_layers], protect_ratio: 0.25 };
        let o1 = m.forward(&toks(32), &ForwardOpts { odp: Some(&all), ..Default::default() }, &mut NullSink);
        let o2 = m.forward(&toks(32), &ForwardOpts { odp: Some(&prot), ..Default::default() }, &mut NullSink);
        let spared = (32.0 * 0.25f32).ceil() as usize * cfg.n_layers;
        assert_eq!(o1.stats.dropped_secondary - o2.stats.dropped_secondary, spared);
    }

    #[test]
    fn drop_all_masks_experts() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 5);
        let policy = OdpPolicy::ProtectedDropAll {
            mu: vec![0.0; cfg.n_layers],
            protect_ratio: 0.0,
            drop_ratio: 0.5,
        };
        let out = m.forward(&toks(32), &ForwardOpts { odp: Some(&policy), ..Default::default() }, &mut NullSink);
        assert_eq!(out.stats.dropped_all, 16 * 2 * cfg.n_layers);
    }

    #[test]
    fn override_expert_changes_output() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 6);
        let mut rng = Rng::new(99);
        let repl = Expert {
            w1: QTensor::F32(Mat::randn(&mut rng, cfg.d_model, cfg.d_ff, 0.1)),
            w3: QTensor::F32(Mat::randn(&mut rng, cfg.d_model, cfg.d_ff, 0.1)),
            w2: QTensor::F32(Mat::randn(&mut rng, cfg.d_ff, cfg.d_model, 0.1)),
        };
        let base = m.score(&toks(16));
        let opts = ForwardOpts {
            override_expert: Some((0, 0, &repl)),
            ..Default::default()
        };
        let swapped = m.forward(&toks(16), &opts, &mut NullSink).logits;
        assert!(base.sub(&swapped).fro_norm() > 1e-3);
    }

    #[test]
    fn importance_collection() {
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 7);
        let opts = ForwardOpts {
            collect_importance: true,
            collect_probs: true,
            ..Default::default()
        };
        let out = m.forward(&toks(16), &opts, &mut NullSink);
        assert_eq!(out.importance.len(), cfg.n_layers);
        assert_eq!(out.importance[0].len(), 16);
        assert!(out.importance[0].iter().all(|v| *v >= 0.0));
        assert_eq!(out.probs[0].rows, 16);
        for t in 0..16 {
            let s: f32 = out.probs[0].row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn routing_sink_sees_all_layers() {
        struct Counter(Vec<usize>);
        impl CalibSink for Counter {
            fn routing(&mut self, layer: usize, _p: &Mat, _t: &[Vec<(usize, f32)>]) {
                self.0[layer] += 1;
            }
        }
        let cfg = ModelConfig::test_tiny();
        let m = random_model(&cfg, 8);
        let mut sink = Counter(vec![0; cfg.n_layers]);
        m.forward(&toks(8), &ForwardOpts::default(), &mut sink);
        assert!(sink.0.iter().all(|&c| c == 1));
    }
}
