//! MCQZ — compressed-model serialization.
//!
//! Saves an MC-compressed `MoeModel` (mixed f32 / packed / binary
//! tensors) so deployment loads the quantized weights directly instead
//! of re-running calibration + GPTQ — the paper's "pre-loading" story.
//!
//! Layout (little-endian): magic "MCQZ", u32 version, u32 header len,
//! JSON header describing every tensor (kind, dims, bits, group,
//! section offsets), then the raw payload 64-byte aligned per section.
//!
//! **v2 (segmented):** every non-expert tensor is written before any
//! expert, and each expert's three tensors occupy one contiguous byte
//! range recorded in an `expert_dir` header table (plus `experts_off`,
//! where the expert region begins). `offload::ExpertStore` uses the
//! directory to fetch a single expert's bytes with one seek + read —
//! without parsing or materializing the rest of the file — which is
//! what makes byte-budgeted expert residency (DESIGN.md §5) possible.
//! The header may also carry `priors` (calibration significance
//! factors) that seed the cache's eviction score and the prefetcher's
//! co-activation table. v1 files (monolithic, no directory) remain
//! fully loadable; `save_v1` keeps the writer covered by tests.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::offload::ResidencyPriors;
use crate::quant::{BinaryTensor, PackedTensor, QTensor};
use crate::tensor::Mat;
use crate::util::crc32::crc32;
use crate::util::json::{arr, num, obj, s, Json};

use super::model::{Expert, Layer, MoeModel};

pub(crate) const MAGIC: &[u8; 4] = b"MCQZ";
pub(crate) const VERSION: u32 = 2;
pub(crate) const VERSION_V1: u32 = 1;
const ALIGN: usize = 64;

struct Writer {
    payload: Vec<u8>,
    entries: BTreeMap<String, Json>,
}

impl Writer {
    fn new() -> Writer {
        Writer { payload: Vec::new(), entries: BTreeMap::new() }
    }

    fn align(&mut self) -> usize {
        let pad = (ALIGN - self.payload.len() % ALIGN) % ALIGN;
        self.payload.extend(std::iter::repeat_n(0u8, pad));
        self.payload.len()
    }

    fn put_f32(&mut self, data: &[f32]) -> usize {
        let off = self.align();
        for v in data {
            self.payload.extend_from_slice(&v.to_le_bytes());
        }
        off
    }

    fn put_u32(&mut self, data: &[u32]) -> usize {
        let off = self.align();
        for v in data {
            self.payload.extend_from_slice(&v.to_le_bytes());
        }
        off
    }

    fn add_qtensor(&mut self, name: &str, t: &QTensor) {
        let entry = match t {
            QTensor::F32(m) => {
                let off = self.put_f32(&m.data);
                obj(vec![
                    ("kind", s("f32")),
                    ("rows", num(m.rows as f64)),
                    ("cols", num(m.cols as f64)),
                    ("off", num(off as f64)),
                ])
            }
            QTensor::Packed(p) => {
                let qw = self.put_u32(&p.qweight);
                let sc = self.put_f32(&p.scales);
                let zp = self.put_f32(&p.zeros);
                obj(vec![
                    ("kind", s("packed")),
                    ("bits", num(p.bits as f64)),
                    ("k", num(p.k as f64)),
                    ("n", num(p.n as f64)),
                    ("group", num(p.group as f64)),
                    ("qw_off", num(qw as f64)),
                    ("qw_len", num(p.qweight.len() as f64)),
                    ("sc_off", num(sc as f64)),
                    ("sc_len", num(p.scales.len() as f64)),
                    ("zp_off", num(zp as f64)),
                ])
            }
            QTensor::Binary(b) => {
                let pk = self.put_u32(&b.packed);
                let sc = self.put_f32(&b.scales);
                obj(vec![
                    ("kind", s("binary")),
                    ("k", num(b.k as f64)),
                    ("n", num(b.n as f64)),
                    ("pk_off", num(pk as f64)),
                    ("pk_len", num(b.packed.len() as f64)),
                    ("sc_off", num(sc as f64)),
                ])
            }
        };
        self.entries.insert(name.to_string(), entry);
    }

    fn add_vec(&mut self, name: &str, data: &[f32]) {
        let off = self.put_f32(data);
        self.entries.insert(
            name.to_string(),
            obj(vec![
                ("kind", s("vec")),
                ("len", num(data.len() as f64)),
                ("off", num(off as f64)),
            ]),
        );
    }

    fn add_mat(&mut self, name: &str, m: &Mat) {
        self.add_qtensor(name, &QTensor::F32(m.clone()));
    }
}

/// Serialize a (possibly quantized) model to MCQZ v2 (segmented).
pub fn save(path: &Path, model: &MoeModel) -> Result<()> {
    save_with_priors(path, model, None)
}

/// v2 save carrying residency priors (significance factors) for the
/// expert cache's eviction score and the prefetcher's warm start.
pub fn save_with_priors(path: &Path, model: &MoeModel,
                        priors: Option<&ResidencyPriors>) -> Result<()> {
    write_file(path, model, VERSION, priors)
}

/// Legacy v1 writer (no expert directory) — kept so the v1 read path
/// stays exercised (`tests/quant_pipeline.rs` round-trips v1 -> v2).
pub fn save_v1(path: &Path, model: &MoeModel) -> Result<()> {
    write_file(path, model, VERSION_V1, None)
}

fn write_file(path: &Path, model: &MoeModel, version: u32,
              priors: Option<&ResidencyPriors>) -> Result<()> {
    if model.layers.iter().any(|l| l.experts.is_empty()) {
        bail!("cannot save a cache-resolved model (experts are not \
               materialized); save the source model instead");
    }
    let mut w = Writer::new();
    // non-expert tensors first, so a budgeted loader materializes the
    // model head by reading payload[..experts_off] only
    w.add_mat("tok_emb", &model.tok_emb);
    w.add_mat("pos_emb", &model.pos_emb);
    w.add_mat("lm_head", &model.lm_head);
    w.add_vec("final_norm", &model.final_norm);
    for (i, layer) in model.layers.iter().enumerate() {
        let p = |m: &str| format!("layers.{i}.{m}");
        w.add_vec(&p("attn_norm"), &layer.attn_norm);
        w.add_vec(&p("ffn_norm"), &layer.ffn_norm);
        w.add_mat(&p("gate"), &layer.gate);
        w.add_qtensor(&p("attn.wq"), &layer.wq);
        w.add_qtensor(&p("attn.wk"), &layer.wk);
        w.add_qtensor(&p("attn.wv"), &layer.wv);
        w.add_qtensor(&p("attn.wo"), &layer.wo);
    }
    // expert region: one contiguous segment per (layer, expert)
    let experts_off = w.align();
    let mut dir_rows = Vec::with_capacity(model.layers.len());
    for (i, layer) in model.layers.iter().enumerate() {
        let mut row = Vec::with_capacity(layer.experts.len());
        for (e, ex) in layer.experts.iter().enumerate() {
            let seg_off = w.align();
            w.add_qtensor(&format!("layers.{i}.experts.{e}.w1"), &ex.w1);
            w.add_qtensor(&format!("layers.{i}.experts.{e}.w3"), &ex.w3);
            w.add_qtensor(&format!("layers.{i}.experts.{e}.w2"), &ex.w2);
            let seg_len = w.payload.len() - seg_off;
            // per-segment integrity: ExpertStore::fetch re-hashes the
            // bytes it reads so disk corruption surfaces as a typed
            // error, not a garbage expert
            let crc = crc32(&w.payload[seg_off..seg_off + seg_len]);
            row.push(obj(vec![
                ("off", num(seg_off as f64)),
                ("len", num(seg_len as f64)),
                ("crc", num(crc as f64)),
            ]));
        }
        dir_rows.push(arr(row));
    }
    let mut fields = vec![
        ("config", Json::parse(&config_json(&model.cfg))?),
        ("tensors", Json::Obj(w.entries.clone())),
    ];
    if version >= 2 {
        fields.push(("experts_off", num(experts_off as f64)));
        fields.push(("expert_dir", arr(dir_rows)));
        if let Some(p) = priors {
            // a mismatched priors block would panic at serve time
            // deep inside the cache; reject it at save time instead
            p.validate(model.cfg.n_layers, model.cfg.n_experts)?;
            fields.push(("priors", p.to_json()));
        }
    }
    let header = obj(fields).to_string();
    let mut out = Vec::with_capacity(12 + header.len() + w.payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(&w.payload);
    std::fs::write(path, out).with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

fn config_json(cfg: &ModelConfig) -> String {
    obj(vec![
        ("name", s(&cfg.name)),
        ("vocab_size", num(cfg.vocab_size as f64)),
        ("d_model", num(cfg.d_model as f64)),
        ("n_layers", num(cfg.n_layers as f64)),
        ("n_heads", num(cfg.n_heads as f64)),
        ("d_ff", num(cfg.d_ff as f64)),
        ("n_experts", num(cfg.n_experts as f64)),
        ("top_k", num(cfg.top_k as f64)),
        ("max_seq", num(cfg.max_seq as f64)),
        ("prefill_tile", num(cfg.prefill_tile as f64)),
    ])
    .to_string()
}

/// Tensor-section reader over (a slice of) the payload. `base` is the
/// absolute payload offset of `payload[0]`: header metadata records
/// absolute offsets, so a reader over a fetched expert segment rebases
/// through it (full-file readers use base 0).
pub(crate) struct Reader<'a> {
    pub(crate) payload: &'a [u8],
    pub(crate) base: usize,
}

impl<'a> Reader<'a> {
    fn span(&self, off: usize, bytes: usize) -> Result<&'a [u8]> {
        let off = off
            .checked_sub(self.base)
            .ok_or_else(|| anyhow!("section offset before reader base"))?;
        let end = off + bytes;
        if end > self.payload.len() {
            bail!("section out of bounds");
        }
        Ok(&self.payload[off..end])
    }

    fn f32s(&self, off: usize, len: usize) -> Result<Vec<f32>> {
        Ok(self
            .span(off, len * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&self, off: usize, len: usize) -> Result<Vec<u32>> {
        Ok(self
            .span(off, len * 4)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(crate) fn qtensor(&self, e: &Json) -> Result<QTensor> {
        match e.get("kind")?.as_str()? {
            "f32" => {
                let rows = e.get("rows")?.as_usize()?;
                let cols = e.get("cols")?.as_usize()?;
                let data = self.f32s(e.get("off")?.as_usize()?, rows * cols)?;
                Ok(QTensor::F32(Mat::from_vec(rows, cols, data)))
            }
            "packed" => {
                let k = e.get("k")?.as_usize()?;
                let n = e.get("n")?.as_usize()?;
                let sc_len = e.get("sc_len")?.as_usize()?;
                let bits = e.get("bits")?.as_usize()?;
                // validated here, at the untrusted-input boundary, so
                // the kernels' bit-width dispatch can never see a
                // width it would have to panic on mid-request
                if !(2..=4).contains(&bits) {
                    bail!("unsupported packed bit-width {bits} \
                           (supported: 2, 3, 4)");
                }
                Ok(QTensor::Packed(PackedTensor {
                    bits,
                    k,
                    n,
                    group: e.get("group")?.as_usize()?,
                    qweight: self.u32s(e.get("qw_off")?.as_usize()?,
                                       e.get("qw_len")?.as_usize()?)?.into(),
                    scales: self.f32s(e.get("sc_off")?.as_usize()?, sc_len)?
                        .into(),
                    zeros: self.f32s(e.get("zp_off")?.as_usize()?, sc_len)?
                        .into(),
                }))
            }
            "binary" => {
                let n = e.get("n")?.as_usize()?;
                Ok(QTensor::Binary(BinaryTensor {
                    k: e.get("k")?.as_usize()?,
                    n,
                    packed: self.u32s(e.get("pk_off")?.as_usize()?,
                                      e.get("pk_len")?.as_usize()?)?.into(),
                    scales: self.f32s(e.get("sc_off")?.as_usize()?, n)?.into(),
                }))
            }
            other => bail!("unknown tensor kind {other:?}"),
        }
    }

    fn vec1(&self, e: &Json) -> Result<Vec<f32>> {
        self.f32s(e.get("off")?.as_usize()?, e.get("len")?.as_usize()?)
    }

    fn mat(&self, e: &Json) -> Result<Mat> {
        match self.qtensor(e)? {
            QTensor::F32(m) => Ok(m),
            _ => bail!("expected f32 matrix"),
        }
    }
}

/// Storage bytes a header tensor entry describes, without decoding it
/// (the store's budget / loading math needs exact `storage_bytes`
/// parity with the materialized `QTensor`).
pub(crate) fn entry_storage_bytes(e: &Json) -> Result<usize> {
    Ok(match e.get("kind")?.as_str()? {
        "f32" => e.get("rows")?.as_usize()? * e.get("cols")?.as_usize()? * 4,
        "packed" => {
            (e.get("qw_len")?.as_usize()? + 2 * e.get("sc_len")?.as_usize()?) * 4
        }
        "binary" => (e.get("pk_len")?.as_usize()? + e.get("n")?.as_usize()?) * 4,
        other => bail!("unknown tensor kind {other:?}"),
    })
}

/// Split an MCQZ byte buffer into (version, parsed header, payload
/// offset). Accepts v1 and v2 containers.
pub(crate) fn parse_container(bytes: &[u8]) -> Result<(u32, Json, usize)> {
    if bytes.len() < 12 || &bytes[0..4] != MAGIC {
        bail!("bad MCQZ magic");
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION_V1 && version != VERSION {
        bail!("unsupported MCQZ version {version}");
    }
    let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    if bytes.len() < 12 + hlen {
        bail!("truncated MCQZ header");
    }
    let header = Json::parse(std::str::from_utf8(&bytes[12..12 + hlen])?)?;
    Ok((version, header, 12 + hlen))
}

/// Materialize a model from a parsed header + payload. With
/// `with_experts = false` the layers get empty expert vecs (the model
/// head a cache-resolved deployment serves; `payload` then only needs
/// to cover the non-expert region).
pub(crate) fn build_model(header: &Json, payload: &[u8],
                          with_experts: bool) -> Result<MoeModel> {
    let cfg = ModelConfig::from_json(header.get("config")?)?;
    let tensors = header.get("tensors")?;
    let r = Reader { payload, base: 0 };

    let get = |name: &str| -> Result<&Json> { tensors.get(name) };
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let p = |m: &str| format!("layers.{i}.{m}");
        let mut experts = Vec::new();
        if with_experts {
            experts.reserve(cfg.n_experts);
            for e in 0..cfg.n_experts {
                experts.push(Expert {
                    w1: r.qtensor(get(&format!("layers.{i}.experts.{e}.w1"))?)?,
                    w3: r.qtensor(get(&format!("layers.{i}.experts.{e}.w3"))?)?,
                    w2: r.qtensor(get(&format!("layers.{i}.experts.{e}.w2"))?)?,
                });
            }
        }
        layers.push(Layer {
            attn_norm: r.vec1(get(&p("attn_norm"))?)?,
            ffn_norm: r.vec1(get(&p("ffn_norm"))?)?,
            gate: r.mat(get(&p("gate"))?)?,
            wq: r.qtensor(get(&p("attn.wq"))?)?,
            wk: r.qtensor(get(&p("attn.wk"))?)?,
            wv: r.qtensor(get(&p("attn.wv"))?)?,
            wo: r.qtensor(get(&p("attn.wo"))?)?,
            experts,
        });
    }
    Ok(MoeModel {
        cfg,
        tok_emb: r.mat(get("tok_emb")?)?,
        pos_emb: r.mat(get("pos_emb")?)?,
        final_norm: r.vec1(get("final_norm")?)?,
        lm_head: r.mat(get("lm_head")?)?,
        layers,
        resolver: crate::offload::resident(),
    })
}

/// Verify every expert segment of a v2 header against its recorded
/// crc32. Directory rows written before checksums existed carry no
/// `crc` key and are skipped — re-saving such a file backfills them.
pub(crate) fn verify_expert_dir(header: &Json, payload: &[u8]) -> Result<()> {
    let Some(dir) = header.opt("expert_dir") else { return Ok(()) };
    for (l, row) in dir.as_arr()?.iter().enumerate() {
        for (e, seg) in row.as_arr()?.iter().enumerate() {
            let Some(want) = seg.opt("crc") else { continue };
            let want = want.as_usize()? as u32;
            let off = seg.get("off")?.as_usize()?;
            let len = seg.get("len")?.as_usize()?;
            if off.checked_add(len).map_or(true, |end| end > payload.len()) {
                bail!("expert segment out of bounds \
                       (layer {l}, expert {e})");
            }
            let got = crc32(&payload[off..off + len]);
            if got != want {
                bail!("expert segment checksum mismatch (layer {l}, \
                       expert {e}): crc32 {got:#010x} != {want:#010x}");
            }
        }
    }
    Ok(())
}

/// Load an MCQZ compressed model, fully materialized (v1 or v2). For
/// byte-budgeted serving of a v2 file see `offload::load_cached`.
pub fn load(path: &Path) -> Result<MoeModel> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let (version, header, payload_off) = parse_container(&bytes)?;
    if version >= 2 {
        verify_expert_dir(&header, &bytes[payload_off..])
            .with_context(|| format!("verifying {path:?}"))?;
    }
    build_model(&header, &bytes[payload_off..], true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::model::tests::random_model;
    use crate::quant::quantize_rtn;

    fn mixed_model() -> MoeModel {
        let cfg = ModelConfig::test_tiny();
        let mut m = random_model(&cfg, 0);
        // mix representations: expert 0 -> 2-bit, 1 -> 3-bit, 2 -> 1-bit
        for layer in m.layers.iter_mut() {
            for (e, bits) in [(0usize, 2usize), (1, 3), (2, 1)] {
                let ex = &mut layer.experts[e];
                ex.w1 = quantize_rtn(&ex.w1.dequantize(), bits);
                ex.w3 = quantize_rtn(&ex.w3.dequantize(), bits);
                ex.w2 = quantize_rtn(&ex.w2.dequantize(), bits);
            }
            layer.wq = quantize_rtn(&layer.wq.dequantize(), 4);
        }
        m
    }

    #[test]
    fn roundtrip_preserves_outputs_exactly() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_roundtrip.mcqz");
        save(&path, &m).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.cfg, m.cfg);
        assert_eq!(loaded.storage_bytes(), m.storage_bytes());
        let toks: Vec<u32> = (1..25).collect();
        let a = m.score(&toks);
        let b = loaded.score(&toks);
        assert_eq!(a.data, b.data, "bit-exact reload required");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_reload_is_bit_exact() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_v1.mcqz");
        save_v1(&path, &m).unwrap();
        let loaded = load(&path).unwrap();
        let toks: Vec<u32> = (1..17).collect();
        assert_eq!(m.score(&toks).data, loaded.score(&toks).data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_header_has_expert_directory() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_dir.mcqz");
        save(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let (version, header, payload_off) = parse_container(&bytes).unwrap();
        assert_eq!(version, VERSION);
        let experts_off = header.get("experts_off").unwrap().as_usize().unwrap();
        let dir = header.get("expert_dir").unwrap().as_arr().unwrap();
        assert_eq!(dir.len(), m.cfg.n_layers);
        let payload_len = bytes.len() - payload_off;
        let mut prev_end = experts_off;
        for row in dir {
            let row = row.as_arr().unwrap();
            assert_eq!(row.len(), m.cfg.n_experts);
            for seg in row {
                let off = seg.get("off").unwrap().as_usize().unwrap();
                let len = seg.get("len").unwrap().as_usize().unwrap();
                // segments are disjoint, ordered, and inside the payload
                assert!(off >= prev_end, "segment overlaps predecessor");
                assert!(off + len <= payload_len);
                prev_end = off + len;
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn entry_bytes_match_materialized_storage() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_bytes.mcqz");
        save(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let (_, header, _) = parse_container(&bytes).unwrap();
        let tensors = header.get("tensors").unwrap();
        for (l, layer) in m.layers.iter().enumerate() {
            for (e, ex) in layer.experts.iter().enumerate() {
                for (w, t) in [("w1", &ex.w1), ("w3", &ex.w3), ("w2", &ex.w2)] {
                    let meta = tensors
                        .get(&format!("layers.{l}.experts.{e}.{w}"))
                        .unwrap();
                    assert_eq!(entry_storage_bytes(meta).unwrap(),
                               t.storage_bytes());
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_smaller_than_f32_model() {
        let m = mixed_model();
        let fp = random_model(&ModelConfig::test_tiny(), 0);
        let p1 = std::env::temp_dir().join("mcqz_mixed.mcqz");
        let p2 = std::env::temp_dir().join("mcqz_fp.mcqz");
        save(&p1, &m).unwrap();
        save(&p2, &fp).unwrap();
        let s1 = std::fs::metadata(&p1).unwrap().len();
        let s2 = std::fs::metadata(&p2).unwrap().len();
        assert!(s1 < s2, "{s1} !< {s2}");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn rejects_corrupt() {
        assert!(load(Path::new("/nonexistent.mcqz")).is_err());
        let path = std::env::temp_dir().join("mcqz_bad.mcqz");
        std::fs::write(&path, b"NOPE0000000000").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// First expert segment of a saved v2 file:
    /// (absolute file offset of segment start, segment length).
    fn first_segment(bytes: &[u8]) -> (usize, usize) {
        let (_, header, payload_off) = parse_container(bytes).unwrap();
        let seg = &header.get("expert_dir").unwrap().as_arr().unwrap()[0]
            .as_arr().unwrap()[0];
        (payload_off + seg.get("off").unwrap().as_usize().unwrap(),
         seg.get("len").unwrap().as_usize().unwrap())
    }

    #[test]
    fn truncated_header_is_err_not_panic() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_trunc_hdr.mcqz");
        save(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // cut inside the fixed 12-byte prelude and inside the JSON
        // header: both must be typed errors
        for cut in [3usize, 8, 20] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = load(&path).expect_err("truncated header");
            assert!(!format!("{err:#}").is_empty());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_expert_segment_is_err_not_panic() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_trunc_seg.mcqz");
        save(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let (seg_at, _) = first_segment(&bytes);
        // keep the header + non-expert region, lose the expert bytes
        std::fs::write(&path, &bytes[..seg_at + 16]).unwrap();
        let err = load(&path).expect_err("truncated segment");
        let msg = format!("{err:#}");
        assert!(msg.contains("out of bounds"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checksum_mismatch_is_err_not_panic() {
        let m = mixed_model();
        let path = std::env::temp_dir().join("mcqz_crc_flip.mcqz");
        save(&path, &m).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let (seg_at, seg_len) = first_segment(&bytes);
        bytes[seg_at + seg_len / 2] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).expect_err("flipped bit");
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_resave_backfills_checksums() {
        let m = mixed_model();
        let p1 = std::env::temp_dir().join("mcqz_migrate_v1.mcqz");
        let p2 = std::env::temp_dir().join("mcqz_migrate_v2.mcqz");
        save_v1(&p1, &m).unwrap();
        // v1 has no directory, hence nothing to verify
        let migrated = load(&p1).unwrap();
        save(&p2, &migrated).unwrap();
        let bytes = std::fs::read(&p2).unwrap();
        let (version, header, payload_off) = parse_container(&bytes).unwrap();
        assert_eq!(version, VERSION);
        for row in header.get("expert_dir").unwrap().as_arr().unwrap() {
            for seg in row.as_arr().unwrap() {
                assert!(seg.opt("crc").is_some(),
                        "migrated segment missing checksum");
            }
        }
        // and the backfilled checksums verify against the payload
        verify_expert_dir(&header, &bytes[payload_off..]).unwrap();
        // migration is lossless
        let toks: Vec<u32> = (1..17).collect();
        assert_eq!(m.score(&toks).data, load(&p2).unwrap().score(&toks).data);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }
}
