//! MCWT weight-file reader (format spec: python/compile/mcwt.py).
//!
//! Little-endian: magic "MCWT", u32 version, u32 header length, JSON
//! header {tensors: {name: {dtype, shape, offset, nbytes}}}, then raw
//! f32 payload 64-byte aligned per tensor.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Mat;
use crate::util::alloc::AVec;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    /// 64-byte aligned so [`Tensor::into_mat`] moves straight into a
    /// kernel-ready `Mat` backing buffer without a copy.
    pub data: AVec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// View as a 2-D matrix (errors on other ranks). Clones the
    /// payload — model loading uses the consuming [`Tensor::into_mat`]
    /// so load-time peak memory stays at one copy.
    pub fn as_mat(&self) -> Result<Mat> {
        if self.shape.len() != 2 {
            bail!("tensor rank {} != 2", self.shape.len());
        }
        Ok(Mat::from_vec(self.shape[0], self.shape[1], self.data.clone()))
    }

    pub fn as_vec1(&self) -> Result<Vec<f32>> {
        if self.shape.len() != 1 {
            bail!("tensor rank {} != 1", self.shape.len());
        }
        Ok(self.data.to_vec())
    }

    /// Consume into a 2-D matrix without copying the payload.
    pub fn into_mat(self) -> Result<Mat> {
        if self.shape.len() != 2 {
            bail!("tensor rank {} != 2", self.shape.len());
        }
        Ok(Mat::from_vec(self.shape[0], self.shape[1], self.data))
    }

    /// Consume into a 1-D vector (one copy out of the aligned buffer;
    /// only the small norm/gain vectors take this path).
    pub fn into_vec1(self) -> Result<Vec<f32>> {
        if self.shape.len() != 1 {
            bail!("tensor rank {} != 1", self.shape.len());
        }
        Ok(self.data.to_vec())
    }
}

/// Bulk little-endian f32 decode: one memcpy on LE hosts, a per-value
/// conversion loop only on BE.
fn f32s_from_le(bytes: &[u8]) -> AVec<f32> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let mut data: AVec<f32> = AVec::zeroed(bytes.len() / 4);
    if cfg!(target_endian = "little") {
        // Safety: f32 and [u8; 4] have identical size; any bit
        // pattern is a valid f32.
        let out = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr().cast::<u8>(),
                                           bytes.len())
        };
        out.copy_from_slice(bytes);
    } else {
        for (v, c) in data.iter_mut().zip(bytes.chunks_exact(4)) {
            *v = f32::from_le_bytes(c.try_into().unwrap());
        }
    }
    data
}

/// Bulk little-endian f32 encode into `out` (one memcpy on LE hosts).
fn f32s_to_le(vals: &[f32], out: &mut [u8]) {
    debug_assert_eq!(out.len(), vals.len() * 4);
    if cfg!(target_endian = "little") {
        // Safety: plain-old-data reinterpret, sizes checked above.
        let bytes = unsafe {
            std::slice::from_raw_parts(vals.as_ptr().cast::<u8>(),
                                       out.len())
        };
        out.copy_from_slice(bytes);
    } else {
        for (c, &v) in out.chunks_exact_mut(4).zip(vals) {
            c.copy_from_slice(&v.to_le_bytes());
        }
    }
}

#[derive(Debug)]
pub struct WeightFile {
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightFile {
    pub fn load(path: &Path) -> Result<WeightFile> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightFile> {
        if bytes.len() < 12 || &bytes[0..4] != b"MCWT" {
            bail!("bad MCWT magic");
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != 1 {
            bail!("unsupported MCWT version {version}");
        }
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        if bytes.len() < 12 + hlen {
            bail!("truncated MCWT header");
        }
        let header = Json::parse(std::str::from_utf8(&bytes[12..12 + hlen])?)?;
        let base = 12 + hlen;
        let mut tensors = BTreeMap::new();
        for (name, meta) in header.get("tensors")?.as_obj()? {
            let dtype = meta.get("dtype")?.as_str()?;
            if dtype != "f32" {
                bail!("tensor {name}: unsupported dtype {dtype}");
            }
            let shape: Vec<usize> = meta
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?;
            let offset = meta.get("offset")?.as_usize()?;
            let nbytes = meta.get("nbytes")?.as_usize()?;
            let numel: usize = shape.iter().product();
            if numel * 4 != nbytes {
                bail!("tensor {name}: shape/nbytes mismatch");
            }
            let start = base + offset;
            if bytes.len() < start + nbytes {
                bail!("tensor {name}: payload out of bounds");
            }
            let data = f32s_from_le(&bytes[start..start + nbytes]);
            debug_assert_eq!(data.len(), numel);
            tensors.insert(name.clone(), Tensor { shape, data });
        }
        Ok(WeightFile { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn mat(&self, name: &str) -> Result<Mat> {
        self.get(name)?.as_mat().with_context(|| name.to_string())
    }

    pub fn vec1(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.as_vec1().with_context(|| name.to_string())
    }

    /// Remove a tensor from the file (consuming access for loaders).
    pub fn take(&mut self, name: &str) -> Result<Tensor> {
        self.tensors
            .remove(name)
            .with_context(|| format!("missing tensor {name:?}"))
    }

    /// Move a tensor out as a matrix — no payload copy.
    pub fn take_mat(&mut self, name: &str) -> Result<Mat> {
        self.take(name)?.into_mat().with_context(|| name.to_string())
    }

    /// Move a tensor out as a vector — no payload copy.
    pub fn take_vec1(&mut self, name: &str) -> Result<Vec<f32>> {
        self.take(name)?.into_vec1().with_context(|| name.to_string())
    }
}

/// Write an MCWT file (used by tests and the quantized-model cache).
pub fn write_mcwt(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    use crate::util::json::{num, obj, Json};
    const ALIGN: usize = 64;
    let mut entries = BTreeMap::new();
    let mut offset = 0usize;
    let mut spans = Vec::new();
    for (name, t) in tensors {
        offset += (ALIGN - offset % ALIGN) % ALIGN;
        let nbytes = t.numel() * 4;
        entries.insert(
            name.clone(),
            obj(vec![
                ("dtype", Json::Str("f32".into())),
                (
                    "shape",
                    Json::Arr(t.shape.iter().map(|&s| num(s as f64)).collect()),
                ),
                ("offset", num(offset as f64)),
                ("nbytes", num(nbytes as f64)),
            ]),
        );
        spans.push((offset, t));
        offset += nbytes;
    }
    let header = Json::Obj(
        [("tensors".to_string(), Json::Obj(entries))].into_iter().collect(),
    )
    .to_string();
    let mut out = Vec::new();
    out.extend_from_slice(b"MCWT");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    let base = out.len();
    out.resize(base + offset, 0);
    for (off, t) in spans {
        // one bulk little-endian write per tensor
        let pos = base + off;
        f32s_to_le(&t.data, &mut out[pos..pos + t.numel() * 4]);
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(
            "a".to_string(),
            Tensor {
                shape: vec![2, 3],
                data: vec![1., 2., 3., 4., 5., 6.].into(),
            },
        );
        m.insert(
            "b.vec".to_string(),
            Tensor { shape: vec![4], data: vec![0.5, -0.5, 1.5, -1.5].into() },
        );
        m
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("mcwt_test_roundtrip.mcwt");
        write_mcwt(&dir, &sample()).unwrap();
        let wf = WeightFile::load(&dir).unwrap();
        assert_eq!(wf.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(wf.get("a").unwrap().data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(wf.vec1("b.vec").unwrap(), vec![0.5, -0.5, 1.5, -1.5]);
        let m = wf.mat("a").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(WeightFile::parse(b"XXXX\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
        assert!(WeightFile::parse(b"MC").is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let dir = std::env::temp_dir().join("mcwt_test_trunc.mcwt");
        write_mcwt(&dir, &sample()).unwrap();
        let mut bytes = std::fs::read(&dir).unwrap();
        bytes.truncate(bytes.len() - 8);
        assert!(WeightFile::parse(&bytes).is_err());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn rank_guards() {
        let t = Tensor { shape: vec![2, 3], data: vec![0.0; 6].into() };
        assert!(t.as_vec1().is_err());
        assert!(t.as_mat().is_ok());
        let v = Tensor { shape: vec![6], data: vec![0.0; 6].into() };
        assert!(v.as_mat().is_err());
        let t = Tensor { shape: vec![2, 3], data: vec![0.0; 6].into() };
        assert!(t.into_vec1().is_err());
        let v = Tensor { shape: vec![6], data: vec![0.0; 6].into() };
        assert!(v.into_mat().is_err());
    }

    #[test]
    fn take_moves_payload_without_copy() {
        let dir = std::env::temp_dir().join("mcwt_test_take.mcwt");
        write_mcwt(&dir, &sample()).unwrap();
        let mut wf = WeightFile::load(&dir).unwrap();
        let src_ptr = wf.get("a").unwrap().data.as_ptr();
        let m = wf.take_mat("a").unwrap();
        assert_eq!(m.data.as_ptr(), src_ptr, "into_mat must move, not clone");
        assert_eq!(m.data, vec![1., 2., 3., 4., 5., 6.]);
        assert!(wf.get("a").is_err(), "taken tensor leaves the file");
        assert_eq!(wf.take_vec1("b.vec").unwrap(), vec![0.5, -0.5, 1.5, -1.5]);
        std::fs::remove_file(&dir).ok();
    }
}
