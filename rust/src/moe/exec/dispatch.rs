//! Expert dispatch: gather each expert's routed tokens into one batch,
//! run every active expert's SwiGLU FFN (optionally in parallel), and
//! scatter the weighted outputs back to token order.
//!
//! Parallel execution runs on the persistent `util::pool::WorkerPool`
//! (DESIGN.md §4): each active expert's batch is one pool task owning
//! its `&mut ExpertBatch`, so pooled results are bit-exact with serial
//! execution. The pre-pool behavior — one `std::thread::scope` spawn
//! per expert per call — is kept as `DispatchMode::SpawnScope`, the
//! baseline `benches/hotpath.rs` measures the pool against.
//!
//! [`DispatchScratch`] keeps the per-expert gather/`gated`/`y` buffers
//! alive across calls (keyed by expert index), so the steady-state
//! decode loop gathers and executes without heap allocation.

use std::sync::Arc;

use crate::moe::model::Expert;
use crate::tensor::{axpy, Mat};
use crate::util::pool::{SendPtr, WorkerPool};

/// The expert weights one dispatch call executes against — either a
/// borrowed resident slice (`Layer::experts`, the zero-cost default)
/// or the pinned slots an `offload::ExpertResolver` produced for this
/// layer (index = expert id; only the routed experts are `Some`).
/// This is the one seam through which every expert access flows
/// (DESIGN.md §5).
#[derive(Clone, Copy)]
pub struct ExpertsRef<'a> {
    owned: &'a [Expert],
    pinned: &'a [Option<Arc<Expert>>],
}

impl<'a> ExpertsRef<'a> {
    pub fn resident(experts: &'a [Expert]) -> ExpertsRef<'a> {
        ExpertsRef { owned: experts, pinned: &[] }
    }

    pub fn pinned(slots: &'a [Option<Arc<Expert>>]) -> ExpertsRef<'a> {
        ExpertsRef { owned: &[], pinned: slots }
    }

    /// Number of expert slots (resident and pinned views both cover
    /// the full expert-id space of the layer).
    pub fn len(&self) -> usize {
        self.owned.len().max(self.pinned.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The expert at id `e`; panics if it was neither resident nor
    /// pinned (dispatch only executes experts with routed rows, which
    /// the resolver pinned by contract).
    pub fn get(&self, e: usize) -> &Expert {
        self.try_get(e)
            .unwrap_or_else(|| panic!("expert {e} neither resident nor pinned"))
    }

    pub fn try_get(&self, e: usize) -> Option<&Expert> {
        if self.pinned.is_empty() {
            self.owned.get(e)
        } else {
            self.pinned.get(e).and_then(|s| s.as_deref())
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    Serial,
    /// Force pool-parallel execution (benchmarks, parity tests).
    Threaded,
    /// Pool-parallel only when the expert work dwarfs region overhead
    /// (and the pool has width); single-token decode stays serial.
    Auto,
    /// Legacy baseline: spawn one scoped OS thread per active expert
    /// per call. Kept only so `benches/hotpath.rs` can measure the
    /// persistent pool against what it replaced.
    SpawnScope,
}

/// Minimum expert-FFN FLOP volume (~2 ms of scalar work) before Auto
/// goes parallel; below this, region overhead dominates.
const AUTO_THREAD_MIN_FLOPS: u64 = 8_000_000;

/// One expert's gathered batch: the rows it serves, its inputs, the
/// gated hidden (kept for `CalibSink::expert_batch`), and its output.
/// `tmp`/`qs` are kernel scratch reused across calls.
pub struct ExpertBatch {
    pub expert: usize,
    /// (token row in `h`, renormalized routing weight)
    pub rows: Vec<(usize, f32)>,
    pub x: Mat,
    pub gated: Mat,
    pub y: Mat,
    pub(crate) tmp: Mat,
    pub(crate) qs: crate::quant::QmScratch,
}

impl ExpertBatch {
    fn empty(expert: usize) -> ExpertBatch {
        ExpertBatch {
            expert,
            rows: Vec::new(),
            x: Mat::zeros(0, 0),
            gated: Mat::zeros(0, 0),
            y: Mat::zeros(0, 0),
            tmp: Mat::zeros(0, 0),
            qs: crate::quant::QmScratch::new(),
        }
    }
}

/// Persistent per-expert batches (indexed by expert) plus the list of
/// experts active in the current call. Owned by whoever drives a
/// decode loop (`SessionScratch`, `StepScratch`) or created ad hoc by
/// the allocating [`dispatch_experts`] wrapper.
pub struct DispatchScratch {
    batches: Vec<ExpertBatch>,
    active: Vec<usize>,
    /// Worst-case pre-reservation only pays off when the scratch is
    /// reused across calls (the zero-alloc decode arenas); the
    /// allocating wrapper's one-shot scratch skips it and lets each
    /// active batch size itself from the rows actually routed.
    reserve_worst_case: bool,
}

impl Default for DispatchScratch {
    fn default() -> DispatchScratch {
        DispatchScratch::new()
    }
}

impl DispatchScratch {
    pub fn new() -> DispatchScratch {
        DispatchScratch {
            batches: Vec::new(),
            active: Vec::new(),
            reserve_worst_case: true,
        }
    }

    fn one_shot() -> DispatchScratch {
        DispatchScratch { reserve_worst_case: false, ..DispatchScratch::new() }
    }

    /// Batches of the experts active in the last dispatch, ascending
    /// expert order.
    pub fn active_batches(&self) -> impl Iterator<Item = &ExpertBatch> {
        self.active.iter().map(|&e| &self.batches[e])
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Gather-buffer pointer of expert `e` (stability assertions in
    /// the zero-alloc tests).
    pub fn probe_x_ptr(&self, e: usize) -> *const f32 {
        self.batches[e].x.data.as_ptr()
    }
}

fn reserve_mat(m: &mut Mat, rows: usize, cols: usize) {
    let cap = rows * cols;
    if m.data.capacity() < cap {
        m.data.reserve(cap - m.data.len());
    }
}

fn run_one(b: &mut ExpertBatch, experts: ExpertsRef<'_>,
           override_expert: Option<(usize, &Expert)>) {
    let ex = match override_expert {
        Some((oe, repl)) if oe == b.expert => repl,
        _ => experts.get(b.expert),
    };
    ex.gated_hidden_into(&b.x, &mut b.gated, &mut b.tmp, &mut b.qs);
    ex.w2.matmul_into(&b.gated, &mut b.y, &mut b.qs);
}

/// Gather + execute into `scratch`. `topk[t]` lists `(expert, weight)`
/// selections for token row `t` of `h`; `override_expert` substitutes
/// one expert (PMQ's eps_{i,j} probe). Active batches are available
/// via `scratch.active_batches()` in ascending expert order — combine
/// them with [`scatter_into`], and feed `CalibSink::expert_batch` from
/// `x`/`gated` (execution order never affects the Hessian sums, so
/// calibration is thread-safe).
pub fn dispatch_experts_into(
    h: &Mat,
    topk: &[Vec<(usize, f32)>],
    experts: ExpertsRef<'_>,
    override_expert: Option<(usize, &Expert)>,
    mode: DispatchMode,
    scratch: &mut DispatchScratch,
) {
    let d = h.cols;
    while scratch.batches.len() < experts.len() {
        let e = scratch.batches.len();
        scratch.batches.push(ExpertBatch::empty(e));
    }
    // worst-case reservation: in a later call of this batch shape,
    // every routed row could land on any one expert — reserving that
    // up front (a capacity check per call thereafter) is what makes
    // the steady-state loop allocation-free even when routing shifts
    // load between experts (tests/zero_alloc.rs). One-shot scratches
    // skip it: active batches size themselves from actual routing.
    // Cache-resolved layers only expose this call's pinned experts,
    // so unpinned slots are skipped (their batches carry no rows).
    if scratch.reserve_worst_case {
        let worst = topk.len();
        for (e, b) in
            scratch.batches.iter_mut().enumerate().take(experts.len())
        {
            let Some(ex) = experts.try_get(e) else { continue };
            let (_, d_ff) = ex.w1.shape();
            reserve_mat(&mut b.x, worst, d);
            reserve_mat(&mut b.gated, worst, d_ff);
            reserve_mat(&mut b.tmp, worst, d_ff);
            reserve_mat(&mut b.y, worst, d);
            if b.rows.capacity() < worst {
                b.rows.reserve(worst - b.rows.len());
            }
            b.qs.reserve(d.max(d_ff), worst);
        }
    }
    for b in scratch.batches.iter_mut() {
        b.rows.clear();
    }
    for (t, sel) in topk.iter().enumerate() {
        for &(e, w) in sel {
            scratch.batches[e].rows.push((t, w));
        }
    }
    // gather + the Auto FLOP gate, computed from the batches actually
    // routed (not `experts.first()`, which is wrong for heterogeneous
    // bit-widths and empty expert lists)
    scratch.active.clear();
    let mut flops = 0u64;
    for (e, b) in scratch.batches.iter_mut().enumerate() {
        if b.rows.is_empty() {
            continue;
        }
        scratch.active.push(e);
        let ex = match override_expert {
            Some((oe, repl)) if oe == e => repl,
            _ => experts.get(e),
        };
        let (_, d_ff) = ex.w1.shape();
        flops += b.rows.len() as u64 * 6 * d as u64 * d_ff as u64;
        b.x.resize_to(b.rows.len(), d);
        for (ri, &(t, _)) in b.rows.iter().enumerate() {
            b.x.row_mut(ri).copy_from_slice(h.row(t));
        }
    }

    let nactive = scratch.active.len();
    let pool = WorkerPool::global();
    // the pool-width check lives here, once, for every mode
    let parallel = nactive >= 2
        && pool.width() > 1
        && match mode {
            DispatchMode::Serial | DispatchMode::SpawnScope => false,
            DispatchMode::Threaded => true,
            DispatchMode::Auto => flops >= AUTO_THREAD_MIN_FLOPS,
        };

    if mode == DispatchMode::SpawnScope && nactive >= 2 {
        std::thread::scope(|s| {
            for b in scratch.batches.iter_mut().filter(|b| !b.rows.is_empty()) {
                // the legacy baseline must reproduce pre-pool behavior:
                // expert kernels stay inline on their spawned thread
                s.spawn(move || {
                    WorkerPool::run_inline(|| {
                        run_one(b, experts, override_expert)
                    })
                });
            }
        });
    } else if parallel {
        let bptr = SendPtr(scratch.batches.as_mut_ptr());
        let active = &scratch.active;
        pool.for_each(nactive, move |ai| {
            // Safety: active indices are unique, so each task holds
            // the only &mut to its batch for the region's duration.
            let b = unsafe { &mut *bptr.0.add(active[ai]) };
            run_one(b, experts, override_expert);
        });
    } else if matches!(mode, DispatchMode::Serial | DispatchMode::SpawnScope) {
        // Serial promises in-thread execution (DESIGN.md §4): suppress
        // the kernels' auto-parallel heuristics for its duration
        WorkerPool::run_inline(|| {
            for &e in &scratch.active {
                run_one(&mut scratch.batches[e], experts, override_expert);
            }
        });
    } else {
        // Auto below its gate / pool without width: in-thread here,
        // but individual large kernels may still strip across the pool
        for &e in &scratch.active {
            run_one(&mut scratch.batches[e], experts, override_expert);
        }
    }
}

/// Allocating wrapper over [`dispatch_experts_into`]: returns the
/// active batches in ascending expert order (scoring forward,
/// calibration, tests — paths outside the zero-alloc decode loop).
pub fn dispatch_experts(
    h: &Mat,
    topk: &[Vec<(usize, f32)>],
    experts: ExpertsRef<'_>,
    override_expert: Option<(usize, &Expert)>,
    mode: DispatchMode,
) -> Vec<ExpertBatch> {
    let mut scratch = DispatchScratch::one_shot();
    dispatch_experts_into(h, topk, experts, override_expert, mode, &mut scratch);
    let mut out = Vec::with_capacity(scratch.active.len());
    for &e in &scratch.active {
        out.push(std::mem::replace(&mut scratch.batches[e],
                                   ExpertBatch::empty(e)));
    }
    out
}

/// Scatter expert outputs back to token order: y[t] += w * y_e[row].
pub fn scatter(batches: &[ExpertBatch], t_rows: usize, d: usize) -> Mat {
    let mut y = Mat::zeros(t_rows, d);
    scatter_batches(batches.iter(), d, &mut y);
    y
}

/// Scatter into a reused buffer (resized + overwritten). Iterates
/// active batches in ascending expert order — the same per-token
/// accumulation order as serial dispatch, so results never depend on
/// execution interleaving.
pub fn scatter_into(scratch: &DispatchScratch, t_rows: usize, d: usize,
                    y: &mut Mat) {
    y.resize_to(t_rows, d);
    y.data.fill(0.0);
    scatter_batches(scratch.active_batches(), d, y);
}

fn scatter_batches<'a>(batches: impl Iterator<Item = &'a ExpertBatch>,
                       d: usize, y: &mut Mat) {
    for b in batches {
        for (ri, &(t, w)) in b.rows.iter().enumerate() {
            axpy(&mut y.data[t * d..(t + 1) * d], b.y.row(ri), w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QTensor;
    use crate::util::rng::Rng;

    fn experts(rng: &mut Rng, n: usize, d: usize, d_ff: usize) -> Vec<Expert> {
        (0..n)
            .map(|_| Expert {
                w1: QTensor::F32(Mat::randn(rng, d, d_ff, 0.1)),
                w3: QTensor::F32(Mat::randn(rng, d, d_ff, 0.1)),
                w2: QTensor::F32(Mat::randn(rng, d_ff, d, 0.1)),
            })
            .collect()
    }

    fn round_robin_topk(rows: usize, n_experts: usize, k: usize)
                        -> Vec<Vec<(usize, f32)>> {
        (0..rows)
            .map(|t| {
                (0..k).map(|j| ((t + j) % n_experts, 1.0 / k as f32)).collect()
            })
            .collect()
    }

    #[test]
    fn threaded_matches_serial_exactly() {
        let mut rng = Rng::new(0);
        let (rows, d, d_ff, ne) = (24, 8, 16, 4);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk = round_robin_topk(rows, ne, 2);
        let bs = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), None, DispatchMode::Serial);
        let ys = scatter(&bs, rows, d);
        for mode in [DispatchMode::Threaded, DispatchMode::SpawnScope] {
            let bt = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), None, mode);
            let yt = scatter(&bt, rows, d);
            assert_eq!(ys.data, yt.data,
                       "{mode:?} dispatch must be bit-exact");
        }
    }

    #[test]
    fn scratch_reuse_is_pointer_stable() {
        let mut rng = Rng::new(4);
        let (rows, d, d_ff, ne) = (12, 8, 16, 4);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk = round_robin_topk(rows, ne, 2);
        let mut scratch = DispatchScratch::new();
        let mut y = Mat::zeros(0, 0);
        dispatch_experts_into(&h, &topk, ExpertsRef::resident(&exps), None, DispatchMode::Serial,
                              &mut scratch);
        scatter_into(&scratch, rows, d, &mut y);
        let first = y.clone();
        let ptrs: Vec<*const f32> =
            (0..ne).map(|e| scratch.probe_x_ptr(e)).collect();
        let yp = y.data.as_ptr();
        for _ in 0..3 {
            dispatch_experts_into(&h, &topk, ExpertsRef::resident(&exps), None,
                                  DispatchMode::Serial, &mut scratch);
            scatter_into(&scratch, rows, d, &mut y);
        }
        for (e, &p) in ptrs.iter().enumerate() {
            assert_eq!(scratch.probe_x_ptr(e), p,
                       "expert {e} gather buffer must not realloc");
        }
        assert_eq!(y.data.as_ptr(), yp);
        assert_eq!(y.data, first.data);
    }

    #[test]
    fn scatter_applies_routing_weights() {
        let mut rng = Rng::new(1);
        let (rows, d, d_ff, ne) = (6, 8, 16, 2);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        // every token routed to expert 0 with weight 0.5
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(0usize, 0.5f32)]).collect();
        let b = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), None, DispatchMode::Serial);
        assert_eq!(b.len(), 1);
        let y = scatter(&b, rows, d);
        let full = exps[0].forward(&h);
        for (a, f) in y.data.iter().zip(&full.data) {
            assert!((a - 0.5 * f).abs() < 1e-5);
        }
    }

    #[test]
    fn override_expert_substitutes() {
        let mut rng = Rng::new(2);
        let (rows, d, d_ff, ne) = (5, 8, 16, 2);
        let exps = experts(&mut rng, ne, d, d_ff);
        let repl_v = experts(&mut rng, 1, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(1usize, 1.0f32)]).collect();
        let base = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), None, DispatchMode::Serial);
        let swap = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), Some((1, &repl_v[0])),
                                    DispatchMode::Serial);
        let yb = scatter(&base, rows, d);
        let ys = scatter(&swap, rows, d);
        assert!(yb.sub(&ys).fro_norm() > 1e-3);
    }

    #[test]
    fn empty_experts_skipped() {
        let mut rng = Rng::new(3);
        let (rows, d, d_ff, ne) = (4, 8, 16, 4);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(2usize, 1.0f32)]).collect();
        let b = dispatch_experts(&h, &topk, ExpertsRef::resident(&exps), None, DispatchMode::Auto);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].expert, 2);
        assert_eq!(b[0].rows.len(), rows);
    }

    #[test]
    fn auto_gate_handles_empty_expert_list() {
        // no experts, no routing: must not panic on experts.first()
        let h = Mat::zeros(2, 8);
        let topk: Vec<Vec<(usize, f32)>> = vec![Vec::new(); 2];
        let b = dispatch_experts(&h, &topk, ExpertsRef::resident(&[]), None, DispatchMode::Auto);
        assert!(b.is_empty());
    }
}
