//! Expert dispatch: gather each expert's routed tokens into one batch,
//! run every active expert's SwiGLU FFN (optionally in parallel), and
//! scatter the weighted outputs back to token order.
//!
//! Threading uses `std::thread::scope` — the crate is deliberately
//! dependency-free (no rayon), and per-layer expert FFNs are the one
//! place with enough coarse-grained, disjoint work to pay for thread
//! spawns (DESIGN.md §4; measured in `benches/hotpath.rs`, recorded in
//! BENCH_dispatch.json).

use crate::moe::model::Expert;
use crate::tensor::Mat;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    Serial,
    Threaded,
    /// Thread only when the expert work dwarfs spawn cost (and the
    /// host has more than one core); single-token decode stays serial.
    Auto,
}

/// Minimum expert-FFN FLOP volume (~2 ms of scalar work) before Auto
/// switches to threads; below this, spawn overhead dominates.
const AUTO_THREAD_MIN_FLOPS: u64 = 8_000_000;

/// One expert's gathered batch: the rows it serves, its inputs, the
/// gated hidden (kept for `CalibSink::expert_batch`), and its output.
pub struct ExpertBatch {
    pub expert: usize,
    /// (token row in `h`, renormalized routing weight)
    pub rows: Vec<(usize, f32)>,
    pub x: Mat,
    pub gated: Mat,
    pub y: Mat,
}

fn run_one(b: &mut ExpertBatch, experts: &[Expert],
           override_expert: Option<(usize, &Expert)>) {
    let ex = match override_expert {
        Some((oe, repl)) if oe == b.expert => repl,
        _ => &experts[b.expert],
    };
    b.gated = ex.gated_hidden(&b.x);
    b.y = ex.w2.matmul(&b.gated);
}

/// Gather + execute. `topk[t]` lists `(expert, weight)` selections for
/// token row `t` of `h`; `override_expert` substitutes one expert
/// (PMQ's eps_{i,j} probe). Returns per-expert batches in ascending
/// expert order — combine them with [`scatter`], and feed
/// `CalibSink::expert_batch` from `x`/`gated` (execution order never
/// affects the Hessian sums, so calibration is thread-safe).
pub fn dispatch_experts(
    h: &Mat,
    topk: &[Vec<(usize, f32)>],
    experts: &[Expert],
    override_expert: Option<(usize, &Expert)>,
    mode: DispatchMode,
) -> Vec<ExpertBatch> {
    let d = h.cols;
    let mut per_expert: Vec<Vec<(usize, f32)>> = vec![Vec::new(); experts.len()];
    let mut routed_rows = 0usize;
    for (t, sel) in topk.iter().enumerate() {
        for &(e, w) in sel {
            per_expert[e].push((t, w));
            routed_rows += 1;
        }
    }
    let mut batches: Vec<ExpertBatch> = Vec::new();
    for (e, rows) in per_expert.into_iter().enumerate() {
        if rows.is_empty() {
            continue;
        }
        let mut x = Mat::zeros(rows.len(), d);
        for (ri, &(t, _)) in rows.iter().enumerate() {
            x.row_mut(ri).copy_from_slice(h.row(t));
        }
        batches.push(ExpertBatch {
            expert: e,
            rows,
            x,
            gated: Mat::zeros(0, 0),
            y: Mat::zeros(0, 0),
        });
    }

    let threaded = match mode {
        DispatchMode::Serial => false,
        DispatchMode::Threaded => batches.len() >= 2,
        DispatchMode::Auto => {
            let (_, d_ff) = match experts.first() {
                Some(ex) => ex.w1.shape(),
                None => (0, 0),
            };
            let flops = routed_rows as u64 * 6 * d as u64 * d_ff as u64;
            batches.len() >= 2
                && flops >= AUTO_THREAD_MIN_FLOPS
                && std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    > 1
        }
    };

    if threaded {
        std::thread::scope(|s| {
            for b in batches.iter_mut() {
                s.spawn(move || run_one(b, experts, override_expert));
            }
        });
    } else {
        for b in batches.iter_mut() {
            run_one(b, experts, override_expert);
        }
    }
    batches
}

/// Scatter expert outputs back to token order: y[t] += w * y_e[row].
pub fn scatter(batches: &[ExpertBatch], t_rows: usize, d: usize) -> Mat {
    let mut y = Mat::zeros(t_rows, d);
    for b in batches {
        for (ri, &(t, w)) in b.rows.iter().enumerate() {
            let yrow = b.y.row(ri);
            let orow = &mut y.data[t * d..(t + 1) * d];
            for (o, &v) in orow.iter_mut().zip(yrow) {
                *o += w * v;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QTensor;
    use crate::util::rng::Rng;

    fn experts(rng: &mut Rng, n: usize, d: usize, d_ff: usize) -> Vec<Expert> {
        (0..n)
            .map(|_| Expert {
                w1: QTensor::F32(Mat::randn(rng, d, d_ff, 0.1)),
                w3: QTensor::F32(Mat::randn(rng, d, d_ff, 0.1)),
                w2: QTensor::F32(Mat::randn(rng, d_ff, d, 0.1)),
            })
            .collect()
    }

    fn round_robin_topk(rows: usize, n_experts: usize, k: usize)
                        -> Vec<Vec<(usize, f32)>> {
        (0..rows)
            .map(|t| {
                (0..k).map(|j| ((t + j) % n_experts, 1.0 / k as f32)).collect()
            })
            .collect()
    }

    #[test]
    fn threaded_matches_serial_exactly() {
        let mut rng = Rng::new(0);
        let (rows, d, d_ff, ne) = (24, 8, 16, 4);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk = round_robin_topk(rows, ne, 2);
        let bs = dispatch_experts(&h, &topk, &exps, None, DispatchMode::Serial);
        let bt = dispatch_experts(&h, &topk, &exps, None, DispatchMode::Threaded);
        let ys = scatter(&bs, rows, d);
        let yt = scatter(&bt, rows, d);
        assert_eq!(ys.data, yt.data, "threaded dispatch must be bit-exact");
    }

    #[test]
    fn scatter_applies_routing_weights() {
        let mut rng = Rng::new(1);
        let (rows, d, d_ff, ne) = (6, 8, 16, 2);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        // every token routed to expert 0 with weight 0.5
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(0usize, 0.5f32)]).collect();
        let b = dispatch_experts(&h, &topk, &exps, None, DispatchMode::Serial);
        assert_eq!(b.len(), 1);
        let y = scatter(&b, rows, d);
        let full = exps[0].forward(&h);
        for (a, f) in y.data.iter().zip(&full.data) {
            assert!((a - 0.5 * f).abs() < 1e-5);
        }
    }

    #[test]
    fn override_expert_substitutes() {
        let mut rng = Rng::new(2);
        let (rows, d, d_ff, ne) = (5, 8, 16, 2);
        let exps = experts(&mut rng, ne, d, d_ff);
        let repl_v = experts(&mut rng, 1, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(1usize, 1.0f32)]).collect();
        let base = dispatch_experts(&h, &topk, &exps, None, DispatchMode::Serial);
        let swap = dispatch_experts(&h, &topk, &exps, Some((1, &repl_v[0])),
                                    DispatchMode::Serial);
        let yb = scatter(&base, rows, d);
        let ys = scatter(&swap, rows, d);
        assert!(yb.sub(&ys).fro_norm() > 1e-3);
    }

    #[test]
    fn empty_experts_skipped() {
        let mut rng = Rng::new(3);
        let (rows, d, d_ff, ne) = (4, 8, 16, 4);
        let exps = experts(&mut rng, ne, d, d_ff);
        let h = Mat::randn(&mut rng, rows, d, 1.0);
        let topk: Vec<Vec<(usize, f32)>> =
            (0..rows).map(|_| vec![(2usize, 1.0f32)]).collect();
        let b = dispatch_experts(&h, &topk, &exps, None, DispatchMode::Auto);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].expert, 2);
        assert_eq!(b[0].rows.len(), rows);
    }
}
