//! The shared layer-execution core (DESIGN.md §2): one attention /
//! router / dispatch subsystem behind every path through the model —
//! full-sequence scoring (`MoeModel::forward`), batched prefill and
//! KV-cache decode (`coordinator::DecodeSession`), and the fused
//! multi-session batcher step (`coordinator::Batcher`).
//!
//! Before this module existed the scoring and decode paths were two
//! hand-duplicated implementations of the same layer stack with
//! documented behavioral drift; now both are thin drivers over:
//!
//!   * [`attention`] — causal attention generalized over "fresh
//!     sequence" vs "KV-cache append", owning the Eq.-6 head-averaged
//!     attention map;
//!   * [`router`] — top-k selection, every `OdpPolicy` / `DecodeOdp`
//!     pruning decision, and the shared `RunStats` accounting;
//!   * [`dispatch`] — expert gather/scatter with per-expert FFN
//!     execution on the persistent `util::pool::WorkerPool`.
//!
//! Every subsystem has an `*_into` entry point that writes into
//! caller-owned scratch buffers (`AttnScratch`, `DispatchScratch`,
//! reused selection Vecs), which is how the decode hot path runs
//! allocation-free (DESIGN.md §4).

pub mod attention;
pub mod dispatch;
pub mod kvcache;
pub mod router;

pub use attention::{
    causal_attention, causal_attention_into, causal_attention_paged_into,
    eq6_importance, AttnOut, AttnScratch,
};
pub use kvcache::{
    prefix_hash, KvPage, KvView, PageData, SharedPrefix, DEFAULT_PAGE_ROWS,
};
pub use dispatch::{
    dispatch_experts, dispatch_experts_into, scatter, scatter_into,
    DispatchMode, DispatchScratch, ExpertBatch, ExpertsRef,
};
pub use router::{
    decode_select, decode_select_into, gate_probs, gate_probs_into,
    score_route, select_top_k, select_top_k_into, DecodeOdp, RunStats,
    ScoreRoute,
};
