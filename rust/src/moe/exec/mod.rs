//! The shared layer-execution core (DESIGN.md §2): one attention /
//! router / dispatch subsystem behind every path through the model —
//! full-sequence scoring (`MoeModel::forward`), batched prefill and
//! KV-cache decode (`coordinator::DecodeSession`), and the fused
//! multi-session batcher step (`coordinator::Batcher`).
//!
//! Before this module existed the scoring and decode paths were two
//! hand-duplicated implementations of the same layer stack with
//! documented behavioral drift; now both are thin drivers over:
//!
//!   * [`attention`] — causal attention generalized over "fresh
//!     sequence" vs "KV-cache append", owning the Eq.-6 head-averaged
//!     attention map;
//!   * [`router`] — top-k selection, every `OdpPolicy` / `DecodeOdp`
//!     pruning decision, and the shared `RunStats` accounting;
//!   * [`dispatch`] — expert gather/scatter with optional
//!     `std::thread::scope`-parallel per-expert FFN execution.

pub mod attention;
pub mod dispatch;
pub mod router;

pub use attention::{causal_attention, eq6_importance, AttnOut};
pub use dispatch::{dispatch_experts, scatter, DispatchMode, ExpertBatch};
pub use router::{
    decode_select, gate_probs, score_route, select_top_k, DecodeOdp, RunStats,
    ScoreRoute,
};
