//! Block-granular paged KV storage (DESIGN.md §8): a session's KV
//! cache is a list of fixed-size pages grown on demand instead of one
//! flat `[max_seq, D]` buffer reserved up front, plus an optional
//! shared read-only prefix segment (copy-on-write prompt sharing —
//! the prefix Mats are owned by an `Arc` the sessions only read, and
//! a session's own writes always land in its private pages).
//!
//! Pages store f32 by default and are bit-exact with the flat layout;
//! under memory pressure the governor down-quantizes whole pages to
//! f16 (`KvPage::quantize`) — rows are dequantized on read through
//! [`KvView::k_slice`]/[`KvView::v_slice`], trading bounded precision
//! for half the page bytes. The f32↔f16 conversion is hand-rolled
//! (round-to-nearest-even, subnormals flushed to zero): no half crate
//! in the offline image.

use std::sync::Arc;

use crate::tensor::Mat;

/// Rows per KV page. 64 keeps the whole `test_tiny` window (max_seq
/// 64) in one page, so the zero-allocation decode contract of
/// `tests/zero_alloc.rs` holds without growth inside a measured run.
pub const DEFAULT_PAGE_ROWS: usize = 64;

/// f32 -> f16 bits, round-to-nearest-even; out-of-range saturates to
/// ±inf, subnormal results flush to zero.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m;
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e16 <= 0 {
        return sign; // subnormal (or underflow): flush to zero
    }
    // round mantissa 23 -> 10 bits, ties to even
    let mant16 = mant >> 13;
    let rest = mant & 0x1fff;
    let halfway = 0x1000;
    let mut out = (sign as u32) | ((e16 as u32) << 10) | mant16;
    if rest > halfway || (rest == halfway && (mant16 & 1) == 1) {
        out += 1; // carries ripple into the exponent correctly
    }
    out as u16
}

/// f16 bits -> f32 (subnormals decode to zero, matching the encoder).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = match exp {
        0 => sign, // zero / flushed subnormal
        0x1f => sign | 0x7f80_0000 | (mant << 13),
        _ => sign | ((exp + 127 - 15) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

/// One page's payload for K or V: full precision, or down-quantized
/// to f16 by the memory governor's rung-3 action.
#[derive(Debug, Clone)]
pub enum PageData {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl PageData {
    fn bytes(&self) -> usize {
        match self {
            PageData::F32(v) => v.len() * 4,
            PageData::F16(v) => v.len() * 2,
        }
    }

    fn quantize(&mut self) -> usize {
        if let PageData::F32(v) = self {
            let saved = v.len() * 2;
            let q: Vec<u16> = v.iter().map(|&x| f32_to_f16_bits(x)).collect();
            *self = PageData::F16(q);
            saved
        } else {
            0
        }
    }
}

/// One fixed-size KV page: `page_rows` rows of K and V, row-major.
#[derive(Debug, Clone)]
pub struct KvPage {
    pub k: PageData,
    pub v: PageData,
}

impl KvPage {
    pub fn new_f32(page_rows: usize, d: usize) -> KvPage {
        KvPage {
            k: PageData::F32(vec![0.0; page_rows * d]),
            v: PageData::F32(vec![0.0; page_rows * d]),
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self.k, PageData::F16(_))
    }

    pub fn bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    /// Down-quantize both planes to f16 in place; returns bytes freed
    /// (0 when already quantized).
    pub fn quantize(&mut self) -> usize {
        self.k.quantize() + self.v.quantize()
    }

    /// Write one row (f32). The target page must still be full
    /// precision — the governor only quantizes fully-written pages,
    /// and rows are append-only, so this cannot race a quantize.
    pub fn write_row(&mut self, offset: usize, d: usize, krow: &[f32],
                     vrow: &[f32]) {
        let (PageData::F32(k), PageData::F32(v)) = (&mut self.k, &mut self.v)
        else {
            panic!("KV write into a down-quantized page");
        };
        k[offset * d..offset * d + d].copy_from_slice(krow);
        v[offset * d..offset * d + d].copy_from_slice(vrow);
    }
}

/// A read-only shared prompt prefix: the first `rows` KV rows of every
/// layer, published once and attached by any session whose prompt
/// starts with the same tokens. Sessions never write into it (their
/// rows land in private pages at positions >= `rows`), which is the
/// copy-on-write discipline — identical system prompts share one copy.
#[derive(Debug)]
pub struct SharedPrefix {
    pub tokens: Vec<u32>,
    /// per-layer [rows, D] K / V
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub rows: usize,
    /// Eq.-6 token importance of the prefix positions (absolute).
    pub importance: Vec<f32>,
}

/// Borrowed two-segment view of one layer's KV for the attention
/// kernel: optional shared prefix Mats, then the session's private
/// pages. Row `r` resolves to the prefix when `r < prefix_rows`, else
/// to page `(r - prefix_rows) / page_rows`.
pub struct KvView<'a> {
    pub prefix: Option<&'a SharedPrefix>,
    pub prefix_rows: usize,
    pub pages: &'a [KvPage],
    pub page_rows: usize,
    pub d: usize,
    pub layer: usize,
}

impl<'a> KvView<'a> {
    /// Rows addressable through this view.
    pub fn rows(&self) -> usize {
        self.prefix_rows + self.pages.len() * self.page_rows
    }

    /// `&k[r][c0..c0+hd]`, dequantizing into `buf` when the row lives
    /// in an f16 page. The returned slice borrows either the backing
    /// storage (f32: bit-exact, zero-copy) or `buf`.
    #[inline]
    pub fn k_slice<'b>(&'b self, r: usize, c0: usize, hd: usize,
                       buf: &'b mut [f32]) -> &'b [f32] {
        self.plane_slice(r, c0, hd, buf, true)
    }

    /// `&v[r][c0..c0+hd]`; see [`KvView::k_slice`].
    #[inline]
    pub fn v_slice<'b>(&'b self, r: usize, c0: usize, hd: usize,
                       buf: &'b mut [f32]) -> &'b [f32] {
        self.plane_slice(r, c0, hd, buf, false)
    }

    #[inline]
    fn plane_slice<'b>(&'b self, r: usize, c0: usize, hd: usize,
                       buf: &'b mut [f32], want_k: bool) -> &'b [f32] {
        if r < self.prefix_rows {
            let p = self.prefix.expect("prefix row without a prefix");
            let m = if want_k { &p.k[self.layer] } else { &p.v[self.layer] };
            return &m.row(r)[c0..c0 + hd];
        }
        let local = r - self.prefix_rows;
        let page = &self.pages[local / self.page_rows];
        let off = (local % self.page_rows) * self.d + c0;
        let data = if want_k { &page.k } else { &page.v };
        match data {
            PageData::F32(v) => &v[off..off + hd],
            PageData::F16(v) => {
                for (dst, &h) in buf[..hd].iter_mut().zip(&v[off..off + hd]) {
                    *dst = f16_bits_to_f32(h);
                }
                &buf[..hd]
            }
        }
    }
}

/// Stable 64-bit hash of a token prefix (splitmix64 over the ids) —
/// the prefix-registry key. Collisions are handled by token-equality
/// checks at lookup, never trusted from the hash alone.
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    let mut h = 0x9e37_79b9_7f4a_7c15u64 ^ (tokens.len() as u64);
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = h;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        h = z ^ (z >> 31);
    }
    h
}

/// Shared-prefix handle as stored by sessions.
pub type PrefixRef = Arc<SharedPrefix>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_is_close_and_special_cases_hold() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 1e-3, 3.14159, -2.7e4] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!((x - y).abs() <= 1e-3 * (1.0 + x.abs()),
                    "{x} -> {y}");
        }
        // exact halves survive exactly
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(0.25)), 0.25);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-6.5)), -6.5);
        // overflow saturates to inf, subnormals flush to zero
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e9)).is_infinite());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-9)), 0.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between two f16 mantissa steps; RNE
        // keeps the even (lower) one
        let x = 1.0f32 + (2.0f32).powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), 1.0);
        // 1 + 3*2^-11 ties up to the even above
        let y = 1.0f32 + 3.0 * (2.0f32).powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(y)),
                   1.0 + (2.0f32).powi(-9));
    }

    #[test]
    fn page_write_read_roundtrip_and_quantize() {
        let (rows, d) = (4, 8);
        let mut page = KvPage::new_f32(rows, d);
        let krow: Vec<f32> = (0..d).map(|i| i as f32 * 0.5).collect();
        let vrow: Vec<f32> = (0..d).map(|i| -(i as f32)).collect();
        page.write_row(2, d, &krow, &vrow);
        assert_eq!(page.bytes(), 2 * rows * d * 4);
        let view = KvView {
            prefix: None,
            prefix_rows: 0,
            pages: std::slice::from_ref(&page),
            page_rows: rows,
            d,
            layer: 0,
        };
        let mut buf = vec![0.0f32; d];
        assert_eq!(view.k_slice(2, 0, d, &mut buf), &krow[..]);
        assert_eq!(view.v_slice(2, 2, 4, &mut buf), &vrow[2..6]);
        // quantize halves the bytes; reads stay close
        let mut page = page;
        let saved = page.quantize();
        assert_eq!(saved, 2 * rows * d * 2);
        assert!(page.is_quantized());
        assert_eq!(page.quantize(), 0, "second quantize is a no-op");
        let view = KvView {
            prefix: None,
            prefix_rows: 0,
            pages: std::slice::from_ref(&page),
            page_rows: rows,
            d,
            layer: 0,
        };
        for (a, b) in view.k_slice(2, 0, d, &mut buf).iter().zip(&krow) {
            assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn view_resolves_prefix_then_pages() {
        let d = 4;
        let mut pk = Mat::zeros(2, d);
        let mut pv = Mat::zeros(2, d);
        pk.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        pv.row_mut(1).copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        let prefix = SharedPrefix {
            tokens: vec![1, 2],
            k: vec![pk],
            v: vec![pv],
            rows: 2,
            importance: vec![0.0, 0.0],
        };
        let mut page = KvPage::new_f32(2, d);
        page.write_row(0, d, &[9.0; 4], &[10.0; 4]);
        let pages = [page];
        let view = KvView {
            prefix: Some(&prefix),
            prefix_rows: 2,
            pages: &pages,
            page_rows: 2,
            d,
            layer: 0,
        };
        assert_eq!(view.rows(), 4);
        let mut buf = vec![0.0f32; d];
        assert_eq!(view.k_slice(1, 0, d, &mut buf), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(view.v_slice(1, 0, d, &mut buf), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(view.k_slice(2, 0, d, &mut buf), &[9.0; 4]);
    }

    #[test]
    fn prefix_hash_is_stable_and_length_sensitive() {
        assert_eq!(prefix_hash(&[1, 2, 3]), prefix_hash(&[1, 2, 3]));
        assert_ne!(prefix_hash(&[1, 2, 3]), prefix_hash(&[1, 2]));
        assert_ne!(prefix_hash(&[1, 2, 3]), prefix_hash(&[3, 2, 1]));
        assert_ne!(prefix_hash(&[]), prefix_hash(&[0]));
    }
}
