//! Router: top-k expert selection plus every ODP pruning decision —
//! scoring-time (`OdpPolicy`, paper Sec. 3.3 with sequence-level Eq.-6
//! protection) and decode-time (`DecodeOdp`, the autoregressive
//! approximation) — and the shared `RunStats` accounting both paths
//! report through, so `Metrics::prune_ratio()` means the same thing
//! everywhere (DESIGN.md §2).

use crate::moe::model::{MoeModel, OdpPolicy, TokenMetric};
use crate::tensor::{softmax_rows, Mat};
use crate::util::stats::{kurtosis, mean, percentile, top_k_indices, variance};

// ---------------------------------------------------------------------------
// Shared accounting
// ---------------------------------------------------------------------------

/// Expert-routing statistics shared by the scoring forward, KV-cache
/// decode, and the fused batcher step. One struct, one meaning: the
/// serving metrics and the paper's CR are computed identically on
/// every path.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// expert invocations actually executed
    pub expert_calls: usize,
    /// tokens * top_k summed over layers (the no-pruning count)
    pub expert_possible: usize,
    pub dropped_secondary: usize,
    pub dropped_all: usize,
    /// per [layer][expert] activation counts (significance phi)
    pub activation_counts: Vec<Vec<u64>>,
    /// per [layer][expert] summed renormalized routing weights (w_i)
    pub weight_sums: Vec<Vec<f64>>,
    pub tokens_seen: usize,
}

impl RunStats {
    pub fn new(n_layers: usize, n_experts: usize) -> RunStats {
        RunStats {
            activation_counts: vec![vec![0; n_experts]; n_layers],
            weight_sums: vec![vec![0.0; n_experts]; n_layers],
            ..Default::default()
        }
    }

    pub fn merge(&mut self, other: &RunStats) {
        self.expert_calls += other.expert_calls;
        self.expert_possible += other.expert_possible;
        self.dropped_secondary += other.dropped_secondary;
        self.dropped_all += other.dropped_all;
        self.tokens_seen += other.tokens_seen;
        for (a, b) in self.activation_counts.iter_mut().zip(&other.activation_counts) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        for (a, b) in self.weight_sums.iter_mut().zip(&other.weight_sums) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    /// Total pruned expert invocations — the numerator every consumer
    /// (engine, batcher, metrics) must use, on both paths.
    pub fn pruned_total(&self) -> usize {
        self.dropped_secondary + self.dropped_all
    }

    /// Fraction of expert compute saved by pruning (paper's "CR").
    pub fn compression_ratio(&self) -> f64 {
        if self.expert_possible == 0 {
            return 0.0;
        }
        self.pruned_total() as f64 / self.expert_possible as f64
    }
}

// ---------------------------------------------------------------------------
// Decode-time ODP policy
// ---------------------------------------------------------------------------

/// ODP at decode time (paper Sec. 3.3 applied autoregressively): the
/// w1/w0 ratio rule is exact; Eq.-6 token protection needs attention
/// *received from future queries*, which doesn't exist yet for the
/// token being decoded, so protection falls back to the L1-norm factor
/// of Eq. 6 alone (DESIGN.md §2).
#[derive(Debug, Clone, Default)]
pub struct DecodeOdp {
    /// per-layer ratio threshold (median of w1/w0 on calibration data)
    pub mu: Vec<f32>,
    /// per-layer L1-norm protection threshold (None = no protection)
    pub l1_threshold: Option<Vec<f32>>,
}

impl DecodeOdp {
    /// Calibrate L1 thresholds: protect tokens whose post-norm hidden
    /// L1 exceeds the (1-protect_ratio) percentile per layer.
    pub fn calibrate(
        model: &MoeModel,
        seqs: &[Vec<u32>],
        mu: Vec<f32>,
        protect_ratio: f32,
    ) -> DecodeOdp {
        use crate::moe::model::{CalibSink, ForwardOpts};
        struct L1Sink(Vec<Vec<f32>>);
        impl CalibSink for L1Sink {
            fn moe_input(&mut self, layer: usize, x: &Mat) {
                for r in 0..x.rows {
                    self.0[layer].push(x.row(r).iter().map(|v| v.abs()).sum());
                }
            }
        }
        let mut sink = L1Sink(vec![Vec::new(); model.cfg.n_layers]);
        for s in seqs {
            model.forward(s, &ForwardOpts::default(), &mut sink);
        }
        let thresholds = sink
            .0
            .iter()
            .map(|l1s| percentile(l1s, 100.0 * (1.0 - protect_ratio)))
            .collect();
        DecodeOdp { mu, l1_threshold: Some(thresholds) }
    }
}

// ---------------------------------------------------------------------------
// Selection primitives
// ---------------------------------------------------------------------------

/// Top-k expert selection over a router row, honoring an eligibility
/// filter; ties break toward the lower index (matches jax.lax.top_k).
pub fn select_top_k(
    row: &[f32],
    k: usize,
    eligible: impl Fn(usize) -> bool,
) -> Vec<(usize, f32)> {
    let mut sel = Vec::with_capacity(k + 1);
    select_top_k_into(row, k, eligible, &mut sel);
    sel
}

/// `select_top_k` into a reused selection buffer (cleared first): the
/// decode loop keeps one Vec per batch slot, so routing allocates
/// nothing in steady state. The candidate list never exceeds k+1
/// entries, so the sort is allocation-free insertion sort.
pub fn select_top_k_into(
    row: &[f32],
    k: usize,
    eligible: impl Fn(usize) -> bool,
    sel: &mut Vec<(usize, f32)>,
) {
    sel.clear();
    for (e, &w) in row.iter().enumerate() {
        if !eligible(e) {
            continue;
        }
        sel.push((e, w));
        sel.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        sel.truncate(k);
    }
}

/// Router probabilities for a token batch: softmax(h @ gate).
pub fn gate_probs(h: &Mat, gate: &Mat) -> Mat {
    let mut probs = h.matmul(gate);
    softmax_rows(&mut probs);
    probs
}

/// `gate_probs` into a reused buffer (resized + overwritten).
pub fn gate_probs_into(h: &Mat, gate: &Mat, probs: &mut Mat) {
    crate::tensor::matmul_reset_into(h, gate, probs);
    softmax_rows(probs);
}

/// Shared per-token selection: top-k (minus an optionally masked
/// expert), renormalize, record activation/weight/possible counts.
/// Returns the w1/w0 ratio the ODP rules consume.
fn select_and_count_into(
    row: &[f32],
    top_k: usize,
    li: usize,
    masked_expert: Option<usize>,
    stats: &mut RunStats,
    sel: &mut Vec<(usize, f32)>,
) -> f32 {
    select_top_k_into(row, top_k, |e| Some(e) != masked_expert, sel);
    let sum: f32 = sel.iter().map(|&(_, w)| w).sum();
    for se in sel.iter_mut() {
        se.1 /= sum;
    }
    for &(e, w) in sel.iter() {
        stats.activation_counts[li][e] += 1;
        stats.weight_sums[li][e] += w as f64;
    }
    stats.expert_possible += top_k;
    if sel.len() >= 2 { sel[1].1 / sel[0].1 } else { 0.0 }
}

fn select_and_count(
    row: &[f32],
    top_k: usize,
    li: usize,
    masked_expert: Option<usize>,
    stats: &mut RunStats,
) -> (Vec<(usize, f32)>, f32) {
    let mut sel = Vec::with_capacity(top_k + 1);
    let ratio = select_and_count_into(row, top_k, li, masked_expert, stats,
                                      &mut sel);
    (sel, ratio)
}

/// One decode-time routing decision (used token-wise by `step`,
/// batched prefill, and the fused multi-session batcher step).
/// Allocating wrapper over [`decode_select_into`].
pub fn decode_select(
    probs_row: &[f32],
    h_row: &[f32],
    top_k: usize,
    li: usize,
    odp: Option<&DecodeOdp>,
    stats: &mut RunStats,
) -> Vec<(usize, f32)> {
    let mut sel = Vec::with_capacity(top_k + 1);
    decode_select_into(probs_row, h_row, top_k, li, odp, stats, &mut sel);
    sel
}

/// `decode_select` into a reused selection buffer (cleared first) —
/// the zero-allocation decode routing path.
pub fn decode_select_into(
    probs_row: &[f32],
    h_row: &[f32],
    top_k: usize,
    li: usize,
    odp: Option<&DecodeOdp>,
    stats: &mut RunStats,
    sel: &mut Vec<(usize, f32)>,
) {
    let ratio = select_and_count_into(probs_row, top_k, li, None, stats, sel);
    if let Some(odp) = odp {
        let protected = match &odp.l1_threshold {
            Some(thr) => {
                let l1: f32 = h_row.iter().map(|v| v.abs()).sum();
                l1 >= thr[li]
            }
            None => false,
        };
        if !protected && sel.len() >= 2 && ratio < odp.mu[li] {
            sel.truncate(1);
            sel[0].1 = 1.0;
            stats.dropped_secondary += 1;
        }
    }
    stats.expert_calls += sel.len();
}

// ---------------------------------------------------------------------------
// Scoring-path routing (sequence-level ODP)
// ---------------------------------------------------------------------------

pub struct ScoreRoute {
    pub probs: Mat,
    pub topk: Vec<Vec<(usize, f32)>>,
    pub ratio_samples: Vec<f32>,
}

/// Full-sequence routing for one layer under the scoring-path ODP
/// policy: top-k + renormalize, Eq.-6 token protection / drop-all
/// (`importance` must cover the sequence when the policy needs it),
/// and the Tab.-11 token-metric baselines.
#[allow(clippy::too_many_arguments)]
pub fn score_route(
    h: &Mat,
    gate: &Mat,
    top_k: usize,
    li: usize,
    odp: &OdpPolicy,
    importance: &[f32],
    masked_expert: Option<usize>,
    collect_ratio_samples: bool,
    stats: &mut RunStats,
) -> ScoreRoute {
    let s = h.rows;
    let probs = gate_probs(h, gate);

    let metric_vals: Vec<f32> = match odp {
        OdpPolicy::TokenMetric { metric, .. } => match metric {
            TokenMetric::Eq6Importance => importance.to_vec(),
            TokenMetric::Kurtosis => (0..s).map(|t| kurtosis(h.row(t))).collect(),
            TokenMetric::Variance => (0..s).map(|t| variance(h.row(t))).collect(),
            TokenMetric::MeanAbs => (0..s)
                .map(|t| mean(&h.row(t).iter().map(|v| v.abs()).collect::<Vec<_>>()))
                .collect(),
        },
        _ => Vec::new(),
    };

    // protected / dropped token sets
    let protected = match odp {
        OdpPolicy::Protected { protect_ratio, .. }
        | OdpPolicy::ProtectedDropAll { protect_ratio, .. } => {
            let n_prot = ((s as f32) * protect_ratio).ceil() as usize;
            let mut mask = vec![false; s];
            for idx in top_k_indices(importance, n_prot.min(s)) {
                mask[idx] = true;
            }
            mask
        }
        _ => vec![false; s],
    };
    let drop_all = match odp {
        OdpPolicy::ProtectedDropAll { drop_ratio, .. } => {
            let n_drop = ((s as f32) * drop_ratio).floor() as usize;
            let neg: Vec<f32> = importance.iter().map(|v| -v).collect();
            let mut mask = vec![false; s];
            for idx in top_k_indices(&neg, n_drop.min(s)) {
                if !protected[idx] {
                    mask[idx] = true;
                }
            }
            mask
        }
        _ => vec![false; s],
    };
    let metric_pruned = match odp {
        OdpPolicy::TokenMetric { prune_frac, .. } => {
            let n_prune = ((s as f32) * prune_frac).round() as usize;
            let neg: Vec<f32> = metric_vals.iter().map(|v| -v).collect();
            let mut mask = vec![false; s];
            for idx in top_k_indices(&neg, n_prune.min(s)) {
                mask[idx] = true;
            }
            mask
        }
        _ => vec![false; s],
    };

    let mut topk: Vec<Vec<(usize, f32)>> = Vec::with_capacity(s);
    let mut ratio_samples = Vec::new();
    for t in 0..s {
        let (mut sel, ratio) =
            select_and_count(probs.row(t), top_k, li, masked_expert, stats);
        if collect_ratio_samples {
            ratio_samples.push(ratio);
        }
        if drop_all[t] {
            stats.dropped_all += sel.len();
            sel.clear();
        } else {
            let prune_secondary = match odp {
                OdpPolicy::None => false,
                OdpPolicy::WeightOnly { mu } => ratio < mu[li],
                OdpPolicy::Protected { mu, .. }
                | OdpPolicy::ProtectedDropAll { mu, .. } => {
                    !protected[t] && ratio < mu[li]
                }
                OdpPolicy::TokenMetric { .. } => metric_pruned[t],
            };
            if prune_secondary && sel.len() >= 2 {
                sel.truncate(1);
                sel[0].1 = 1.0;
                stats.dropped_secondary += 1;
            }
        }
        stats.expert_calls += sel.len();
        topk.push(sel);
    }
    ScoreRoute { probs, topk, ratio_samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn select_top_k_ties_prefer_lower_index() {
        let sel = select_top_k(&[0.25, 0.25, 0.4, 0.1], 2, |_| true);
        assert_eq!(sel[0].0, 2);
        assert_eq!(sel[1].0, 0); // tie 0 vs 1 -> lower index
    }

    #[test]
    fn decode_select_prunes_below_mu() {
        let mut stats = RunStats::new(1, 4);
        let odp = DecodeOdp { mu: vec![2.0], l1_threshold: None };
        let sel = decode_select(&[0.4, 0.3, 0.2, 0.1], &[1.0; 8], 2, 0,
                                Some(&odp), &mut stats);
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0], (0, 1.0));
        assert_eq!(stats.dropped_secondary, 1);
        assert_eq!(stats.expert_calls, 1);
        assert_eq!(stats.expert_possible, 2);
        assert_eq!(stats.pruned_total(), 1);
    }

    #[test]
    fn decode_select_l1_protection_keeps_both() {
        let mut stats = RunStats::new(1, 4);
        let odp = DecodeOdp { mu: vec![2.0], l1_threshold: Some(vec![4.0]) };
        // L1 of h_row = 8 >= 4 -> protected, secondary survives
        let sel = decode_select(&[0.4, 0.3, 0.2, 0.1], &[1.0; 8], 2, 0,
                                Some(&odp), &mut stats);
        assert_eq!(sel.len(), 2);
        assert_eq!(stats.dropped_secondary, 0);
    }

    #[test]
    fn score_route_counts_match_selection() {
        let mut rng = Rng::new(0);
        let (s, d, e) = (12, 8, 4);
        let h = Mat::randn(&mut rng, s, d, 1.0);
        let gate = Mat::randn(&mut rng, d, e, 1.0);
        let mut stats = RunStats::new(1, e);
        let r = score_route(&h, &gate, 2, 0, &OdpPolicy::None, &[], None,
                            false, &mut stats);
        assert_eq!(r.topk.len(), s);
        assert_eq!(stats.expert_possible, s * 2);
        assert_eq!(stats.expert_calls, s * 2);
        for sel in &r.topk {
            let w: f32 = sel.iter().map(|&(_, w)| w).sum();
            assert!((w - 1.0).abs() < 1e-5);
        }
        // per-expert activations sum to s * top_k
        let total: u64 = stats.activation_counts[0].iter().sum();
        assert_eq!(total, (s * 2) as u64);
    }

    #[test]
    fn masked_expert_never_selected() {
        let mut rng = Rng::new(1);
        let (s, d, e) = (10, 8, 4);
        let h = Mat::randn(&mut rng, s, d, 1.0);
        let gate = Mat::randn(&mut rng, d, e, 1.0);
        let mut stats = RunStats::new(1, e);
        let r = score_route(&h, &gate, 2, 0, &OdpPolicy::None, &[], Some(1),
                            false, &mut stats);
        assert!(r.topk.iter().all(|sel| sel.iter().all(|&(ex, _)| ex != 1)));
        assert_eq!(stats.activation_counts[0][1], 0);
    }
}
