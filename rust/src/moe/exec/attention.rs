//! Causal multi-head attention, generalized over the two shapes the
//! engine needs (DESIGN.md §2):
//!
//!   * **fresh sequence** — scoring / batched prefill: queries exist
//!     for every position, keys == queries (`klen == q.rows`), and the
//!     head-averaged attention map feeding Eq.-6 token importance can
//!     be materialized;
//!   * **KV-cache append** — decode: queries exist only for the
//!     appended suffix while keys/values span the whole cache
//!     (`klen > q.rows`); the Eq.-6 map is undefined here because it
//!     needs attention *received from future queries* (decode falls
//!     back to the L1 factor, see `exec::router`).
//!
//! One kernel serves both, so the scoring and decode paths can no
//! longer drift apart numerically. The `_into` entry point writes into
//! a caller-owned output through [`AttnScratch`] (zero-allocation
//! decode, DESIGN.md §4) and can fan heads out across the
//! `WorkerPool`: each head owns a disjoint column range of the output,
//! so pooled and serial execution are bit-identical. The Eq.-6 map is
//! a cross-head mean (a reduction), so `want_map` forces the serial
//! path to keep its accumulation order fixed.

use crate::kernels::{self, KernelOps};
use crate::tensor::{softmax_rows_ops, Mat};
use crate::util::pool::{SendPtr, WorkerPool};

use super::kvcache::KvView;

pub const NEG_INF: f32 = -1e30;

/// Head-work volume (t·klen·d) below which the pool is not engaged.
const ATTN_PAR_MIN_WORK: usize = 262_144;

pub struct AttnOut {
    /// [T, D] concatenated head outputs (the input of wo).
    pub out: Mat,
    /// Head-averaged [S, S] attention map for Eq. 6; only materialized
    /// on full-sequence calls (`klen == q.rows`) when requested.
    pub a_mean: Option<Mat>,
}

/// Reusable per-context attention buffers: the transposed K panel and
/// the score matrix. A `DecodeSession` owns one and calls
/// [`AttnScratch::reserve`] up front so steady-state decode never
/// reallocates as the KV window grows.
#[derive(Debug, Default)]
pub struct AttnScratch {
    kht: Vec<f32>,
    scores: Mat,
    /// one-row dequant buffer for f16 KV pages (paged path only)
    dq: Vec<f32>,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }

    /// Pre-reserve for single-token decode against a KV window of up
    /// to `max_klen` keys (buffer-pointer stability from step one).
    pub fn reserve(&mut self, head_dim: usize, max_klen: usize) {
        self.kht.reserve(head_dim * max_klen);
        self.scores.data.reserve(max_klen);
        self.dq.resize(head_dim, 0.0);
    }
}

/// Causal attention for the `q.rows` newest tokens against keys/values
/// `0..klen`. Query row `i` sits at global position `klen - q.rows + i`
/// and attends to keys `0..=klen - q.rows + i`. `k` and `v` must hold
/// at least `klen` valid rows (decode passes the whole KV-cache
/// buffer; scoring passes exactly the fresh projections).
///
/// Allocating wrapper over [`causal_attention_into`] (scoring path and
/// tests; the decode loop uses the into-variant with its own scratch).
pub fn causal_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    klen: usize,
    n_heads: usize,
    want_map: bool,
) -> AttnOut {
    let mut scratch = AttnScratch::new();
    let mut out = Mat::zeros(0, 0);
    let a_mean = causal_attention_into(
        q,
        k,
        v,
        klen,
        n_heads,
        want_map,
        Some(WorkerPool::global()),
        &mut scratch,
        &mut out,
    );
    AttnOut { out, a_mean }
}

/// Attention into a caller-owned `out` (resized + overwritten), with
/// kht/score buffers from `scratch`. `pool: Some(..)` fans heads out
/// when the map is not requested and the work clears the gate — each
/// head writes out[:, head·hd ..] exclusively, so results are
/// bit-identical to `pool: None`. Returns the Eq.-6 map if requested.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_into(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    klen: usize,
    n_heads: usize,
    want_map: bool,
    pool: Option<&WorkerPool>,
    scratch: &mut AttnScratch,
    out: &mut Mat,
) -> Option<Mat> {
    causal_attention_into_ops(q, k, v, klen, n_heads, want_map, pool, scratch,
                              out, kernels::active())
}

/// [`causal_attention_into`] pinned to an explicit kernel backend
/// (parity tests cross-check every compiled ISA against scalar).
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_into_ops(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    klen: usize,
    n_heads: usize,
    want_map: bool,
    pool: Option<&WorkerPool>,
    scratch: &mut AttnScratch,
    out: &mut Mat,
    ops: &'static KernelOps,
) -> Option<Mat> {
    let t = q.rows;
    let d = q.cols;
    assert!(t >= 1 && klen >= t, "bad attention window: T={t} klen={klen}");
    assert!(k.rows >= klen && v.rows >= klen, "KV shorter than klen");
    assert_eq!(d % n_heads, 0);
    let hd = d / n_heads;
    let pos0 = klen - t;
    assert!(!want_map || pos0 == 0, "Eq.-6 map needs the full sequence");
    let scale = 1.0 / (hd as f32).sqrt();

    out.resize_to(t, d);
    out.data.fill(0.0);
    let outbase = SendPtr(out.data.as_mut_ptr());

    let pooled = match pool {
        Some(p)
            if !want_map
                && n_heads >= 2
                && p.width() > 1
                && t * klen * d >= ATTN_PAR_MIN_WORK
                && !WorkerPool::on_worker() =>
        {
            Some(p)
        }
        _ => None,
    };
    if let Some(p) = pooled {
        p.for_each(n_heads, move |head| {
            // per-head buffers: this is the prefill/scoring-scale
            // path, outside the zero-alloc decode contract
            let mut kht = Vec::new();
            let mut scores = Mat::zeros(0, 0);
            one_head(q, k, v, klen, pos0, head * hd, hd, scale, &mut kht,
                     &mut scores, outbase, d, ops);
        });
        return None;
    }

    let mut a_mean = if want_map { Some(Mat::zeros(t, t)) } else { None };
    for head in 0..n_heads {
        one_head(q, k, v, klen, pos0, head * hd, hd, scale,
                 &mut scratch.kht, &mut scratch.scores, outbase, d, ops);
        if let Some(am) = a_mean.as_mut() {
            for (a, sc) in am.data.iter_mut().zip(&scratch.scores.data) {
                *a += sc / n_heads as f32;
            }
        }
    }
    a_mean
}

/// One attention head over columns [c0, c0+hd): transpose K into
/// `kht` so the score loop vectorizes over key index j (EXPERIMENTS.md
/// §Perf), softmax, then accumulate scores @ v into the head's column
/// range of the output (disjoint across heads — pool-safe). The score
/// and AV inner loops dispatch through `ops.axpy`, so one SIMD axpy
/// serves GEMM, dequant-GEMM and attention alike.
#[allow(clippy::too_many_arguments)]
fn one_head(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    klen: usize,
    pos0: usize,
    c0: usize,
    hd: usize,
    scale: f32,
    kht: &mut Vec<f32>,
    scores: &mut Mat,
    outbase: SendPtr<f32>,
    d: usize,
    ops: &'static KernelOps,
) {
    let t = q.rows;
    kht.resize(hd * klen, 0.0);
    for j in 0..klen {
        let krow = &k.row(j)[c0..c0 + hd];
        for (dd, &kv) in krow.iter().enumerate() {
            kht[dd * klen + j] = kv;
        }
    }
    scores.resize_to(t, klen);
    scores.data.fill(0.0);
    for i in 0..t {
        let limit = pos0 + i; // last key this query may attend to
        let qrow = &q.row(i)[c0..c0 + hd];
        let srow = &mut scores.data[i * klen..(i + 1) * klen];
        for (dd, &qv) in qrow.iter().enumerate() {
            let kr = &kht[dd * klen..dd * klen + limit + 1];
            (ops.axpy)(&mut srow[..=limit], kr, qv);
        }
        (ops.vscale)(&mut srow[..=limit], scale);
        for sv in srow[limit + 1..].iter_mut() {
            *sv = NEG_INF;
        }
    }
    softmax_rows_ops(scores, ops);
    // out[:, c0..c0+hd] += scores @ v[:, c0..c0+hd]
    for i in 0..t {
        let limit = pos0 + i;
        // Safety: each head owns columns [c0, c0+hd) exclusively.
        let orow = unsafe {
            std::slice::from_raw_parts_mut(outbase.0.add(i * d + c0), hd)
        };
        for j in 0..=limit {
            let a = scores.data[i * klen + j];
            if a == 0.0 {
                continue;
            }
            let vrow = &v.row(j)[c0..c0 + hd];
            (ops.axpy)(orow, vrow, a);
        }
    }
}

/// [`causal_attention_into`] over a paged two-segment KV view
/// (shared prefix + private pages, `exec::kvcache`) instead of flat
/// K/V Mats. Numerics are identical — `one_head_paged` is `one_head`
/// with the two row reads swapped for `KvView` resolution — so f32
/// pages are bit-exact with the flat kernel; f16 pages dequantize per
/// row through the scratch buffer. Same pooling/`want_map` contract.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_paged_into(
    q: &Mat,
    kv: &KvView<'_>,
    klen: usize,
    n_heads: usize,
    want_map: bool,
    pool: Option<&WorkerPool>,
    scratch: &mut AttnScratch,
    out: &mut Mat,
) -> Option<Mat> {
    let ops = kernels::active();
    let t = q.rows;
    let d = q.cols;
    assert!(t >= 1 && klen >= t, "bad attention window: T={t} klen={klen}");
    assert!(kv.rows() >= klen, "paged KV shorter than klen");
    assert_eq!(d, kv.d, "KV view width mismatch");
    assert_eq!(d % n_heads, 0);
    let hd = d / n_heads;
    let pos0 = klen - t;
    assert!(!want_map || pos0 == 0, "Eq.-6 map needs the full sequence");
    let scale = 1.0 / (hd as f32).sqrt();

    out.resize_to(t, d);
    out.data.fill(0.0);
    let outbase = SendPtr(out.data.as_mut_ptr());

    let pooled = match pool {
        Some(p)
            if !want_map
                && n_heads >= 2
                && p.width() > 1
                && t * klen * d >= ATTN_PAR_MIN_WORK
                && !WorkerPool::on_worker() =>
        {
            Some(p)
        }
        _ => None,
    };
    if let Some(p) = pooled {
        p.for_each(n_heads, move |head| {
            // per-head buffers: prefill/scoring scale, outside the
            // zero-alloc decode contract (mirrors the flat kernel)
            let mut kht = Vec::new();
            let mut scores = Mat::zeros(0, 0);
            let mut dq = vec![0.0f32; hd];
            one_head_paged(q, kv, klen, pos0, head * hd, hd, scale, &mut kht,
                           &mut scores, &mut dq, outbase, d, ops);
        });
        return None;
    }

    scratch.dq.resize(hd, 0.0);
    let mut a_mean = if want_map { Some(Mat::zeros(t, t)) } else { None };
    for head in 0..n_heads {
        one_head_paged(q, kv, klen, pos0, head * hd, hd, scale,
                       &mut scratch.kht, &mut scratch.scores, &mut scratch.dq,
                       outbase, d, ops);
        if let Some(am) = a_mean.as_mut() {
            for (a, sc) in am.data.iter_mut().zip(&scratch.scores.data) {
                *a += sc / n_heads as f32;
            }
        }
    }
    a_mean
}

/// [`one_head`] reading K/V rows through a paged [`KvView`]: only the
/// two row reads differ, keeping every accumulation order identical.
#[allow(clippy::too_many_arguments)]
fn one_head_paged(
    q: &Mat,
    kv: &KvView<'_>,
    klen: usize,
    pos0: usize,
    c0: usize,
    hd: usize,
    scale: f32,
    kht: &mut Vec<f32>,
    scores: &mut Mat,
    dq: &mut [f32],
    outbase: SendPtr<f32>,
    d: usize,
    ops: &'static KernelOps,
) {
    let t = q.rows;
    kht.resize(hd * klen, 0.0);
    for j in 0..klen {
        let krow = kv.k_slice(j, c0, hd, dq);
        for (dd, &kvv) in krow.iter().enumerate() {
            kht[dd * klen + j] = kvv;
        }
    }
    scores.resize_to(t, klen);
    scores.data.fill(0.0);
    for i in 0..t {
        let limit = pos0 + i; // last key this query may attend to
        let qrow = &q.row(i)[c0..c0 + hd];
        let srow = &mut scores.data[i * klen..(i + 1) * klen];
        for (dd, &qv) in qrow.iter().enumerate() {
            let kr = &kht[dd * klen..dd * klen + limit + 1];
            (ops.axpy)(&mut srow[..=limit], kr, qv);
        }
        (ops.vscale)(&mut srow[..=limit], scale);
        for sv in srow[limit + 1..].iter_mut() {
            *sv = NEG_INF;
        }
    }
    softmax_rows_ops(scores, ops);
    // out[:, c0..c0+hd] += scores @ v[:, c0..c0+hd]
    for i in 0..t {
        let limit = pos0 + i;
        // Safety: each head owns columns [c0, c0+hd) exclusively.
        let orow = unsafe {
            std::slice::from_raw_parts_mut(outbase.0.add(i * d + c0), hd)
        };
        for j in 0..=limit {
            let a = scores.data[i * klen + j];
            if a == 0.0 {
                continue;
            }
            let vrow = kv.v_slice(j, c0, hd, dq);
            (ops.axpy)(orow, vrow, a);
        }
    }
}

/// Eq. 6: I_j = ||t_j||_1 * mean_{i >= j} A[i, j] (head-averaged A).
pub fn eq6_importance(h: &Mat, a_mean: &Mat) -> Vec<f32> {
    let s = h.rows;
    let mut out = vec![0.0f32; s];
    for j in 0..s {
        let mut col = 0.0;
        for i in j..s {
            col += a_mean.data[i * s + j];
        }
        let denom = (s - j).max(1) as f32;
        let l1: f32 = h.row(j).iter().map(|v| v.abs()).sum();
        out[j] = l1 * (col / denom);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn qkv(seed: u64, s: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(&mut rng, s, d, 1.0),
            Mat::randn(&mut rng, s, d, 1.0),
            Mat::randn(&mut rng, s, d, 1.0),
        )
    }

    #[test]
    fn incremental_append_matches_full_sequence() {
        let (s, d, nh) = (9, 8, 2);
        let (q, k, v) = qkv(0, s, d);
        let full = causal_attention(&q, &k, &v, s, nh, false);
        // one-token appends against a growing KV window
        for i in 0..s {
            let qi = q.slice_rows(i, i + 1);
            let inc = causal_attention(&qi, &k, &v, i + 1, nh, false);
            for (a, b) in inc.out.row(0).iter().zip(full.out.row(i)) {
                assert!((a - b).abs() < 1e-5, "pos {i}: {a} vs {b}");
            }
        }
        // suffix append (batched prefill continuation)
        let qs = q.slice_rows(3, s);
        let suf = causal_attention(&qs, &k, &v, s, nh, false);
        for i in 3..s {
            for (a, b) in suf.out.row(i - 3).iter().zip(full.out.row(i)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn map_is_causal_and_row_stochastic() {
        let (s, d, nh) = (6, 8, 2);
        let (q, k, v) = qkv(1, s, d);
        let out = causal_attention(&q, &k, &v, s, nh, true);
        let am = out.a_mean.unwrap();
        for i in 0..s {
            let row_sum: f32 = am.row(i).iter().sum();
            assert!((row_sum - 1.0).abs() < 1e-5, "row {i}: {row_sum}");
            for j in i + 1..s {
                assert_eq!(am.at(i, j), 0.0, "future leak at ({i},{j})");
            }
        }
    }

    #[test]
    fn pooled_heads_bit_match_serial() {
        // shape chosen to clear ATTN_PAR_MIN_WORK so the pool engages
        // (when this host has >1 core; with 1 core both runs inline)
        let (s, d, nh) = (64, 64, 8);
        let (q, k, v) = qkv(3, s, d);
        let mut scratch = AttnScratch::new();
        let mut serial = Mat::zeros(0, 0);
        causal_attention_into(&q, &k, &v, s, nh, false, None, &mut scratch,
                              &mut serial);
        let mut pooled = Mat::zeros(0, 0);
        causal_attention_into(&q, &k, &v, s, nh, false,
                              Some(WorkerPool::global()), &mut scratch,
                              &mut pooled);
        assert_eq!(serial.data, pooled.data, "head fan-out must be bit-exact");
    }

    #[test]
    fn scratch_reuse_is_pointer_stable() {
        let (s, d, nh) = (12, 8, 2);
        let (q, k, v) = qkv(4, s, d);
        let mut scratch = AttnScratch::new();
        scratch.reserve(d / nh, s);
        let mut out = Mat::zeros(0, 0);
        causal_attention_into(&q, &k, &v, s, nh, false, None, &mut scratch,
                              &mut out);
        let (kp, sp, op) = (scratch.kht.as_ptr(), scratch.scores.data.as_ptr(),
                            out.data.as_ptr());
        let first = out.clone();
        causal_attention_into(&q, &k, &v, s, nh, false, None, &mut scratch,
                              &mut out);
        assert_eq!(scratch.kht.as_ptr(), kp);
        assert_eq!(scratch.scores.data.as_ptr(), sp);
        assert_eq!(out.data.as_ptr(), op);
        assert_eq!(out.data, first.data);
    }

    fn pages_from(k: &Mat, v: &Mat, rows: usize, page_rows: usize)
                  -> Vec<super::super::kvcache::KvPage> {
        use super::super::kvcache::KvPage;
        let d = k.cols;
        let n_pages = rows.div_ceil(page_rows);
        let mut pages: Vec<KvPage> =
            (0..n_pages).map(|_| KvPage::new_f32(page_rows, d)).collect();
        for r in 0..rows {
            pages[r / page_rows].write_row(r % page_rows, d, k.row(r),
                                           v.row(r));
        }
        pages
    }

    #[test]
    fn paged_f32_bit_matches_flat() {
        // same values through pages (including a ragged last page)
        // must give bit-identical output and map to the flat kernel
        let (s, d, nh) = (13, 8, 2);
        let (q, k, v) = qkv(7, s, d);
        let mut scratch = AttnScratch::new();
        let mut flat = Mat::zeros(0, 0);
        let am_flat = causal_attention_into(&q, &k, &v, s, nh, true, None,
                                            &mut scratch, &mut flat);
        let pages = pages_from(&k, &v, s, 4);
        let view = KvView {
            prefix: None,
            prefix_rows: 0,
            pages: &pages,
            page_rows: 4,
            d,
            layer: 0,
        };
        let mut paged = Mat::zeros(0, 0);
        let am_paged = causal_attention_paged_into(&q, &view, s, nh, true,
                                                   None, &mut scratch,
                                                   &mut paged);
        assert_eq!(flat.data, paged.data, "paged f32 must be bit-exact");
        assert_eq!(am_flat.unwrap().data, am_paged.unwrap().data);
        // decode shape: single appended query against the full window
        let qi = q.slice_rows(s - 1, s);
        let mut flat1 = Mat::zeros(0, 0);
        causal_attention_into(&qi, &k, &v, s, nh, false, None, &mut scratch,
                              &mut flat1);
        let mut paged1 = Mat::zeros(0, 0);
        causal_attention_paged_into(&qi, &view, s, nh, false, None,
                                    &mut scratch, &mut paged1);
        assert_eq!(flat1.data, paged1.data);
    }

    #[test]
    fn paged_f16_stays_close_to_flat() {
        let (s, d, nh) = (12, 8, 2);
        let (q, k, v) = qkv(8, s, d);
        let mut scratch = AttnScratch::new();
        let mut flat = Mat::zeros(0, 0);
        causal_attention_into(&q, &k, &v, s, nh, false, None, &mut scratch,
                              &mut flat);
        let mut pages = pages_from(&k, &v, s, 4);
        for p in pages.iter_mut() {
            assert!(p.quantize() > 0);
        }
        let view = KvView {
            prefix: None,
            prefix_rows: 0,
            pages: &pages,
            page_rows: 4,
            d,
            layer: 0,
        };
        let mut paged = Mat::zeros(0, 0);
        causal_attention_paged_into(&q, &view, s, nh, false, None,
                                    &mut scratch, &mut paged);
        for (a, b) in paged.data.iter().zip(&flat.data) {
            assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()),
                    "f16 pages drifted: {a} vs {b}");
        }
    }

    #[test]
    fn eq6_importance_nonnegative_and_sized() {
        let (s, d, nh) = (7, 8, 2);
        let (q, k, v) = qkv(2, s, d);
        let h = Mat::randn(&mut Rng::new(3), s, d, 1.0);
        let am = causal_attention(&q, &k, &v, s, nh, true).a_mean.unwrap();
        let imp = eq6_importance(&h, &am);
        assert_eq!(imp.len(), s);
        assert!(imp.iter().all(|v| *v >= 0.0));
    }
}
