//! Flight-recorder tracing (DESIGN.md §9): per-request span timelines
//! with Chrome-trace export and live MoE routing introspection.
//!
//! Aggregate counters (`coordinator::metrics`) answer "how is the
//! fleet doing"; this module answers "where did *this* request's
//! 400 ms go". Every stage of the request path — admission, queue
//! wait, batch slot, prefill, per-step decode, expert fetch, sampling,
//! SSE write — records a span into a sharded ring of the last N
//! events (the "flight recorder"), and per-layer routing events carry
//! the paper's live signals: routing entropy, top-k scores, experts
//! activated, and Eq.-6 ODP prune counts.
//!
//! **Cost discipline.** Tracing is off by default and every entry
//! point is gated on one relaxed atomic load (`enabled()`), the same
//! pattern as `util::faults` — the disabled path is a load + branch,
//! proven ≤1% of decode tokens/s by `benches/trace_overhead.rs`.
//! When enabled, recording is lock-light: events land in one of
//! [`SHARDS`] fixed-capacity rings keyed by thread id, so decode
//! workers, the batcher, and connection threads rarely contend on a
//! shard mutex, and a full ring overwrites the oldest event instead
//! of allocating (a flight recorder, not a log).
//!
//! **Ownership rules.** Event `name`/arg keys are `&'static str` (no
//! allocation on the hot path); spans are RAII guards recorded at
//! drop; cross-thread stages (queue wait: enqueued on a connection
//! thread, admitted on the batcher thread) use [`complete`] with an
//! explicit start timestamp instead of a guard. The recorder itself
//! is process-global — there is one timeline per process, matching
//! the one fault plan and one kernel backend.
//!
//! Three windows onto the recorder:
//! * `GET /debug/trace?last_ms=..` — Chrome trace-event JSON
//!   ([`chrome::render`]), loads in `chrome://tracing` / Perfetto.
//! * `GET /debug/experts` — per-layer expert heat table ([`heat`]).
//! * [`dump_now`] — auto-dump to a file on panic, blown deadline, or
//!   `/admin/drain`, so post-mortems ship with a timeline.

pub mod chrome;
pub mod heat;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

/// Fixed arg capacity per event: named u64s only (floats ride as
/// fixed-point micro-units), so events stay `Copy` and ring pushes
/// never allocate.
pub const MAX_ARGS: usize = 3;
pub type Args = [(&'static str, u64); MAX_ARGS];
pub const NO_ARGS: Args = [("", 0); MAX_ARGS];

pub fn args1(k: &'static str, v: u64) -> Args {
    [(k, v), ("", 0), ("", 0)]
}

pub fn args2(k1: &'static str, v1: u64, k2: &'static str, v2: u64) -> Args {
    [(k1, v1), (k2, v2), ("", 0)]
}

pub fn args3(k1: &'static str, v1: u64, k2: &'static str, v2: u64,
             k3: &'static str, v3: u64) -> Args {
    [(k1, v1), (k2, v2), (k3, v3)]
}

/// Span taxonomy (DESIGN.md §9). One category per subsystem, used as
/// the Chrome trace `cat` field so timelines filter by layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cat {
    /// HTTP front end: parse, admission, SSE writes.
    Serve,
    /// Time between submit and a batch slot.
    Queue,
    /// Batcher slot residency and the fused step.
    Batch,
    /// Prompt prefill.
    Prefill,
    /// Per-step decode.
    Decode,
    /// Expert residency: demand fetch, prefetch, quarantine.
    Expert,
    /// Per-layer MoE routing introspection.
    Route,
    /// Token sampling.
    Sample,
    /// Memory governor: rung changes, KV down-quantization.
    Mem,
    /// Lifecycle: drain, dumps.
    Drain,
}

impl Cat {
    pub fn name(self) -> &'static str {
        match self {
            Cat::Serve => "serve",
            Cat::Queue => "queue",
            Cat::Batch => "batch",
            Cat::Prefill => "prefill",
            Cat::Decode => "decode",
            Cat::Expert => "expert",
            Cat::Route => "route",
            Cat::Sample => "sample",
            Cat::Mem => "mem",
            Cat::Drain => "drain",
        }
    }
}

/// One recorded event. `dur_ns == 0` renders as a Chrome instant
/// event (`ph:"i"`), anything else as a complete span (`ph:"X"`).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub name: &'static str,
    pub cat: Cat,
    /// Stable per-thread lane id (not the OS tid).
    pub tid: u64,
    pub args: Args,
}

/// Shard count: threads hash onto shards by lane id, so the decode
/// pool, batcher, and connection threads rarely share a mutex.
const SHARDS: usize = 8;
/// Events retained per shard; the recorder holds the last
/// `SHARDS * SHARD_CAP` events process-wide.
pub const SHARD_CAP: usize = 8192;

#[derive(Default)]
struct Ring {
    buf: Vec<Event>,
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.buf.len() < SHARD_CAP {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.dropped += 1;
        }
        self.next = (self.next + 1) % SHARD_CAP;
    }
}

struct Recorder {
    shards: Vec<Mutex<Ring>>,
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        shards: (0..SHARDS).map(|_| Mutex::new(Ring::default())).collect(),
    })
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch (first touch of the subsystem).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

/// The master gate every recording call checks first. Disabled (the
/// default) this is the whole cost of the subsystem: one relaxed
/// atomic load and a branch. `MC_TRACE=1` enables at first touch;
/// `MC_TRACE_OUT=<dir>` sets the auto-dump directory.
#[inline]
pub fn enabled() -> bool {
    ENV_INIT.call_once(|| {
        let _ = epoch(); // pin the epoch before the first event
        if let Ok(v) = std::env::var("MC_TRACE") {
            let v = v.trim();
            if v == "1" || v.eq_ignore_ascii_case("true")
                || v.eq_ignore_ascii_case("on")
            {
                ENABLED.store(true, Relaxed);
            }
        }
        if let Ok(dir) = std::env::var("MC_TRACE_OUT") {
            if !dir.is_empty() {
                *dump_dir().lock().unwrap() = Some(PathBuf::from(dir));
            }
        }
    });
    ENABLED.load(Relaxed)
}

/// Override the gate (`--trace`, tests). Runs the env init first so a
/// later `enabled()` cannot clobber an explicit setting.
pub fn set_enabled(on: bool) {
    let _ = enabled();
    ENABLED.store(on, Relaxed);
}

// -- per-thread lane ids ------------------------------------------------

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

fn lane() -> u64 {
    TID.with(|c| {
        let v = c.get();
        if v != 0 {
            v
        } else {
            let v = NEXT_TID.fetch_add(1, Relaxed);
            c.set(v);
            v
        }
    })
}

fn record(ev: Event) {
    let shard = (ev.tid as usize) % SHARDS;
    recorder().shards[shard].lock().unwrap().push(ev);
}

/// Record an instant event (zero duration).
#[inline]
pub fn instant(cat: Cat, name: &'static str, args: Args) {
    if !enabled() {
        return;
    }
    record(Event { ts_ns: now_ns(), dur_ns: 0, name, cat, tid: lane(), args });
}

/// Record a span whose start was captured earlier (possibly on
/// another thread) as [`now_ns`]. The cross-thread stages — queue
/// wait, batch-slot residency — use this instead of a guard.
#[inline]
pub fn complete(cat: Cat, name: &'static str, start_ns: u64, args: Args) {
    if !enabled() {
        return;
    }
    let end = now_ns();
    record(Event {
        ts_ns: start_ns,
        dur_ns: end.saturating_sub(start_ns).max(1),
        name,
        cat,
        tid: lane(),
        args,
    });
}

/// RAII span: records a complete event on drop. Disarmed (free) when
/// tracing is off at construction.
pub struct Span {
    start_ns: u64,
    name: &'static str,
    cat: Cat,
    args: Args,
    armed: bool,
}

/// Open a span guard covering the rest of the scope.
#[inline]
pub fn span(cat: Cat, name: &'static str) -> Span {
    let armed = enabled();
    Span {
        start_ns: if armed { now_ns() } else { 0 },
        name,
        cat,
        args: NO_ARGS,
        armed,
    }
}

impl Span {
    /// Attach a named arg (first free slot of [`MAX_ARGS`]; extras are
    /// silently dropped). No-op when disarmed.
    pub fn arg(mut self, key: &'static str, v: u64) -> Span {
        self.set_arg(key, v);
        self
    }

    /// In-place variant of [`Span::arg`] for values only known
    /// mid-span.
    pub fn set_arg(&mut self, key: &'static str, v: u64) {
        if !self.armed {
            return;
        }
        for slot in &mut self.args {
            if slot.0.is_empty() {
                *slot = (key, v);
                return;
            }
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            complete(self.cat, self.name, self.start_ns, self.args);
        }
    }
}

// -- snapshot / export --------------------------------------------------

/// Copy out the recorder's contents, oldest-first. `last_ns` keeps
/// only events whose *end* falls inside the trailing window.
pub fn snapshot(last_ns: Option<u64>) -> Vec<Event> {
    let cutoff = last_ns.map(|w| now_ns().saturating_sub(w));
    let mut out = Vec::new();
    for shard in &recorder().shards {
        let g = shard.lock().unwrap();
        for ev in &g.buf {
            if cutoff.is_none_or(|c| ev.ts_ns + ev.dur_ns >= c) {
                out.push(*ev);
            }
        }
    }
    out.sort_by_key(|e| (e.ts_ns, e.tid));
    out
}

/// Events overwritten since startup (ring saturation indicator,
/// reported in the trace header).
pub fn dropped() -> u64 {
    recorder().shards.iter().map(|s| s.lock().unwrap().dropped).sum()
}

/// Empty every shard (tests; `/debug/trace?clear=1`).
pub fn clear() {
    for shard in &recorder().shards {
        let mut g = shard.lock().unwrap();
        g.buf.clear();
        g.next = 0;
    }
}

// -- auto-dump ----------------------------------------------------------

fn dump_dir() -> &'static Mutex<Option<PathBuf>> {
    static D: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    D.get_or_init(|| Mutex::new(None))
}

/// Where [`dump_now`] writes (`--trace-out` / `MC_TRACE_OUT`); `None`
/// falls back to the system temp dir.
pub fn set_dump_dir(dir: Option<PathBuf>) {
    let _ = enabled(); // env init first, so an explicit dir wins
    *dump_dir().lock().unwrap() = dir;
}

static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Dump the whole flight recorder to
/// `<dir>/mc-trace-<reason>-<pid>-<seq>.json` as Chrome trace JSON.
/// The post-mortem hook: called on recovered worker panics, blown
/// deadlines, and `/admin/drain`. No-op (None) while tracing is
/// disabled; write failures are swallowed (a failing disk must not
/// take the serving path down with it).
pub fn dump_now(reason: &str) -> Option<PathBuf> {
    if !enabled() {
        return None;
    }
    let dir = dump_dir()
        .lock()
        .unwrap()
        .clone()
        .unwrap_or_else(std::env::temp_dir);
    let seq = DUMP_SEQ.fetch_add(1, Relaxed);
    let path = dir.join(format!("mc-trace-{reason}-{}-{seq}.json",
                                std::process::id()));
    let events = snapshot(None);
    let json = chrome::render(&events, reason);
    match std::fs::write(&path, json) {
        Ok(()) => {
            instant(Cat::Drain, "trace_dumped", NO_ARGS);
            Some(path)
        }
        Err(_) => None,
    }
}

/// Fixed-point helper: an `f64` in micro-units (×1e6) for u64 args.
pub fn micro(v: f64) -> u64 {
    if v.is_finite() && v > 0.0 {
        (v * 1e6).round() as u64
    } else {
        0
    }
}

/// The gate and recorder are process-global, so unit tests that flip
/// them serialize on one lock (mirrors `tests/fault_tolerance.rs`'s
/// FAULT_LOCK discipline for the fault plan).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static OBS_LOCK: Mutex<()> = Mutex::new(());
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_guard as guard;

    #[test]
    fn disabled_records_nothing() {
        let _g = guard();
        set_enabled(false);
        clear();
        instant(Cat::Decode, "x", NO_ARGS);
        drop(span(Cat::Decode, "y").arg("a", 1));
        assert!(snapshot(None).is_empty());
    }

    #[test]
    fn span_and_instant_round_trip() {
        let _g = guard();
        set_enabled(true);
        clear();
        {
            let mut s = span(Cat::Prefill, "prefill").arg("rows", 40);
            s.set_arg("layer", 2);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        instant(Cat::Route, "route", args2("layer", 1, "active", 3));
        let evs = snapshot(None);
        set_enabled(false);
        assert_eq!(evs.len(), 2);
        let sp = evs.iter().find(|e| e.name == "prefill").unwrap();
        assert!(sp.dur_ns >= 1_000_000, "span measured {}ns", sp.dur_ns);
        assert_eq!(sp.args[0], ("rows", 40));
        assert_eq!(sp.args[1], ("layer", 2));
        let ins = evs.iter().find(|e| e.name == "route").unwrap();
        assert_eq!(ins.dur_ns, 0);
        assert_eq!(ins.args[1], ("active", 3));
        clear();
    }

    #[test]
    fn window_filters_old_events() {
        let _g = guard();
        set_enabled(true);
        clear();
        instant(Cat::Serve, "old", NO_ARGS);
        std::thread::sleep(std::time::Duration::from_millis(10));
        instant(Cat::Serve, "new", NO_ARGS);
        let recent = snapshot(Some(5_000_000)); // trailing 5ms
        set_enabled(false);
        assert!(recent.iter().any(|e| e.name == "new"));
        assert!(!recent.iter().any(|e| e.name == "old"));
        clear();
    }

    #[test]
    fn ring_overwrites_instead_of_growing() {
        let _g = guard();
        set_enabled(true);
        clear();
        // single thread → single shard: overflow it
        for _ in 0..SHARD_CAP + 10 {
            instant(Cat::Decode, "e", NO_ARGS);
        }
        let evs = snapshot(None);
        set_enabled(false);
        assert_eq!(evs.len(), SHARD_CAP);
        assert!(dropped() >= 10);
        clear();
    }

    #[test]
    fn dump_writes_chrome_json() {
        let _g = guard();
        set_enabled(true);
        clear();
        set_dump_dir(Some(std::env::temp_dir()));
        instant(Cat::Drain, "marker", args1("id", 7));
        let path = dump_now("test").expect("dump path");
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        set_enabled(false);
        clear();
        assert!(body.contains("\"traceEvents\""), "{body}");
        assert!(body.contains("\"marker\""), "{body}");
        let parsed = crate::util::json::Json::parse(&body).expect("valid JSON");
        assert!(parsed.opt("traceEvents").is_some());
    }

    #[test]
    fn micro_fixed_point() {
        assert_eq!(micro(1.5), 1_500_000);
        assert_eq!(micro(0.0), 0);
        assert_eq!(micro(f64::NAN), 0);
        assert_eq!(micro(-3.0), 0);
    }
}
