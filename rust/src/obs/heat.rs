//! Live per-layer expert heat: the process-global accumulation behind
//! `GET /debug/experts`. The decode path reports every routed
//! selection here (gated on [`obs::enabled`], so the disabled cost is
//! the same relaxed load as every other trace point); the serve tier
//! renders the table joined with the resolver's residency/quarantine
//! snapshot.
//!
//! This is the same per-expert activation-frequency / routing-weight
//! signal `RunStats` accumulates per session — kept globally and
//! continuously so operators watch it on live traffic, and so the
//! planned `compress-experts` pass (ROADMAP) can be fed from a
//! serving instance instead of an offline calibration run.

use std::sync::{Mutex, OnceLock};

#[derive(Default)]
struct HeatMap {
    /// per [layer][expert] activation counts
    counts: Vec<Vec<u64>>,
    /// per [layer][expert] summed routing weights (post-renorm)
    weights: Vec<Vec<f64>>,
    /// token-steps observed per layer (denominator for frequencies)
    tokens: Vec<u64>,
}

fn heat() -> &'static Mutex<HeatMap> {
    static H: OnceLock<Mutex<HeatMap>> = OnceLock::new();
    H.get_or_init(|| Mutex::new(HeatMap::default()))
}

fn grow(m: &mut HeatMap, layer: usize, expert: usize) {
    if m.counts.len() <= layer {
        m.counts.resize_with(layer + 1, Vec::new);
        m.weights.resize_with(layer + 1, Vec::new);
        m.tokens.resize(layer + 1, 0);
    }
    if m.counts[layer].len() <= expert {
        m.counts[layer].resize(expert + 1, 0);
        m.weights[layer].resize(expert + 1, 0.0);
    }
}

/// Report one token's routed selections at `layer`. Cheap no-op while
/// tracing is disabled; enabled cost is one short-held mutex per
/// token-layer (the table grows to the largest (layer, expert) seen,
/// so one global works across differently-shaped test servers).
pub fn record(layer: usize, selections: &[(usize, f32)]) {
    if !super::enabled() {
        return;
    }
    let mut m = heat().lock().unwrap();
    grow(&mut m, layer, 0);
    m.tokens[layer] += 1;
    for &(e, w) in selections {
        grow(&mut m, layer, e);
        m.counts[layer][e] += 1;
        m.weights[layer][e] += w as f64;
    }
}

/// One expert's row in the heat table.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExpertRow {
    pub activations: u64,
    pub mean_weight: f64,
}

/// Copy out the table: `rows[layer][expert]` plus per-layer token
/// counts.
pub fn snapshot() -> (Vec<Vec<ExpertRow>>, Vec<u64>) {
    let m = heat().lock().unwrap();
    let rows = m
        .counts
        .iter()
        .zip(&m.weights)
        .map(|(cs, ws)| {
            cs.iter()
                .zip(ws)
                .map(|(&c, &w)| ExpertRow {
                    activations: c,
                    mean_weight: if c > 0 { w / c as f64 } else { 0.0 },
                })
                .collect()
        })
        .collect();
    (rows, m.tokens.clone())
}

/// Zero the table (tests; `/debug/experts?clear=1`).
pub fn clear() {
    let mut m = heat().lock().unwrap();
    m.counts.clear();
    m.weights.clear();
    m.tokens.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_is_gated_and_accumulates() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        clear();
        record(0, &[(1, 0.5)]);
        assert!(snapshot().0.is_empty(), "disabled records nothing");

        crate::obs::set_enabled(true);
        record(1, &[(0, 0.75), (2, 0.25)]);
        record(1, &[(2, 1.0)]);
        crate::obs::set_enabled(false);
        let (rows, tokens) = snapshot();
        assert_eq!(tokens, vec![0, 2]);
        assert_eq!(rows[1][0].activations, 1);
        assert_eq!(rows[1][2].activations, 2);
        assert!((rows[1][2].mean_weight - 0.625).abs() < 1e-9);
        assert_eq!(rows[1][1].activations, 0);
        clear();
    }
}
