//! Chrome trace-event JSON rendering (the `chrome://tracing` /
//! Perfetto "JSON Array Format"): every recorded [`Event`] becomes a
//! complete (`ph:"X"`) or instant (`ph:"i"`) trace event with
//! microsecond timestamps, lane ids as `tid`, and the fixed u64 args
//! as the `args` object. Hand-rolled like `serve/json.rs` — names and
//! arg keys are `&'static str` identifiers but are escaped anyway so
//! the output is valid JSON for any future name.

use super::{dropped, Event};

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
}

fn push_event(out: &mut String, ev: &Event) {
    out.push_str("{\"name\":\"");
    escape_into(out, ev.name);
    out.push_str("\",\"cat\":\"");
    out.push_str(ev.cat.name());
    out.push_str("\",\"ph\":\"");
    // instant events get thread scope so Perfetto draws them as ticks
    if ev.dur_ns == 0 {
        out.push_str("i\",\"s\":\"t");
    } else {
        out.push('X');
    }
    out.push_str("\",\"pid\":1,\"tid\":");
    out.push_str(&ev.tid.to_string());
    // trace-event timestamps are microseconds; keep ns precision in
    // the fraction
    out.push_str(&format!(",\"ts\":{:.3}", ev.ts_ns as f64 / 1e3));
    if ev.dur_ns > 0 {
        out.push_str(&format!(",\"dur\":{:.3}", ev.dur_ns as f64 / 1e3));
    }
    let mut first = true;
    for (k, v) in &ev.args {
        if k.is_empty() {
            continue;
        }
        out.push_str(if first { ",\"args\":{" } else { "," });
        first = false;
        out.push('"');
        escape_into(out, k);
        out.push_str("\":");
        out.push_str(&v.to_string());
    }
    if !first {
        out.push('}');
    }
    out.push('}');
}

/// Render `events` as one self-contained Chrome trace JSON document.
/// `reason` labels why the trace was captured (`"debug_endpoint"`,
/// `"panic"`, `"deadline"`, `"drain"`) in `otherData`.
pub fn render(events: &[Event], reason: &str) -> String {
    // ~160 bytes per event renders without intermediate reallocs
    let mut out = String::with_capacity(128 + events.len() * 160);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"otherData\":{\"reason\":\"");
    escape_into(&mut out, reason);
    out.push_str(&format!(
        "\",\"pid\":{},\"events\":{},\"dropped\":{}}},\"traceEvents\":[",
        std::process::id(),
        events.len(),
        dropped()
    ));
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, ev);
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{args2, Cat, NO_ARGS};
    use crate::util::json::Json;

    fn ev(name: &'static str, ts: u64, dur: u64) -> Event {
        Event {
            ts_ns: ts,
            dur_ns: dur,
            name,
            cat: Cat::Decode,
            tid: 3,
            args: if dur > 0 { args2("batch", 4, "step", 9) } else { NO_ARGS },
        }
    }

    #[test]
    fn renders_parseable_complete_and_instant_events() {
        let events = [ev("decode_step", 1_500, 2_000), ev("mem_rung", 4_000, 0)];
        let body = render(&events, "unit");
        let json = Json::parse(&body).expect("valid JSON");
        let arr = json.opt("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let span = &arr[0];
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(span.get("args").unwrap().get("batch").unwrap()
                       .as_usize().unwrap(), 4);
        let inst = &arr[1];
        assert_eq!(inst.get("ph").unwrap().as_str().unwrap(), "i");
        assert!(inst.opt("dur").is_none());
        assert!(inst.opt("args").is_none());
        let other = json.opt("otherData").unwrap();
        assert_eq!(other.get("reason").unwrap().as_str().unwrap(), "unit");
        assert_eq!(other.get("events").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn empty_trace_is_still_valid() {
        let body = render(&[], "empty");
        let json = Json::parse(&body).expect("valid JSON");
        assert!(json.opt("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn names_are_escaped() {
        let mut e = ev("a\"b\\c", 0, 10);
        e.args = NO_ARGS;
        let body = render(&[e], "esc\nline");
        let json = Json::parse(&body).expect("valid JSON despite quotes");
        let arr = json.opt("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "a\"b\\c");
    }
}
