//! Model / tokenizer / packing configuration (rust twin of
//! `python/compile/config.py`; loaded from `artifacts/config.json`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

// --- tokenizer spec ---------------------------------------------------------
pub const VOCAB_SIZE: usize = 256;
pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const QRY: u32 = 4;
pub const TASK_BASE: u32 = 5;
pub const NUM_BASE: u32 = 16;
pub const NUM_COUNT: u32 = 64;
pub const SYM_BASE: u32 = 80;
pub const SYM_COUNT: u32 = 64;
pub const TXT_BASE: u32 = 144;
pub const TXT_COUNT: u32 = 112;

pub const TASK_NAMES: [&str; 8] = [
    "copy", "reverse", "sortsym", "modadd", "recall", "majority",
    "counting", "induction",
];

/// LM-Eval-analogue display names (which paper benchmark each task
/// substitutes for; see DESIGN.md §2).
pub const TASK_ANALOGUE: [&str; 8] = [
    "PIQA", "ARC-e", "ARC-c", "MathQA", "BoolQ", "HellaS.", "Wino.", "MMLU",
];

// --- packing spec ------------------------------------------------------------
pub const GROUP_SIZE: usize = 64;

pub fn vals_per_word(bits: usize) -> usize {
    match bits {
        2 => 16,
        3 => 10,
        4 => 8,
        _ => panic!("unsupported bit-width {bits}"),
    }
}

// --- model config ------------------------------------------------------------
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub prefill_tile: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    pub fn from_json(json: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: json.get("name")?.as_str()?.to_string(),
            vocab_size: json.get("vocab_size")?.as_usize()?,
            d_model: json.get("d_model")?.as_usize()?,
            n_layers: json.get("n_layers")?.as_usize()?,
            n_heads: json.get("n_heads")?.as_usize()?,
            d_ff: json.get("d_ff")?.as_usize()?,
            n_experts: json.get("n_experts")?.as_usize()?,
            top_k: json.get("top_k")?.as_usize()?,
            max_seq: json.get("max_seq")?.as_usize()?,
            prefill_tile: json.get("prefill_tile")?.as_usize()?,
        })
    }

    pub fn load(path: &Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Test-scale config mirroring python's test fixture.
    pub fn test_tiny() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            vocab_size: VOCAB_SIZE,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 4,
            top_k: 2,
            max_seq: 64,
            prefill_tile: 32,
        }
    }

    /// Total parameter count (must equal python's param_count()).
    pub fn param_count(&self) -> usize {
        let (d, f, e, v, s) =
            (self.d_model, self.d_ff, self.n_experts, self.vocab_size, self.max_seq);
        let emb = v * d + s * d;
        let per_layer = 4 * d * d + 2 * d + d * e + e * 3 * d * f;
        emb + self.n_layers * per_layer + d + d * v
    }

    pub fn expert_param_count(&self) -> usize {
        self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
    }

    /// Parameters outside the experts (attention, norms, gate, embeddings).
    pub fn non_expert_param_count(&self) -> usize {
        self.param_count() - self.expert_param_count()
    }
}

/// Default artifacts directory (overridable via MC_MOE_ARTIFACTS).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MC_MOE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_config_json() {
        let text = r#"{
            "name": "tiny", "vocab_size": 256, "d_model": 128,
            "n_layers": 4, "n_heads": 4, "d_ff": 256, "n_experts": 8,
            "top_k": 2, "max_seq": 256, "prefill_tile": 128,
            "train_steps": 600, "train_batch": 16, "train_seq": 128,
            "lr": 0.003, "seed": 0
        }"#;
        let cfg = ModelConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.d_model, 128);
        assert_eq!(cfg.head_dim(), 32);
        // matches python: config.tiny().param_count()
        assert_eq!(cfg.param_count(), 3_511_424);
    }

    #[test]
    fn param_count_formula() {
        let cfg = ModelConfig::test_tiny();
        // emb: 256*32 + 64*32; per layer: 4*32*32+2*32+32*4+4*3*32*64;
        // head: 32 + 32*256
        let expected = (256 * 32 + 64 * 32)
            + 2 * (4 * 32 * 32 + 2 * 32 + 32 * 4 + 4 * 3 * 32 * 64)
            + 32
            + 32 * 256;
        assert_eq!(cfg.param_count(), expected);
        assert_eq!(cfg.expert_param_count(), 2 * 4 * 3 * 32 * 64);
    }

    #[test]
    fn vals_per_word_spec() {
        assert_eq!(vals_per_word(2), 16);
        assert_eq!(vals_per_word(3), 10);
        assert_eq!(vals_per_word(4), 8);
    }
}
