//! Exact solver for the paper's Eq.-4 Integer Program, per MoE layer:
//!
//!   min  Σ_i Σ_j  φ_i^α · w_i^β · (ε_{i,j})^γ · x_{ij}
//!   s.t. Σ_i Σ_j  j · x_{ij} = B   (B = n·k total bits)
//!        Σ_j x_{ij} = 1  ∀i,   Σ_i x_{i3} ≥ 1,  Σ_i x_{i2} ≥ 1
//!
//! Dynamic program over (expert, bits-used, has-3-bit, has-2-bit):
//! O(n · B · 4 · 3) states — exact and instant even at Mixtral scale
//! (n=8, B≤24), matching the paper's "only takes a second".  A
//! brute-force enumerator cross-checks it in tests.

/// One layer's instance: cost[i][j-1] = weighted cost of expert i at j bits.
#[derive(Debug, Clone)]
pub struct IpProblem {
    pub cost: Vec<[f64; 3]>,
    /// total bits across experts (n*k)
    pub total_bits: usize,
    /// enforce >=1 expert at 3 bits and >=1 at 2 bits (paper constraint)
    pub enforce_minimums: bool,
}

/// Returns per-expert bit-widths (1..=3) minimizing the objective, or
/// None if infeasible.
pub fn solve_layer(p: &IpProblem) -> Option<Vec<usize>> {
    let n = p.cost.len();
    let bmax = p.total_bits;
    if bmax < n || bmax > 3 * n {
        return None;
    }
    const INF: f64 = f64::INFINITY;
    // dp[b][f] = min cost using experts 0..i with b bits, flags f
    // f = has3 * 2 + has2
    let mut dp = vec![[INF; 4]; bmax + 1];
    let mut parent: Vec<Vec<[(usize, usize, usize); 4]>> =
        vec![vec![[(usize::MAX, 0, 0); 4]; bmax + 1]; n];
    dp[0][0] = 0.0;
    for i in 0..n {
        let mut next = vec![[INF; 4]; bmax + 1];
        for b in 0..=bmax {
            for f in 0..4 {
                let cur = dp[b][f];
                if cur == INF {
                    continue;
                }
                for j in 1..=3usize {
                    let nb = b + j;
                    if nb > bmax {
                        continue;
                    }
                    let nf = f | if j == 3 { 2 } else { 0 } | if j == 2 { 1 } else { 0 };
                    let c = cur + p.cost[i][j - 1];
                    if c < next[nb][nf] {
                        next[nb][nf] = c;
                        parent[i][nb][nf] = (b, f, j);
                    }
                }
            }
        }
        dp = next;
    }
    // pick the best admissible final state
    let mut best: Option<(f64, usize)> = None;
    for f in 0..4 {
        if p.enforce_minimums && f != 3 {
            continue;
        }
        if dp[bmax][f] < INF {
            match best {
                Some((c, _)) if c <= dp[bmax][f] => {}
                _ => best = Some((dp[bmax][f], f)),
            }
        }
    }
    let (_, mut f) = best?;
    // backtrack
    let mut bits = vec![0usize; n];
    let mut b = bmax;
    for i in (0..n).rev() {
        let (pb, pf, j) = parent[i][b][f];
        if pb == usize::MAX {
            return None;
        }
        bits[i] = j;
        b = pb;
        f = pf;
    }
    Some(bits)
}

/// Brute-force reference (3^n enumeration) for cross-checking.
pub fn solve_brute(p: &IpProblem) -> Option<(Vec<usize>, f64)> {
    let n = p.cost.len();
    let mut best: Option<(Vec<usize>, f64)> = None;
    let mut assign = vec![1usize; n];
    loop {
        let total: usize = assign.iter().sum();
        let has3 = assign.iter().any(|&j| j == 3);
        let has2 = assign.iter().any(|&j| j == 2);
        if total == p.total_bits && (!p.enforce_minimums || (has3 && has2)) {
            let cost: f64 = assign.iter().enumerate().map(|(i, &j)| p.cost[i][j - 1]).sum();
            match &best {
                Some((_, c)) if *c <= cost => {}
                _ => best = Some((assign.clone(), cost)),
            }
        }
        // increment base-3 counter over {1,2,3}
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            if assign[i] < 3 {
                assign[i] += 1;
                break;
            }
            assign[i] = 1;
            i += 1;
        }
    }
}

/// Objective coefficients from significance factors (paper Eq. 4):
/// cost[i][j] = phi_i^alpha * w_i^beta * eps_{i,j}^gamma.
pub fn eq4_costs(phi: &[f64], w: &[f64], eps: &[[f32; 3]],
                 alpha: f64, beta: f64, gamma: f64) -> Vec<[f64; 3]> {
    phi.iter()
        .zip(w)
        .zip(eps)
        .map(|((&p, &wt), e)| {
            let sig = p.max(1e-9).powf(alpha) * wt.max(1e-9).powf(beta);
            [
                sig * (e[0] as f64).max(1e-12).powf(gamma),
                sig * (e[1] as f64).max(1e-12).powf(gamma),
                sig * (e[2] as f64).max(1e-12).powf(gamma),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_problem(rng: &mut Rng, n: usize, total: usize) -> IpProblem {
        let cost = (0..n)
            .map(|_| {
                // decreasing in bits, like real quantization error
                let base = rng.f64() + 0.1;
                [base * 4.0, base * 1.5, base * 0.5]
            })
            .collect();
        IpProblem { cost, total_bits: total, enforce_minimums: true }
    }

    #[test]
    fn dp_matches_brute_force() {
        let mut rng = Rng::new(0);
        for n in [4usize, 6, 8] {
            for total in n..=3 * n {
                let p = random_problem(&mut rng, n, total);
                let dp = solve_layer(&p);
                let bf = solve_brute(&p);
                match (dp, bf) {
                    (Some(bits), Some((_, want_cost))) => {
                        let got: f64 = bits
                            .iter()
                            .enumerate()
                            .map(|(i, &j)| p.cost[i][j - 1])
                            .sum();
                        assert!(
                            (got - want_cost).abs() < 1e-9,
                            "n={n} B={total}: dp {got} vs brute {want_cost}"
                        );
                        assert_eq!(bits.iter().sum::<usize>(), total);
                    }
                    (None, None) => {}
                    (a, b) => panic!("n={n} B={total}: dp {a:?} vs brute {b:?}"),
                }
            }
        }
    }

    #[test]
    fn constraints_enforced() {
        let mut rng = Rng::new(1);
        let p = random_problem(&mut rng, 8, 20);
        let bits = solve_layer(&p).unwrap();
        assert_eq!(bits.iter().sum::<usize>(), 20);
        assert!(bits.contains(&3));
        assert!(bits.contains(&2));
    }

    #[test]
    fn infeasible_totals_rejected() {
        let mut rng = Rng::new(2);
        let p = random_problem(&mut rng, 8, 30); // > 3n=24
        assert!(solve_layer(&p).is_none());
        let p = random_problem(&mut rng, 8, 7); // < n=8
        assert!(solve_layer(&p).is_none());
    }

    #[test]
    fn important_experts_get_more_bits() {
        // expert 0 very costly to quantize low, expert 7 free
        let mut cost = vec![[1.0, 0.5, 0.2]; 8];
        cost[0] = [100.0, 10.0, 0.1];
        cost[7] = [0.001, 0.001, 0.001];
        let p = IpProblem { cost, total_bits: 16, enforce_minimums: true };
        let bits = solve_layer(&p).unwrap();
        assert_eq!(bits[0], 3, "{bits:?}");
        assert_eq!(bits[7], 1, "{bits:?}");
    }

    #[test]
    fn eq4_cost_shapes() {
        let phi = vec![0.5, 0.1];
        let w = vec![0.3, 0.05];
        let eps = vec![[4.0f32, 2.0, 1.0], [4.0, 2.0, 1.0]];
        let c = eq4_costs(&phi, &w, &eps, 1.0, 1.0, 2.0);
        // same eps, bigger significance -> bigger cost
        assert!(c[0][0] > c[1][0]);
        // cost decreasing in bits
        assert!(c[0][0] > c[0][1] && c[0][1] > c[0][2]);
    }

    #[test]
    fn solver_scales_to_64_experts() {
        let mut rng = Rng::new(3);
        let p = random_problem(&mut rng, 64, 130);
        let t0 = std::time::Instant::now();
        let bits = solve_layer(&p).unwrap();
        assert!(t0.elapsed().as_millis() < 1000);
        assert_eq!(bits.iter().sum::<usize>(), 130);
    }
}
