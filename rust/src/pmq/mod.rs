//! PMQ — Pre-loading Mixed-Precision Quantization (paper Sec. 3.2).
//!
//! Pipeline: calibrate (one forward pass collecting routing stats +
//! GPTQ Hessians) -> build the quantized-expert zoo (every expert at
//! 1/2/3 bits via GPTQ) -> probe significance (drop-F-norm, eps_{i,j})
//! -> solve the Eq.-4 integer program per layer -> assemble the
//! compressed model.

pub mod allocate;
pub mod calibrate;
pub mod pipeline;
pub mod significance;
pub mod solver;
pub mod zoo;

pub use allocate::{Allocation, Allocator};
pub use calibrate::{calibrate, Calibration};
pub use pipeline::{Workbench, WorkbenchConfig};
pub use significance::{probe_significance, Significance};
pub use solver::{solve_layer, IpProblem};
pub use zoo::{assemble, ExpertZoo};
