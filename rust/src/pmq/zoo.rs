//! Quantized-expert zoo: every expert pre-quantized at 1/2/3 bits with
//! GPTQ (+ optionally the LWC backend), so allocation strategies just
//! pick entries, and the eps_{i,j} probes and the final assembly share
//! one set of quantizations — exactly how the paper runs one GPTQ pass
//! per configuration.

use anyhow::Result;

use crate::moe::model::{Expert, MoeModel};
use crate::quant::gptq::gptq_quantize;
use crate::quant::{quantize_rtn, QTensor};

use super::calibrate::HessianStore;
use super::Allocation;

/// Which quantizer backs the zoo (paper Tab. 8's backend swap).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantBackend {
    Gptq,
    /// OmniQuant-style clipped RTN (quant::lwc)
    Lwc,
    /// plain round-to-nearest (ablation)
    Rtn,
}

pub struct ExpertZoo {
    /// [layer][expert][bits-1] for bits in {1,2,3}
    pub entries: Vec<Vec<[Expert; 3]>>,
    /// GPTQ reconstruction F-norm per [layer][expert][bits-1]
    pub recon_err: Vec<Vec<[f32; 3]>>,
}

impl ExpertZoo {
    pub fn get(&self, layer: usize, expert: usize, bits: usize) -> &Expert {
        &self.entries[layer][expert][bits - 1]
    }

    /// Build the zoo from the FP model + calibration Hessians.
    pub fn build(model: &MoeModel, hess: &HessianStore,
                 backend: QuantBackend) -> Result<ExpertZoo> {
        let cfg = &model.cfg;
        let mut entries = Vec::with_capacity(cfg.n_layers);
        let mut recon = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut layer_entries = Vec::with_capacity(cfg.n_experts);
            let mut layer_recon = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                let fp = &model.layers[l].experts[e];
                let (hin, hmid) = &hess.experts[l][e];
                let mut by_bits: Vec<Expert> = Vec::with_capacity(3);
                let mut errs = [0.0f32; 3];
                for bits in 1..=3usize {
                    let quant_one = |w: &QTensor, h| -> Result<(QTensor, f32)> {
                        let dense = w.dequantize();
                        match backend {
                            QuantBackend::Gptq => {
                                let r = gptq_quantize(&dense, h, bits)?;
                                Ok((r.tensor, r.recon_err))
                            }
                            QuantBackend::Lwc => {
                                let t = if bits == 1 {
                                    quantize_rtn(&dense, 1)
                                } else {
                                    QTensor::Packed(crate::quant::lwc::quantize_lwc(
                                        &dense, bits,
                                    ))
                                };
                                let err = dense.sub(&t.dequantize()).fro_norm();
                                Ok((t, err))
                            }
                            QuantBackend::Rtn => {
                                let t = quantize_rtn(&dense, bits);
                                let err = dense.sub(&t.dequantize()).fro_norm();
                                Ok((t, err))
                            }
                        }
                    };
                    let (w1, e1) = quant_one(&fp.w1, hin)?;
                    let (w3, e3) = quant_one(&fp.w3, hin)?;
                    let (w2, e2) = quant_one(&fp.w2, hmid)?;
                    errs[bits - 1] = (e1 * e1 + e3 * e3 + e2 * e2).sqrt();
                    by_bits.push(Expert { w1, w3, w2 });
                }
                let arr: [Expert; 3] = by_bits.try_into().map_err(|_| {
                    anyhow::anyhow!("zoo entry build failed")
                })?;
                layer_entries.push(arr);
                layer_recon.push(errs);
            }
            entries.push(layer_entries);
            recon.push(layer_recon);
        }
        Ok(ExpertZoo { entries, recon_err: recon })
    }
}

/// Assemble the compressed model: experts from the zoo per `alloc`,
/// attention + gate quantized to `attn_bits` (paper: 4-bit; 16 keeps FP).
pub fn assemble(model: &MoeModel, zoo: &ExpertZoo, alloc: &Allocation,
                hess: &HessianStore, attn_bits: usize) -> Result<MoeModel> {
    let cfg = &model.cfg;
    let mut out = model.clone();
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let bits = alloc.bits[l][e];
            out.layers[l].experts[e] = if bits == 16 {
                model.layers[l].experts[e].clone()
            } else {
                zoo.get(l, e, bits).clone()
            };
        }
        if attn_bits < 16 {
            let layer = &mut out.layers[l];
            for (w, h) in [
                (&mut layer.wq, &hess.attn_in[l]),
                (&mut layer.wk, &hess.attn_in[l]),
                (&mut layer.wv, &hess.attn_in[l]),
                (&mut layer.wo, &hess.attn_out[l]),
            ] {
                let dense = w.dequantize();
                *w = gptq_quantize(&dense, h, attn_bits)?.tensor;
            }
            // the gate is [D, E] — E < GROUP_SIZE columns, keep rows
            // grouped along D like every other matrix. Its size is
            // negligible (paper quantizes it to 4-bit; D=128 rows
            // satisfy the group constraint).
            if layer.gate.rows % crate::config::GROUP_SIZE == 0 {
                let g = gptq_quantize(&layer.gate, &hess.gate_in[l], attn_bits)?;
                layer.gate = g.tensor.dequantize();
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{calibration_set, Split};
    use crate::moe::model::tests::random_model;
    use crate::pmq::calibrate::calibrate;

    fn setup() -> (ModelConfig, MoeModel, ExpertZoo, super::super::calibrate::Calibration) {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let seqs = calibration_set(2, 2, 32, Split::General);
        let cal = calibrate(&model, &seqs);
        let zoo = ExpertZoo::build(&model, &cal.hessians, QuantBackend::Gptq).unwrap();
        (cfg, model, zoo, cal)
    }

    #[test]
    fn zoo_has_all_entries_with_monotone_error() {
        let (cfg, _, zoo, _) = setup();
        assert_eq!(zoo.entries.len(), cfg.n_layers);
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let errs = zoo.recon_err[l][e];
                // more bits -> lower (or equal) reconstruction error
                assert!(errs[0] >= errs[1] && errs[1] >= errs[2],
                        "layer {l} expert {e}: {errs:?}");
            }
        }
    }

    #[test]
    fn zoo_bits_per_weight() {
        let (_, _, zoo, _) = setup();
        let e2 = &zoo.entries[0][0][1]; // 2-bit
        // test_tiny has K=32 < GROUP_SIZE, so quantizer-param overhead
        // is large (2 f32 per 32 elems = 2 extra bits); real configs
        // amortize to ~+1 bit.
        let bpw = e2.w1.bits_per_weight();
        assert!((2.0..4.5).contains(&bpw), "{bpw}");
        let e1 = &zoo.entries[0][0][0]; // 1-bit
        let bpw1 = e1.w1.bits_per_weight();
        assert!((1.0..=2.0).contains(&bpw1), "{bpw1}");
    }

    #[test]
    fn assemble_respects_allocation() {
        let (cfg, model, zoo, cal) = setup();
        let alloc = Allocation::uniform(&cfg, 2);
        let q = assemble(&model, &zoo, &alloc, &cal.hessians, 4).unwrap();
        let avg = q.expert_avg_bits();
        // 2-bit + group-param overhead (large at test_tiny's K=32)
        assert!((2.5..4.5).contains(&avg), "{avg}");
        // test_tiny is embedding-dominated; check expert shrinkage, not
        // whole-model ratio (real configs are expert-dominated)
        assert!(q.storage_bytes() < model.storage_bytes());
        let fp_expert: usize = model.layers.iter()
            .flat_map(|l| &l.experts).map(|e| e.storage_bytes()).sum();
        let q_expert: usize = q.layers.iter()
            .flat_map(|l| &l.experts).map(|e| e.storage_bytes()).sum();
        assert!(q_expert * 3 < fp_expert, "{q_expert} vs {fp_expert}");
    }

    #[test]
    fn assembled_model_still_functions() {
        let (_, model, zoo, cal) = setup();
        let alloc = Allocation::uniform(&model.cfg, 2);
        let q = assemble(&model, &zoo, &alloc, &cal.hessians, 4).unwrap();
        let toks: Vec<u32> = (1..33).collect();
        let logits = q.score(&toks);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // quantized output differs from FP but not absurdly
        let fp = model.score(&toks);
        let rel = fp.sub(&logits).fro_norm() / fp.fro_norm();
        assert!(rel > 1e-4 && rel < 1.0, "rel {rel}");
    }

    #[test]
    fn mixed_allocation_sizes_between_uniforms() {
        let (cfg, model, zoo, cal) = setup();
        let a1 = Allocation::uniform(&cfg, 1);
        let a3 = Allocation::uniform(&cfg, 3);
        let mut mixed = Allocation::uniform(&cfg, 2);
        mixed.bits[0][0] = 3;
        mixed.bits[0][1] = 1;
        let s1 = assemble(&model, &zoo, &a1, &cal.hessians, 4).unwrap().storage_bytes();
        let s2 = assemble(&model, &zoo, &mixed, &cal.hessians, 4).unwrap().storage_bytes();
        let s3 = assemble(&model, &zoo, &a3, &cal.hessians, 4).unwrap().storage_bytes();
        assert!(s1 < s2 && s2 < s3);
    }
}
