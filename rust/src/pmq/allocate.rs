//! Bit-width allocation strategies: PMQ (the paper's Eq.-4 IP) and all
//! the comparison baselines from Figs. 5-6 / Tabs. 2-3:
//!   uniform, random (Fig. 5), routing-weight-only, frequency-only,
//!   drop-F-norm, Hessian/HAWQ-v2 (Dong et al. 2020), and BSP
//!   (Li et al. 2024, layer-granular).

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelConfig;
use crate::util::rng::Rng;

use super::calibrate::Calibration;
use super::significance::Significance;
use super::solver::{eq4_costs, solve_layer, IpProblem};

/// Per-[layer][expert] bit-widths (1..=3, or 16 = FP passthrough).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub bits: Vec<Vec<usize>>,
    pub strategy: String,
}

impl Allocation {
    pub fn uniform(cfg: &ModelConfig, bits: usize) -> Allocation {
        Allocation {
            bits: vec![vec![bits; cfg.n_experts]; cfg.n_layers],
            strategy: format!("uniform{bits}"),
        }
    }

    /// Average expert bit-width (the paper's headline "Bits" before the
    /// +0.05 attention overhead).
    pub fn avg_bits(&self) -> f64 {
        let total: usize = self.bits.iter().flatten().sum();
        let count: usize = self.bits.iter().map(|l| l.len()).sum();
        total as f64 / count as f64
    }

    /// Histogram of assigned widths (Fig. 10 visualization data).
    pub fn histogram(&self) -> [usize; 3] {
        let mut h = [0usize; 3];
        for &b in self.bits.iter().flatten() {
            if (1..=3).contains(&b) {
                h[b - 1] += 1;
            }
        }
        h
    }
}

/// Allocation strategies (paper Figs. 5-6 nomenclature).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocator {
    /// the paper's PMQ: Eq.-4 IP over phi^alpha * w^beta * eps^gamma
    Pmq,
    /// drop-F-norm as the only importance signal
    FNorm,
    /// activation frequency only
    Frequency,
    /// routing-weight mass only
    Weight,
    /// HAWQ-v2: Hessian-trace-weighted quantization loss
    Hessian,
    /// random allocation at matched budget (Fig. 5)
    Random(u64),
    /// BSP (Li et al. 2024): layer-granular, top-q layers high-bit
    Bsp,
}

/// Hyper-parameters of the Eq.-4 objective (Tab. 10 ablates alpha/beta).
#[derive(Debug, Clone, Copy)]
pub struct PmqHyper {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
}

impl Default for PmqHyper {
    fn default() -> Self {
        // paper Appendix A.6: alpha=1, beta=1, gamma=2 is the default
        PmqHyper { alpha: 1.0, beta: 1.0, gamma: 2.0 }
    }
}

/// Inputs shared by every allocator.
pub struct AllocInputs<'a> {
    pub cfg: &'a ModelConfig,
    pub sig: &'a Significance,
    pub cal: &'a Calibration,
    /// mean Hessian diagonal per [layer][expert] (HAWQ trace estimate)
    pub hessian_trace: Vec<Vec<f64>>,
}

impl<'a> AllocInputs<'a> {
    pub fn new(cfg: &'a ModelConfig, sig: &'a Significance,
               cal: &'a Calibration) -> AllocInputs<'a> {
        let hessian_trace = cal
            .hessians
            .experts
            .iter()
            .map(|layer| {
                layer
                    .iter()
                    .map(|(hin, hmid)| 0.5 * (hin.diag_mean() + hmid.diag_mean()))
                    .collect()
            })
            .collect();
        AllocInputs { cfg, sig, cal, hessian_trace }
    }
}

/// Allocate `total_bits` per layer (n..=3n) with the chosen strategy.
/// An infeasible budget is a user error (`Err`), surfaced through the
/// CLI — not a crash.
pub fn allocate(inputs: &AllocInputs, strategy: Allocator, total_bits: usize,
                hyper: PmqHyper) -> Result<Allocation> {
    let cfg = inputs.cfg;
    let n = cfg.n_experts;
    ensure!(
        (n..=3 * n).contains(&total_bits),
        "infeasible expert bit budget {total_bits}: with {n} experts at \
         1..=3 bits each the per-layer total must lie in [{n}, {}] \
         (i.e. --avg-bits between 1.0 and 3.0)",
        3 * n
    );
    // the paper's >=1@3-bit / >=1@2-bit constraint can be infeasible at
    // very low budgets (e.g. B < n+3); relax it there, as the paper's
    // own 1.57-bit setting implies
    let solve = |cost: Vec<[f64; 3]>| -> Result<Vec<usize>> {
        let strict = IpProblem { cost: cost.clone(), total_bits, enforce_minimums: true };
        match solve_layer(&strict) {
            Some(bits) => Ok(bits),
            None => {
                let relaxed = IpProblem { cost, total_bits, enforce_minimums: false };
                solve_layer(&relaxed).ok_or_else(|| {
                    anyhow!(
                        "bit-allocation IP found no solution for budget \
                         {total_bits} over {n} experts"
                    )
                })
            }
        }
    };
    let mut bits = Vec::with_capacity(cfg.n_layers);
    match strategy {
        Allocator::Pmq => {
            for l in 0..cfg.n_layers {
                let cost = eq4_costs(
                    &inputs.sig.phi[l],
                    &inputs.sig.weight[l],
                    &inputs.sig.eps[l],
                    hyper.alpha,
                    hyper.beta,
                    hyper.gamma,
                );
                bits.push(solve(cost)?);
            }
        }
        Allocator::FNorm => {
            for l in 0..cfg.n_layers {
                let scores: Vec<f64> = inputs.sig.drop_fnorm[l]
                    .iter()
                    .map(|&v| v as f64)
                    .collect();
                bits.push(rank_allocate(&scores, total_bits));
            }
        }
        Allocator::Frequency => {
            for l in 0..cfg.n_layers {
                bits.push(rank_allocate(&inputs.sig.phi[l], total_bits));
            }
        }
        Allocator::Weight => {
            for l in 0..cfg.n_layers {
                bits.push(rank_allocate(&inputs.sig.weight[l], total_bits));
            }
        }
        Allocator::Hessian => {
            // HAWQ-v2 objective: trace(H)/n * ||W - Q(W, j)||^2, solved
            // with the same IP machinery but no phi/w weighting.
            for l in 0..cfg.n_layers {
                let cost: Vec<[f64; 3]> = (0..n)
                    .map(|e| {
                        let tr = inputs.hessian_trace[l][e].max(1e-12);
                        let eps = inputs.sig.eps[l][e];
                        [
                            tr * (eps[0] as f64).powi(2),
                            tr * (eps[1] as f64).powi(2),
                            tr * (eps[2] as f64).powi(2),
                        ]
                    })
                    .collect();
                bits.push(solve(cost)?);
            }
        }
        Allocator::Random(seed) => {
            let mut rng = Rng::new(seed);
            for _ in 0..cfg.n_layers {
                bits.push(random_allocation(&mut rng, n, total_bits));
            }
        }
        Allocator::Bsp => {
            // Block Score Predictor: rank layers by total drop-F-norm,
            // top 25% of MoE layers keep high bits (3), the rest get the
            // budget-matching low width. Layer-granular by design.
            let mut layer_scores: Vec<(usize, f64)> = inputs
                .sig
                .drop_fnorm
                .iter()
                .enumerate()
                .map(|(l, row)| (l, row.iter().map(|&v| v as f64).sum()))
                .collect();
            layer_scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let n_high = (cfg.n_layers as f64 * 0.25).ceil() as usize;
            let high_set: Vec<usize> =
                layer_scores[..n_high].iter().map(|&(l, _)| l).collect();
            // choose the low width so the model-average matches budget:
            // avg = (n_high*3 + n_low*low) / L  => low = ...
            let l_total = cfg.n_layers;
            let want_total = total_bits * l_total; // bits*experts summed
            let high_bits = 3 * n * n_high;
            let low_layers = l_total - n_high;
            let low = if low_layers == 0 {
                3
            } else {
                (want_total.saturating_sub(high_bits) as f64 / (low_layers * n) as f64)
                    .round()
                    .clamp(1.0, 3.0) as usize
            };
            for l in 0..l_total {
                if high_set.contains(&l) {
                    bits.push(vec![3; n]);
                } else {
                    bits.push(vec![low; n]);
                }
            }
        }
    }
    Ok(Allocation {
        bits,
        strategy: format!("{strategy:?}@B{total_bits}"),
    })
}

/// Rank-based allocation for single-score baselines: high scores get 3
/// bits, low scores get 1, the middle 2, meeting the exact budget.
fn rank_allocate(scores: &[f64], total_bits: usize) -> Vec<usize> {
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    // start everyone at 2, then promote the top / demote the bottom
    let mut bits = vec![2usize; n];
    let mut delta = total_bits as i64 - 2 * n as i64;
    let mut top = 0usize;
    let mut bottom = n;
    while delta > 0 && top < n {
        bits[idx[top]] = 3;
        top += 1;
        delta -= 1;
    }
    while delta < 0 && bottom > top {
        bottom -= 1;
        bits[idx[bottom]] = 1;
        delta += 1;
    }
    debug_assert_eq!(bits.iter().sum::<usize>(), total_bits);
    bits
}

/// Random composition of n widths in {1,2,3} summing to total.
fn random_allocation(rng: &mut Rng, n: usize, total: usize) -> Vec<usize> {
    loop {
        let mut bits: Vec<usize> = (0..n).map(|_| 1 + rng.below(3)).collect();
        // repair toward the target by random adjustments
        for _ in 0..200 {
            let sum: usize = bits.iter().sum();
            if sum == total {
                return bits;
            }
            let i = rng.below(n);
            if sum < total && bits[i] < 3 {
                bits[i] += 1;
            } else if sum > total && bits[i] > 1 {
                bits[i] -= 1;
            }
        }
        let sum: usize = bits.iter().sum();
        if sum == total {
            return bits;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{calibration_set, Split};
    use crate::moe::model::tests::random_model;
    use crate::pmq::calibrate::calibrate;
    use crate::pmq::significance::Significance;
    use crate::pmq::zoo::{ExpertZoo, QuantBackend};

    fn setup() -> (ModelConfig, Calibration, Significance) {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let seqs = calibration_set(6, 2, 24, Split::General);
        let cal = calibrate(&model, &seqs);
        let zoo = ExpertZoo::build(&model, &cal.hessians, QuantBackend::Rtn).unwrap();
        let sig = Significance::from_recon_err(&cal, &zoo);
        (cfg, cal, sig)
    }

    #[test]
    fn all_strategies_meet_budget() {
        let (cfg, cal, sig) = setup();
        let inputs = AllocInputs::new(&cfg, &sig, &cal);
        let n = cfg.n_experts;
        for strat in [
            Allocator::Pmq,
            Allocator::FNorm,
            Allocator::Frequency,
            Allocator::Weight,
            Allocator::Hessian,
            Allocator::Random(7),
        ] {
            for total in [n + 1, 2 * n, 5 * n / 2] {
                let a = allocate(&inputs, strat, total, PmqHyper::default()).unwrap();
                for (l, row) in a.bits.iter().enumerate() {
                    assert_eq!(
                        row.iter().sum::<usize>(),
                        total,
                        "{strat:?} layer {l}"
                    );
                }
            }
        }
    }

    #[test]
    fn bsp_meets_budget_on_average() {
        let (cfg, cal, sig) = setup();
        let inputs = AllocInputs::new(&cfg, &sig, &cal);
        let a = allocate(&inputs, Allocator::Bsp, 5 * cfg.n_experts / 2,
                         PmqHyper::default()).unwrap();
        // layer-granular: every expert in a layer shares a width
        for row in &a.bits {
            assert!(row.iter().all(|&b| b == row[0]));
        }
        let avg = a.avg_bits();
        assert!((2.0..=3.0).contains(&avg), "{avg}");
    }

    #[test]
    fn pmq_favors_significant_experts() {
        let (cfg, cal, mut sig) = setup();
        // make expert 0 of layer 0 maximally significant & fragile
        sig.phi[0][0] = 1.0;
        sig.weight[0][0] = 1.0;
        sig.eps[0][0] = [50.0, 25.0, 10.0];
        sig.phi[0][1] = 1e-6;
        sig.weight[0][1] = 1e-6;
        sig.eps[0][1] = [1e-6, 1e-6, 1e-6];
        let inputs = AllocInputs::new(&cfg, &sig, &cal);
        let a = allocate(&inputs, Allocator::Pmq, 2 * cfg.n_experts,
                         PmqHyper::default()).unwrap();
        assert_eq!(a.bits[0][0], 3, "{:?}", a.bits[0]);
        assert_eq!(a.bits[0][1], 1, "{:?}", a.bits[0]);
    }

    #[test]
    fn infeasible_budget_is_an_error_not_a_panic() {
        let (cfg, cal, sig) = setup();
        let inputs = AllocInputs::new(&cfg, &sig, &cal);
        let n = cfg.n_experts;
        for bad in [0, n - 1, 3 * n + 1, 100 * n] {
            let err = allocate(&inputs, Allocator::Pmq, bad,
                               PmqHyper::default());
            assert!(err.is_err(), "budget {bad} must be rejected");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(msg.contains("infeasible"), "unhelpful message: {msg}");
        }
    }

    #[test]
    fn random_allocations_differ_by_seed() {
        let (cfg, cal, sig) = setup();
        let inputs = AllocInputs::new(&cfg, &sig, &cal);
        let a = allocate(&inputs, Allocator::Random(1), 2 * cfg.n_experts,
                         PmqHyper::default()).unwrap();
        let b = allocate(&inputs, Allocator::Random(2), 2 * cfg.n_experts,
                         PmqHyper::default()).unwrap();
        assert_ne!(a.bits, b.bits);
    }

    #[test]
    fn rank_allocate_extremes() {
        let scores = vec![5.0, 4.0, 3.0, 2.0];
        assert_eq!(rank_allocate(&scores, 12), vec![3, 3, 3, 3]);
        assert_eq!(rank_allocate(&scores, 4), vec![1, 1, 1, 1]);
        let b = rank_allocate(&scores, 8);
        assert_eq!(b.iter().sum::<usize>(), 8);
        assert!(b[0] >= b[3]);
    }

    #[test]
    fn histogram_and_avg() {
        let cfg = ModelConfig::test_tiny();
        let mut a = Allocation::uniform(&cfg, 2);
        a.bits[0][0] = 3;
        a.bits[0][1] = 1;
        assert_eq!(a.avg_bits(), 2.0);
        let h = a.histogram();
        assert_eq!(h, [1, cfg.n_layers * cfg.n_experts - 2, 1]);
    }
}
