//! End-to-end PMQ workbench: build calibration + zoo + significance
//! once, then assemble compressed models for any (strategy, budget)
//! pair — the shape every sweep bench (Figs. 5-6, Tabs. 2-8) drives.

use anyhow::Result;

use crate::data::{calibration_set, Split};
use crate::moe::model::MoeModel;
use crate::moe::model::OdpPolicy;

use super::allocate::{allocate, AllocInputs, Allocation, Allocator, PmqHyper};
use super::calibrate::{calibrate, Calibration};
use super::significance::{probe_significance, Significance};
use super::zoo::{assemble, ExpertZoo, QuantBackend};

#[derive(Debug, Clone)]
pub struct WorkbenchConfig {
    /// calibration sequences (paper: 128 x 2048 tokens of C4)
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub calib_seed: u64,
    pub calib_split: Split,
    /// probe subset used for drop-F-norm / eps output probes
    pub probe_seqs: usize,
    pub backend: QuantBackend,
    /// bit-width for attention/gate weights (paper: 4)
    pub attn_bits: usize,
    /// use zoo reconstruction errors instead of output probes
    /// (faster; ablated in fig6 bench as "recon-proxy")
    pub fast_eps: bool,
}

impl Default for WorkbenchConfig {
    fn default() -> Self {
        WorkbenchConfig {
            calib_seqs: 8,
            calib_len: 256,
            calib_seed: 17,
            calib_split: Split::General,
            probe_seqs: 2,
            backend: QuantBackend::Gptq,
            attn_bits: 4,
            fast_eps: false,
        }
    }
}

/// Everything computed once per FP model.
pub struct Workbench {
    pub fp: MoeModel,
    pub cal: Calibration,
    pub zoo: ExpertZoo,
    pub sig: Significance,
    pub cfg: WorkbenchConfig,
}

impl Workbench {
    pub fn build(fp: MoeModel, cfg: WorkbenchConfig) -> Result<Workbench> {
        let seqs = calibration_set(cfg.calib_seed, cfg.calib_seqs,
                                   cfg.calib_len.min(fp.cfg.max_seq),
                                   cfg.calib_split);
        let cal = calibrate(&fp, &seqs);
        let zoo = ExpertZoo::build(&fp, &cal.hessians, cfg.backend)?;
        let sig = if cfg.fast_eps {
            Significance::from_recon_err(&cal, &zoo)
        } else {
            let n = cfg.probe_seqs.min(seqs.len());
            probe_significance(&fp, &zoo, &cal, &seqs[..n], &cal.base_logits[..n])
        };
        Ok(Workbench { fp, cal, zoo, sig, cfg })
    }

    /// Allocate a bit budget with `strategy` and assemble the model.
    pub fn compress(&self, strategy: Allocator, total_bits: usize,
                    hyper: PmqHyper) -> Result<(MoeModel, Allocation)> {
        let inputs = AllocInputs::new(&self.fp.cfg, &self.sig, &self.cal);
        let alloc = allocate(&inputs, strategy, total_bits, hyper)?;
        let model = assemble(&self.fp, &self.zoo, &alloc, &self.cal.hessians,
                             self.cfg.attn_bits)?;
        Ok((model, alloc))
    }

    /// Uniform-width baseline ("Uni" rows of Tab. 2).
    pub fn compress_uniform(&self, bits: usize) -> Result<MoeModel> {
        let alloc = Allocation::uniform(&self.fp.cfg, bits);
        assemble(&self.fp, &self.zoo, &alloc, &self.cal.hessians,
                 self.cfg.attn_bits)
    }

    /// The paper's default ODP policy calibrated on this workbench.
    pub fn odp_policy(&self, protect_ratio: f32) -> OdpPolicy {
        crate::odp::odp(&self.cal, protect_ratio)
    }

    /// Reported bit label, matching the paper's "Bits" column
    /// convention: the nominal expert average (e.g. 20/8 = 2.5); the
    /// exact storage-true value (incl. quantizer params + 4-bit
    /// attention) is available as `model.expert_avg_bits()`.
    pub fn bits_label(&self, alloc: &Allocation) -> f64 {
        alloc.avg_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::tests::random_model;

    fn bench_cfg() -> WorkbenchConfig {
        WorkbenchConfig {
            calib_seqs: 2,
            calib_len: 32,
            probe_seqs: 1,
            fast_eps: true,
            backend: QuantBackend::Rtn,
            ..Default::default()
        }
    }

    #[test]
    fn workbench_end_to_end() {
        let cfg = ModelConfig::test_tiny();
        let fp = random_model(&cfg, 0);
        let wb = Workbench::build(fp, bench_cfg()).unwrap();
        let n = cfg.n_experts;
        let (model, alloc) = wb
            .compress(Allocator::Pmq, 2 * n, PmqHyper::default())
            .unwrap();
        assert_eq!(alloc.avg_bits(), 2.0);
        let toks: Vec<u32> = (1..33).collect();
        assert!(model.score(&toks).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn different_budgets_different_sizes() {
        let cfg = ModelConfig::test_tiny();
        let fp = random_model(&cfg, 1);
        let wb = Workbench::build(fp, bench_cfg()).unwrap();
        let n = cfg.n_experts;
        let (m_low, _) = wb.compress(Allocator::Pmq, n + 2, PmqHyper::default()).unwrap();
        let (m_high, _) = wb.compress(Allocator::Pmq, 3 * n - 2, PmqHyper::default()).unwrap();
        assert!(m_low.storage_bytes() < m_high.storage_bytes());
    }

    #[test]
    fn odp_policy_from_workbench() {
        let cfg = ModelConfig::test_tiny();
        let fp = random_model(&cfg, 2);
        let wb = Workbench::build(fp, bench_cfg()).unwrap();
        match wb.odp_policy(0.02) {
            OdpPolicy::Protected { mu, protect_ratio } => {
                assert_eq!(mu.len(), cfg.n_layers);
                assert!((protect_ratio - 0.02).abs() < 1e-6);
            }
            other => panic!("unexpected policy {other:?}"),
        }
    }
}
