//! Calibration pass: one forward over the calibration set collecting
//! everything PMQ and ODP need (paper: "128 sets of random sequences"
//! from C4 — here the synthetic general split, see DESIGN.md §2):
//!   * routing statistics  -> significance factors phi_i, w_i
//!   * GPTQ Hessians       -> per-expert (and attention) quantizers
//!   * base logits         -> drop-F-norm / eps_{i,j} references
//!   * w1/w0 ratio samples -> ODP's per-layer median threshold mu

use std::collections::BTreeMap;

use crate::config::ModelConfig;
use crate::moe::model::{CalibSink, ForwardOpts, MoeModel, RunStats};
use crate::quant::gptq::Hessian;
use crate::tensor::Mat;

/// Hessians for every quantizable linear in the model.
pub struct HessianStore {
    /// [layer][expert] -> (input Hessian for w1/w3, mid Hessian for w2)
    pub experts: Vec<Vec<(Hessian, Hessian)>>,
    /// [layer] -> Hessian over attention inputs (wq/wk/wv)
    pub attn_in: Vec<Hessian>,
    /// [layer] -> Hessian over head outputs (wo)
    pub attn_out: Vec<Hessian>,
    /// [layer] -> Hessian over MoE inputs (gate)
    pub gate_in: Vec<Hessian>,
}

impl HessianStore {
    fn new(cfg: &ModelConfig) -> HessianStore {
        HessianStore {
            experts: (0..cfg.n_layers)
                .map(|_| {
                    (0..cfg.n_experts)
                        .map(|_| (Hessian::new(cfg.d_model), Hessian::new(cfg.d_ff)))
                        .collect()
                })
                .collect(),
            attn_in: (0..cfg.n_layers).map(|_| Hessian::new(cfg.d_model)).collect(),
            attn_out: (0..cfg.n_layers).map(|_| Hessian::new(cfg.d_model)).collect(),
            gate_in: (0..cfg.n_layers).map(|_| Hessian::new(cfg.d_model)).collect(),
        }
    }
}

struct Collector<'a> {
    hessians: &'a mut HessianStore,
}

impl CalibSink for Collector<'_> {
    fn expert_batch(&mut self, layer: usize, expert: usize, x: &Mat, gated: &Mat) {
        let (hin, hmid) = &mut self.hessians.experts[layer][expert];
        hin.update(x);
        hmid.update(gated);
    }

    fn attn_batch(&mut self, layer: usize, x: &Mat) {
        self.hessians.attn_in[layer].update(x);
    }

    fn attn_out_batch(&mut self, layer: usize, x: &Mat) {
        self.hessians.attn_out[layer].update(x);
    }

    fn moe_input(&mut self, layer: usize, x: &Mat) {
        self.hessians.gate_in[layer].update(x);
    }
}

pub struct Calibration {
    pub stats: RunStats,
    pub hessians: HessianStore,
    /// FP logits per calibration sequence (Eq.-3 reference output)
    pub base_logits: Vec<Mat>,
    /// per-layer w1/w0 ratio samples (ODP mu calibration)
    pub ratio_samples: Vec<Vec<f32>>,
    /// number of (seq) samples
    pub n_seqs: usize,
}

/// Run the calibration pass over `seqs` on the FP model.
pub fn calibrate(model: &MoeModel, seqs: &[Vec<u32>]) -> Calibration {
    let cfg = &model.cfg;
    let mut hessians = HessianStore::new(cfg);
    let mut stats = RunStats::new(cfg.n_layers, cfg.n_experts);
    let mut base_logits = Vec::with_capacity(seqs.len());
    let mut ratio_samples = vec![Vec::new(); cfg.n_layers];
    for seq in seqs {
        let mut sink = Collector { hessians: &mut hessians };
        let opts = ForwardOpts {
            collect_ratio_samples: true,
            ..Default::default()
        };
        let out = model.forward(seq, &opts, &mut sink);
        stats.merge(&out.stats);
        for (l, rs) in out.ratio_samples.into_iter().enumerate() {
            ratio_samples[l].extend(rs);
        }
        base_logits.push(out.logits);
    }
    Calibration {
        stats,
        hessians,
        base_logits,
        ratio_samples,
        n_seqs: seqs.len(),
    }
}

impl Calibration {
    /// phi_i: activation frequency of each expert (paper Sec. 3.2.1).
    pub fn phi(&self) -> Vec<Vec<f64>> {
        let n = self.stats.tokens_seen.max(1) as f64;
        self.stats
            .activation_counts
            .iter()
            .map(|layer| layer.iter().map(|&c| c as f64 / n).collect())
            .collect()
    }

    /// w_i: mean routing weight mass of each expert.
    pub fn weight(&self) -> Vec<Vec<f64>> {
        let n = self.stats.tokens_seen.max(1) as f64;
        self.stats
            .weight_sums
            .iter()
            .map(|layer| layer.iter().map(|&w| w / n).collect())
            .collect()
    }

    /// Per-layer median of w1/w0 (the paper's default ODP threshold).
    pub fn mu_median(&self) -> Vec<f32> {
        self.ratio_samples
            .iter()
            .map(|rs| crate::util::stats::median(rs))
            .collect()
    }

    /// Summary for serialization / the expert-analysis example.
    pub fn summary_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, Json};
        let to_arr2 = |v: &Vec<Vec<f64>>| {
            arr(v.iter().map(|row| arr(row.iter().map(|&x| num(x)))))
        };
        let mut m = BTreeMap::new();
        m.insert("phi".to_string(), to_arr2(&self.phi()));
        m.insert("weight".to_string(), to_arr2(&self.weight()));
        m.insert(
            "mu_median".to_string(),
            arr(self.mu_median().iter().map(|&x| num(x as f64))),
        );
        m.insert("tokens".to_string(), num(self.stats.tokens_seen as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{calibration_set, Split};
    use crate::moe::model::tests::random_model;

    fn tiny() -> (ModelConfig, MoeModel, Vec<Vec<u32>>) {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let seqs = calibration_set(1, 3, 32, Split::General);
        (cfg, model, seqs)
    }

    #[test]
    fn phi_sums_to_top_k() {
        let (cfg, model, seqs) = tiny();
        let cal = calibrate(&model, &seqs);
        for layer_phi in cal.phi() {
            let sum: f64 = layer_phi.iter().sum();
            assert!((sum - cfg.top_k as f64).abs() < 1e-9, "{sum}");
        }
    }

    #[test]
    fn weight_sums_to_one_per_token() {
        let (_, model, seqs) = tiny();
        let cal = calibrate(&model, &seqs);
        for layer_w in cal.weight() {
            let sum: f64 = layer_w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "{sum}");
        }
    }

    #[test]
    fn hessians_populated_for_activated_experts() {
        let (cfg, model, seqs) = tiny();
        let cal = calibrate(&model, &seqs);
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let activated = cal.stats.activation_counts[l][e] > 0;
                let (hin, _) = &cal.hessians.experts[l][e];
                assert_eq!(hin.n_samples > 0, activated, "layer {l} expert {e}");
            }
            assert!(cal.hessians.attn_in[l].n_samples > 0);
            assert!(cal.hessians.attn_out[l].n_samples > 0);
            assert!(cal.hessians.gate_in[l].n_samples > 0);
        }
    }

    #[test]
    fn mu_median_in_unit_interval() {
        let (_, model, seqs) = tiny();
        let cal = calibrate(&model, &seqs);
        for mu in cal.mu_median() {
            assert!((0.0..=1.0).contains(&mu), "mu {mu}");
        }
    }

    #[test]
    fn base_logits_per_sequence() {
        let (_, model, seqs) = tiny();
        let cal = calibrate(&model, &seqs);
        assert_eq!(cal.base_logits.len(), 3);
        assert_eq!(cal.base_logits[0].rows, 32);
    }
}
