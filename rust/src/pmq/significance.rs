//! Expert-significance probes (paper Sec. 3.2.1 / Eq. 3 / Fig. 3):
//!   * drop-F-norm: ‖F(θ) − F(θ \ e_i)‖_F — output change when expert
//!     e_i is removed from routing entirely (Fig. 3's red channel and
//!     the "F-norm" allocation baseline).
//!   * ε_{i,j}: ‖F(θ) − F(θ[e_i → Q(e_i, j)])‖_F — output change when
//!     only e_i is quantized to j bits (the Eq.-4 objective term).
//!
//! Probes run over a (small) probe subset of the calibration sequences;
//! both norms are averaged per token for scale stability.

use crate::moe::model::{ForwardOpts, MoeModel, NullSink};
use crate::tensor::Mat;

use super::calibrate::Calibration;
use super::zoo::ExpertZoo;

#[derive(Debug, Clone)]
pub struct Significance {
    /// activation frequency per [layer][expert]
    pub phi: Vec<Vec<f64>>,
    /// routing-weight mass per [layer][expert]
    pub weight: Vec<Vec<f64>>,
    /// expert-drop output F-norm per [layer][expert]
    pub drop_fnorm: Vec<Vec<f32>>,
    /// Eq.-3 quantization output error per [layer][expert][bits-1]
    pub eps: Vec<Vec<[f32; 3]>>,
}

fn output_delta(model: &MoeModel, seqs: &[Vec<u32>], base: &[Mat],
                opts: &ForwardOpts) -> f32 {
    let mut acc = 0.0f64;
    let mut toks = 0usize;
    for (seq, base_logits) in seqs.iter().zip(base) {
        let out = model.forward(seq, opts, &mut NullSink);
        acc += base_logits.sub(&out.logits).fro_norm() as f64;
        toks += seq.len();
    }
    (acc / toks.max(1) as f64) as f32
}

/// Run all probes. `probe_seqs` should be a small subset of the
/// calibration set (each expert×bit pair costs one forward per seq).
pub fn probe_significance(model: &MoeModel, zoo: &ExpertZoo,
                          cal: &Calibration, probe_seqs: &[Vec<u32>],
                          probe_base: &[Mat]) -> Significance {
    let cfg = &model.cfg;
    let mut drop_fnorm = vec![vec![0.0f32; cfg.n_experts]; cfg.n_layers];
    let mut eps = vec![vec![[0.0f32; 3]; cfg.n_experts]; cfg.n_layers];
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let opts = ForwardOpts {
                mask_expert: Some((l, e)),
                ..Default::default()
            };
            drop_fnorm[l][e] = output_delta(model, probe_seqs, probe_base, &opts);
            for bits in 1..=3usize {
                let repl = zoo.get(l, e, bits);
                let opts = ForwardOpts {
                    override_expert: Some((l, e, repl)),
                    ..Default::default()
                };
                eps[l][e][bits - 1] =
                    output_delta(model, probe_seqs, probe_base, &opts);
            }
        }
    }
    Significance {
        phi: cal.phi(),
        weight: cal.weight(),
        drop_fnorm,
        eps,
    }
}

impl Significance {
    /// Cheap proxy variant used by tests / fast paths: eps from the
    /// zoo's weight-space reconstruction errors instead of output
    /// probes (ablated in bench fig6).
    pub fn from_recon_err(cal: &Calibration, zoo: &ExpertZoo) -> Significance {
        let drop_fnorm = zoo
            .recon_err
            .iter()
            .map(|layer| layer.iter().map(|e| e[0]).collect())
            .collect();
        Significance {
            phi: cal.phi(),
            weight: cal.weight(),
            drop_fnorm,
            eps: zoo.recon_err.clone(),
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, Json};
        use std::collections::BTreeMap;
        let f64s = |v: &Vec<Vec<f64>>| {
            arr(v.iter().map(|r| arr(r.iter().map(|&x| num(x)))))
        };
        let f32s = |v: &Vec<Vec<f32>>| {
            arr(v.iter().map(|r| arr(r.iter().map(|&x| num(x as f64)))))
        };
        let mut m = BTreeMap::new();
        m.insert("phi".into(), f64s(&self.phi));
        m.insert("weight".into(), f64s(&self.weight));
        m.insert("drop_fnorm".into(), f32s(&self.drop_fnorm));
        m.insert(
            "eps".into(),
            arr(self.eps.iter().map(|layer| {
                arr(layer.iter().map(|e| arr(e.iter().map(|&x| num(x as f64)))))
            })),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{calibration_set, Split};
    use crate::moe::model::tests::random_model;
    use crate::pmq::calibrate::calibrate;
    use crate::pmq::zoo::{ExpertZoo, QuantBackend};

    #[test]
    fn eps_decreases_with_bits_and_drop_dominates() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 0);
        let seqs = calibration_set(3, 2, 24, Split::General);
        let cal = calibrate(&model, &seqs);
        let zoo = ExpertZoo::build(&model, &cal.hessians, QuantBackend::Gptq).unwrap();
        let sig = probe_significance(&model, &zoo, &cal, &seqs, &cal.base_logits);
        let mut monotone_pairs = 0;
        let mut total_pairs = 0;
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let [e1, e2, e3] = sig.eps[l][e];
                total_pairs += 2;
                monotone_pairs += (e1 >= e2) as usize + (e2 >= e3) as usize;
                // quantizing cannot hurt more than dropping the expert
                // outright (up to probe noise)
                if sig.drop_fnorm[l][e] > 1e-6 {
                    assert!(
                        e3 <= sig.drop_fnorm[l][e] * 1.5,
                        "l{l} e{e}: eps3 {e3} vs drop {}",
                        sig.drop_fnorm[l][e]
                    );
                }
            }
        }
        // eps ordering holds for the overwhelming majority of experts
        assert!(
            monotone_pairs as f64 >= 0.75 * total_pairs as f64,
            "{monotone_pairs}/{total_pairs}"
        );
    }

    #[test]
    fn unactivated_experts_have_zero_drop_norm() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 1);
        let seqs = calibration_set(4, 2, 24, Split::General);
        let cal = calibrate(&model, &seqs);
        let zoo = ExpertZoo::build(&model, &cal.hessians, QuantBackend::Rtn).unwrap();
        let sig = probe_significance(&model, &zoo, &cal, &seqs, &cal.base_logits);
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                if cal.stats.activation_counts[l][e] == 0 {
                    // an expert never routed to cannot change the output
                    // when quantized (dropping may reroute, so only eps)
                    assert!(sig.eps[l][e][0] < 1e-6);
                }
            }
        }
    }

    #[test]
    fn recon_err_proxy_available() {
        let cfg = ModelConfig::test_tiny();
        let model = random_model(&cfg, 2);
        let seqs = calibration_set(5, 2, 16, Split::General);
        let cal = calibrate(&model, &seqs);
        let zoo = ExpertZoo::build(&model, &cal.hessians, QuantBackend::Rtn).unwrap();
        let sig = Significance::from_recon_err(&cal, &zoo);
        assert_eq!(sig.eps.len(), cfg.n_layers);
        let j = sig.to_json().to_string();
        assert!(j.contains("drop_fnorm"));
    }
}
