//! GPTQ (Frantar et al. 2022) from scratch: Hessian-guided column-serial
//! quantization with error compensation — the paper's foundational PTQ
//! tool (Sec. 3.1).
//!
//! Orientation: weights are [K=in, N=out]; we quantize one *input row*
//! at a time (all N outputs share the Hessian over inputs), propagating
//! the quantization error to later input rows via the inverse-Hessian
//! Cholesky factor, exactly the official algorithm:
//!
//!   H    = 2 X Xᵀ + λI            (λ = 1% of mean diagonal)
//!   U    = chol_upper(H⁻¹)        (H⁻¹ = Uᵀ U)
//!   for k in 0..K:
//!       q_k   = quant(w_k)
//!       e     = (w_k - deq(q_k)) / U[k,k]
//!       W[j,:] -= U[k,j] · e      for j > k
//!
//! Group scales/zeros are refreshed at each GROUP_SIZE boundary from
//! the *current* (error-compensated) weights, as in GPTQ's group mode.
//! 1-bit rows binarize against fixed per-column scales so binarization
//! also benefits from compensation (PB-LLM-style).

use anyhow::{bail, Result};

use super::linear::effective_group;
use crate::tensor::Mat;

use super::binary::{binarize, BinaryTensor};
use super::linear::{dequantize_value, group_params, quantize_value, GroupParams};
use super::pack::{pack_levels, PackedTensor};
use super::QTensor;

// ---------------------------------------------------------------------------
// Hessian accumulation
// ---------------------------------------------------------------------------

/// Accumulates H = 2 Σ x xᵀ over calibration activations for one linear
/// layer with input dim K.
#[derive(Debug, Clone)]
pub struct Hessian {
    pub k: usize,
    pub h: Vec<f64>, // [K, K] row-major, f64 accumulation
    pub n_samples: usize,
}

impl Hessian {
    pub fn new(k: usize) -> Hessian {
        Hessian { k, h: vec![0.0; k * k], n_samples: 0 }
    }

    /// Add a batch of activation rows x[T, K].
    pub fn update(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.k);
        for t in 0..x.rows {
            let row = x.row(t);
            for i in 0..self.k {
                let xi = row[i] as f64 * 2.0;
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h[i * self.k..(i + 1) * self.k];
                for (j, &xj) in row.iter().enumerate() {
                    hrow[j] += xi * xj as f64;
                }
            }
        }
        self.n_samples += x.rows;
    }

    /// Mean diagonal (for damping and the HAWQ trace metric).
    pub fn diag_mean(&self) -> f64 {
        (0..self.k).map(|i| self.h[i * self.k + i]).sum::<f64>() / self.k as f64
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.k).map(|i| self.h[i * self.k + i]).collect()
    }
}

// ---------------------------------------------------------------------------
// Dense linear algebra (f64, K <= a few hundred)
// ---------------------------------------------------------------------------

/// In-place lower Cholesky: A = L Lᵀ. Returns Err if not PD.
fn cholesky_lower(a: &mut [f64], n: usize) -> Result<()> {
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= a[i * n + k] * a[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} ({sum})");
                }
                a[i * n + i] = sum.sqrt();
            } else {
                a[i * n + j] = sum / a[j * n + j];
            }
        }
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Given lower L (A = L Lᵀ), compute A⁻¹ (symmetric) via two triangular
/// solves against the identity.
fn inverse_from_cholesky(l: &[f64], n: usize) -> Vec<f64> {
    // forward solve L Y = I  (Y = L⁻¹, lower triangular)
    let mut y = vec![0.0; n * n];
    for col in 0..n {
        for i in col..n {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in col..i {
                sum -= l[i * n + k] * y[k * n + col];
            }
            y[i * n + col] = sum / l[i * n + i];
        }
    }
    // A⁻¹ = Yᵀ Y
    let mut inv = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0;
            for k in i.max(j)..n {
                sum += y[k * n + i] * y[k * n + j];
            }
            inv[i * n + j] = sum;
        }
    }
    inv
}

/// chol_upper(A): U with A = Uᵀ U (i.e. transpose of the lower factor).
fn cholesky_upper(mut a: Vec<f64>, n: usize) -> Result<Vec<f64>> {
    cholesky_lower(&mut a, n)?;
    let mut u = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = a[i * n + j];
        }
    }
    Ok(u)
}

// ---------------------------------------------------------------------------
// GPTQ core
// ---------------------------------------------------------------------------

pub struct GptqResult {
    pub tensor: QTensor,
    /// ||W - Wq||_F of the final (compensated) reconstruction vs original
    pub recon_err: f32,
}

/// Quantize w [K, N] at `bits` (1..=4) using Hessian `hess`.
pub fn gptq_quantize(w: &Mat, hess: &Hessian, bits: usize) -> Result<GptqResult> {
    assert_eq!(w.rows, hess.k);
    if bits == 16 {
        return Ok(GptqResult { tensor: QTensor::F32(w.clone()), recon_err: 0.0 });
    }
    let k = w.rows;
    let n = w.cols;

    // damped Hessian; escalate damping until PD
    let base_damp = 0.01 * hess.diag_mean().max(1e-8);
    let mut u = None;
    for attempt in 0..6 {
        let damp = base_damp * 10f64.powi(attempt);
        let mut h = hess.h.clone();
        for i in 0..k {
            h[i * k + i] += damp;
            // dead inputs (never activated): pin the diagonal
            if hess.h[i * k + i] == 0.0 {
                h[i * k + i] = 1.0;
            }
        }
        if cholesky_lower(&mut h.clone(), k).is_ok() {
            let mut hd = hess.h.clone();
            for i in 0..k {
                hd[i * k + i] += damp;
                if hess.h[i * k + i] == 0.0 {
                    hd[i * k + i] = 1.0;
                }
            }
            let mut l = hd;
            cholesky_lower(&mut l, k)?;
            let inv = inverse_from_cholesky(&l, k);
            u = Some(cholesky_upper(inv, k)?);
            break;
        }
    }
    let u = match u {
        Some(u) => u,
        None => bail!("Hessian not positive definite after damping escalation"),
    };

    let mut cur = w.clone(); // error-compensated working copy
    let mut levels = vec![0u32; k * n];
    let mut dq = Mat::zeros(k, n); // final dequantized weights

    // 1-bit: fixed per-column scales from the original weights
    let bin_scales = if bits == 1 {
        Some(binarize(w, false).scales)
    } else {
        None
    };

    let group = effective_group(k);
    let groups = k.div_ceil(group);
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    let mut params: Option<GroupParams> = None;

    for r in 0..k {
        if bits > 1 && r % group == 0 {
            // refresh quantizer params from the *compensated* weights
            let p = group_params(&cur, r, group, bits);
            let g = r / group;
            scales[g * n..(g + 1) * n].copy_from_slice(&p.scales);
            zeros[g * n..(g + 1) * n].copy_from_slice(&p.zeros);
            params = Some(p);
        }
        let ukk = u[r * k + r];
        for c in 0..n {
            let wv = cur.at(r, c);
            let dqv = if bits == 1 {
                let s = bin_scales.as_ref().unwrap()[c];
                if wv >= 0.0 {
                    levels[r * n + c] = 1;
                    s
                } else {
                    levels[r * n + c] = 0;
                    -s
                }
            } else {
                let p = params.as_ref().unwrap();
                let q = quantize_value(wv, p.scales[c], p.zeros[c], bits);
                levels[r * n + c] = q;
                dequantize_value(q, p.scales[c], p.zeros[c])
            };
            dq.set(r, c, dqv);
            // propagate scaled error to later rows
            let err = ((wv - dqv) as f64 / ukk) as f32;
            if err != 0.0 {
                for j in r + 1..k {
                    let urj = u[r * k + j] as f32;
                    if urj != 0.0 {
                        let v = cur.at(j, c) - urj * err;
                        cur.set(j, c, v);
                    }
                }
            }
        }
    }

    let recon_err = w.sub(&dq).fro_norm();
    let tensor = if bits == 1 {
        let mut bt = BinaryTensor {
            k,
            n,
            packed: vec![0u32; k.div_ceil(32) * n].into(),
            scales: bin_scales.unwrap(),
        };
        for r in 0..k {
            for c in 0..n {
                if levels[r * n + c] == 1 {
                    bt.packed[(r / 32) * n + c] |= 1 << (r % 32);
                }
            }
        }
        QTensor::Binary(bt)
    } else {
        QTensor::Packed(PackedTensor {
            bits,
            k,
            n,
            group,
            qweight: pack_levels(&levels, k, n, bits).into(),
            scales: scales.into(),
            zeros: zeros.into(),
        })
    };
    Ok(GptqResult { tensor, recon_err })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn calib_hessian(rng: &mut Rng, k: usize, t: usize) -> (Mat, Hessian) {
        let x = Mat::randn(rng, t, k, 1.0);
        let mut h = Hessian::new(k);
        h.update(&x);
        (x, h)
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(0);
        let n = 24;
        let a = Mat::randn(&mut rng, n, n, 1.0);
        // SPD matrix: A Aᵀ + n I
        let mut spd = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    s += (a.at(i, k) * a.at(j, k)) as f64;
                }
                spd[i * n + j] = s;
            }
        }
        let mut l = spd.clone();
        cholesky_lower(&mut l, n).unwrap();
        // L Lᵀ == A
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - spd[i * n + j]).abs() < 1e-8);
            }
        }
        // inverse correctness: A·A⁻¹ == I
        let inv = inverse_from_cholesky(&l, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += spd[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-6, "({i},{j}) {s}");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_lower(&mut a, 2).is_err());
    }

    #[test]
    fn gptq_beats_rtn_on_activation_loss() {
        // The whole point of GPTQ: lower ||XW - XWq||_F than plain RTN.
        let mut rng = Rng::new(1);
        let k = 128;
        let w = Mat::randn(&mut rng, k, 32, 1.0);
        // correlated inputs make compensation matter
        let base = Mat::randn(&mut rng, 256, k, 1.0);
        let mut x = base.clone();
        for r in 0..x.rows {
            for c in 0..k {
                let v = 0.7 * x.at(r, c) + 0.3 * base.at(r, (c + 1) % k);
                x.set(r, c, v);
            }
        }
        let mut h = Hessian::new(k);
        h.update(&x);
        for &bits in &[2usize, 3] {
            let g = gptq_quantize(&w, &h, bits).unwrap();
            let rtn = super::super::quantize_rtn(&w, bits);
            let ref_out = x.matmul(&w);
            let gptq_loss = ref_out.sub(&x.matmul(&g.tensor.dequantize())).fro_norm();
            let rtn_loss = ref_out.sub(&x.matmul(&rtn.dequantize())).fro_norm();
            assert!(
                gptq_loss < rtn_loss,
                "bits={bits}: gptq {gptq_loss} !< rtn {rtn_loss}"
            );
        }
    }

    #[test]
    fn gptq_binary_beats_plain_binarization() {
        let mut rng = Rng::new(2);
        let k = 64;
        let w = Mat::randn(&mut rng, k, 16, 1.0);
        let (x, h) = calib_hessian(&mut rng, k, 256);
        let g = gptq_quantize(&w, &h, 1).unwrap();
        let plain = binarize(&w, false);
        let ref_out = x.matmul(&w);
        let g_loss = ref_out.sub(&x.matmul(&g.tensor.dequantize())).fro_norm();
        let p_loss = ref_out.sub(&x.matmul(&plain.dequantize())).fro_norm();
        assert!(g_loss <= p_loss * 1.001, "gptq {g_loss} vs plain {p_loss}");
    }

    #[test]
    fn gptq_16bit_passthrough() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(&mut rng, 64, 8, 1.0);
        let h = Hessian::new(64);
        let g = gptq_quantize(&w, &h, 16).unwrap();
        assert_eq!(g.recon_err, 0.0);
        assert_eq!(g.tensor.dequantize(), w);
    }

    #[test]
    fn gptq_handles_degenerate_hessian() {
        // all-zero Hessian (no calibration data) must still quantize
        let mut rng = Rng::new(4);
        let w = Mat::randn(&mut rng, 64, 8, 1.0);
        let h = Hessian::new(64);
        let g = gptq_quantize(&w, &h, 2).unwrap();
        assert!(g.recon_err.is_finite());
    }

    #[test]
    fn hessian_diag_mean_positive() {
        let mut rng = Rng::new(5);
        let (_, h) = calib_hessian(&mut rng, 32, 64);
        assert!(h.diag_mean() > 0.0);
        assert_eq!(h.n_samples, 64);
    }
}
