//! Bit-packing (rust twin of `python/compile/kernels/packing.py`).
//!
//! Layout contract (little-endian u32 words, checked cross-language by
//! the golden-packing test in python/tests/test_parity.py):
//!   qweight[w, n] holds rows r = w*VPW + i of column n in bit-field
//!   [i*bits, (i+1)*bits); 3-bit packs 10 fields in the low 30 bits.

use crate::config::vals_per_word;
use crate::tensor::Mat;
use crate::util::alloc::AVec;

/// 2/3/4-bit group-wise packed tensor for a logical [K, N] weight.
#[derive(Debug, Clone)]
pub struct PackedTensor {
    pub bits: usize,
    pub k: usize,
    pub n: usize,
    /// quantization group length along K (min(GROUP_SIZE, K))
    pub group: usize,
    /// [k_words, n] row-major (64-byte aligned for the SIMD backends)
    pub qweight: AVec<u32>,
    /// [k/GROUP_SIZE, n] row-major
    pub scales: AVec<f32>,
    /// [k/GROUP_SIZE, n] row-major (float zero-points)
    pub zeros: AVec<f32>,
}

impl PackedTensor {
    pub fn k_words(&self) -> usize {
        let vpw = vals_per_word(self.bits);
        self.k.div_ceil(vpw)
    }

    pub fn groups(&self) -> usize {
        self.k / self.group
    }

    /// Integer level of element (r, c).
    #[inline]
    pub fn level(&self, r: usize, c: usize) -> u32 {
        let vpw = vals_per_word(self.bits);
        let word = self.qweight[(r / vpw) * self.n + c];
        let field = (r % vpw) * self.bits;
        (word >> field) & ((1u32 << self.bits) - 1)
    }

    /// Dequantized element (r, c).
    #[inline]
    pub fn weight(&self, r: usize, c: usize) -> f32 {
        let g = r / self.group;
        let q = self.level(r, c) as f32;
        (q - self.zeros[g * self.n + c]) * self.scales[g * self.n + c]
    }

    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.k, self.n);
        for r in 0..self.k {
            for c in 0..self.n {
                m.data[r * self.n + c] = self.weight(r, c);
            }
        }
        m
    }
}

/// Pack integer levels q[K, N] (row-major) into the word layout.
pub fn pack_levels(q: &[u32], k: usize, n: usize, bits: usize) -> Vec<u32> {
    assert_eq!(q.len(), k * n);
    let vpw = vals_per_word(bits);
    let k_words = k.div_ceil(vpw);
    let mut out = vec![0u32; k_words * n];
    for r in 0..k {
        let word = r / vpw;
        let field = (r % vpw) * bits;
        for c in 0..n {
            debug_assert!(q[r * n + c] < (1 << bits));
            out[word * n + c] |= q[r * n + c] << field;
        }
    }
    out
}

/// Unpack the word layout back to integer levels [K, N].
pub fn unpack_levels(packed: &[u32], k: usize, n: usize, bits: usize) -> Vec<u32> {
    let vpw = vals_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let mut out = vec![0u32; k * n];
    for r in 0..k {
        let word = r / vpw;
        let field = (r % vpw) * bits;
        for c in 0..n {
            out[r * n + c] = (packed[word * n + c] >> field) & mask;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for &bits in &[2usize, 3, 4] {
            for &(k, n) in &[(64usize, 8usize), (128, 16), (130, 5)] {
                let q: Vec<u32> = (0..k * n)
                    .map(|_| rng.below(1 << bits) as u32)
                    .collect();
                let packed = pack_levels(&q, k, n, bits);
                assert_eq!(unpack_levels(&packed, k, n, bits), q);
            }
        }
    }

    #[test]
    fn three_bit_top_bits_zero() {
        let mut rng = Rng::new(1);
        let q: Vec<u32> = (0..40 * 4).map(|_| rng.below(8) as u32).collect();
        let packed = pack_levels(&q, 40, 4, 3);
        for w in packed {
            assert_eq!(w >> 30, 0);
        }
    }

    #[test]
    fn matches_python_golden() {
        // golden vector produced by packing.pack_bits for
        // q = [[1,2],[3,0],[2,1],[0,3]] at 2 bits:
        // col0: 1 | 3<<2 | 2<<4 | 0<<6 = 0b00_10_11_01 = 0x2d
        // col1: 2 | 0<<2 | 1<<4 | 3<<6 = 0b11_01_00_10 = 0xd2
        let q = vec![1, 2, 3, 0, 2, 1, 0, 3];
        let packed = pack_levels(&q, 4, 2, 2);
        assert_eq!(packed, vec![0x2d, 0xd2]);
    }

    #[test]
    fn level_accessor_matches_unpack() {
        let mut rng = Rng::new(2);
        let (k, n, bits) = (128usize, 6usize, 3usize);
        let q: Vec<u32> = (0..k * n).map(|_| rng.below(8) as u32).collect();
        let t = PackedTensor {
            bits,
            k,
            n,
            group: crate::config::GROUP_SIZE,
            qweight: pack_levels(&q, k, n, bits).into(),
            scales: vec![1.0; (k / crate::config::GROUP_SIZE) * n].into(),
            zeros: vec![0.0; (k / crate::config::GROUP_SIZE) * n].into(),
        };
        for r in 0..k {
            for c in 0..n {
                assert_eq!(t.level(r, c), q[r * n + c]);
            }
        }
    }
}
