//! Group-wise asymmetric min/max quantizer (round-to-nearest baseline,
//! and the quantizer-parameter machinery GPTQ reuses).

use crate::config::GROUP_SIZE;
use crate::tensor::Mat;

use super::pack::{pack_levels, PackedTensor};

/// Per-group quantizer parameters for one group row of a [K, N] matrix.
#[derive(Debug, Clone)]
pub struct GroupParams {
    pub scales: Vec<f32>, // [n]
    pub zeros: Vec<f32>,  // [n]
}

/// Effective group length for a K-row matrix: min(GROUP_SIZE, K),
/// which must divide K.
pub fn effective_group(k: usize) -> usize {
    let g = GROUP_SIZE.min(k);
    assert_eq!(k % g, 0, "K={k} not divisible by group {g}");
    g
}

/// Compute asymmetric min/max params for rows [r0, r0+group) of w.
pub fn group_params(w: &Mat, r0: usize, group: usize, bits: usize) -> GroupParams {
    let qmax = ((1usize << bits) - 1) as f32;
    let n = w.cols;
    let mut lo = vec![f32::INFINITY; n];
    let mut hi = vec![f32::NEG_INFINITY; n];
    for r in r0..(r0 + group).min(w.rows) {
        for c in 0..n {
            let v = w.at(r, c);
            lo[c] = lo[c].min(v);
            hi[c] = hi[c].max(v);
        }
    }
    let mut scales = vec![0.0; n];
    let mut zeros = vec![0.0; n];
    for c in 0..n {
        scales[c] = ((hi[c] - lo[c]) / qmax).max(1e-8);
        zeros[c] = -lo[c] / scales[c];
    }
    GroupParams { scales, zeros }
}

/// Quantize one scalar with the given scale/zero at `bits`.
#[inline]
pub fn quantize_value(v: f32, scale: f32, zero: f32, bits: usize) -> u32 {
    let qmax = ((1usize << bits) - 1) as f32;
    (v / scale + zero).round().clamp(0.0, qmax) as u32
}

#[inline]
pub fn dequantize_value(q: u32, scale: f32, zero: f32) -> f32 {
    (q as f32 - zero) * scale
}

/// Full-matrix round-to-nearest group-wise quantization.
pub fn quantize_groupwise(w: &Mat, bits: usize) -> PackedTensor {
    let (k, n) = (w.rows, w.cols);
    let group = effective_group(k);
    let groups = k / group;
    let mut q = vec![0u32; k * n];
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    for g in 0..groups {
        let p = group_params(w, g * group, group, bits);
        scales[g * n..(g + 1) * n].copy_from_slice(&p.scales);
        zeros[g * n..(g + 1) * n].copy_from_slice(&p.zeros);
        for r in g * group..(g + 1) * group {
            for c in 0..n {
                q[r * n + c] = quantize_value(w.at(r, c), p.scales[c], p.zeros[c], bits);
            }
        }
    }
    PackedTensor {
        bits,
        k,
        n,
        group,
        qweight: pack_levels(&q, k, n, bits).into(),
        scales: scales.into(),
        zeros: zeros.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quant_error_bounded_by_half_scale() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(&mut rng, 128, 16, 1.0);
        for &bits in &[2usize, 3, 4] {
            let t = quantize_groupwise(&w, bits);
            let wq = t.dequantize();
            for r in 0..w.rows {
                let g = r / GROUP_SIZE;
                for c in 0..w.cols {
                    let err = (w.at(r, c) - wq.at(r, c)).abs();
                    let s = t.scales[g * w.cols + c];
                    assert!(err <= 0.5 * s + 1e-6, "bits={bits} err={err} s={s}");
                }
            }
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(&mut rng, 256, 32, 1.0);
        let errs: Vec<f32> = [2usize, 3, 4]
            .iter()
            .map(|&b| w.sub(&quantize_groupwise(&w, b).dequantize()).fro_norm())
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn extremes_reachable() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 4, 1.0);
        let t = quantize_groupwise(&w, 2);
        let levels = super::super::pack::unpack_levels(&t.qweight, 64, 4, 2);
        assert_eq!(*levels.iter().min().unwrap(), 0);
        assert_eq!(*levels.iter().max().unwrap(), 3);
    }

    #[test]
    fn bits_per_weight_accounting() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(&mut rng, 256, 64, 1.0);
        let t = super::super::QTensor::Packed(quantize_groupwise(&w, 2));
        // 2 bits + (scale+zero f32 per 64 elems) = 2 + 64/64 = 3 bits
        assert!((t.bits_per_weight() - 3.0).abs() < 0.01, "{}", t.bits_per_weight());
    }
}
