//! Packed dequant-matmul hot paths (the serving-time analogue of the
//! paper's HQQ CUDA kernels; EXPERIMENTS.md §Perf tracks these).
//!
//! Two regimes:
//!   * **small M (decode)** — fused word-decode kernel: each packed
//!     u32 is loaded once and all of its `vpw` fields are decoded in
//!     one shift/mask chain, combined with the group-factored form
//!       y_n = Σ_g s_gn · (Σ_{k∈g} x_k·q_kn) − s_gn·z_gn·(Σ_{k∈g} x_k)
//!     so scale/zero are applied once per group, not per element.
//!   * **large M (prefill)** — decode each weight row once into a
//!     scratch buffer and amortize across all activation rows; big
//!     shapes split output columns across the `WorkerPool` (strips are
//!     bit-exact with serial execution).
//!
//! The per-column inner loops (word decode, scale/zero application,
//! row dequant, binary masked-add) live in [`crate::kernels`] and are
//! dispatched through the runtime-selected ISA table; the `*_ops`
//! variants take the table explicitly for parity tests and benches.
//!
//! The `*_into` variants write into caller-owned buffers through
//! [`QmScratch`] so the decode loop runs allocation-free.

use crate::kernels::{self, KernelOps};
use crate::tensor::Mat;
use crate::util::pool::{SendPtr, WorkerPool};

use super::binary::BinaryTensor;
use super::pack::PackedTensor;

/// Reusable accumulators for the packed/binary kernels (one per
/// execution context: expert batch, session scratch, …).
#[derive(Debug, Default)]
pub struct QmScratch {
    /// per-column group accumulator (small-M packed kernel)
    acc: Vec<f32>,
    /// per-row activation sums (binary kernel)
    xsums: Vec<f32>,
    /// decoded weight row (large-M packed kernel, serial path)
    wrow: Vec<f32>,
    /// per-pool-task decoded strip rows (large-M pooled path) — kept
    /// here so pooled quantized GEMMs stay allocation-free in steady
    /// state, same as the serial path
    strips: Vec<Vec<f32>>,
}

impl QmScratch {
    pub fn new() -> QmScratch {
        QmScratch::default()
    }

    /// Pre-reserve for kernels up to `n_max` output columns and
    /// `rows_max` activation rows (buffer-pointer stability from the
    /// first call).
    pub fn reserve(&mut self, n_max: usize, rows_max: usize) {
        reserve_to(&mut self.acc, n_max);
        reserve_to(&mut self.wrow, n_max);
        reserve_to(&mut self.xsums, rows_max);
    }
}

fn reserve_to(v: &mut Vec<f32>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// FLOP volume below which a packed GEMM stays serial.
const QMM_PAR_MIN_FLOPS: usize = 2_000_000;
/// Minimum output-column strip width per pool task.
const QMM_MIN_STRIP: usize = 32;

/// y = x @ W for a packed 2/3/4-bit tensor (allocating wrapper).
pub fn packed_matmul(x: &Mat, w: &PackedTensor) -> Mat {
    let mut y = Mat::zeros(x.rows, w.n);
    let mut qs = QmScratch::new();
    packed_matmul_into(x, w, &mut y, &mut qs);
    y
}

/// y = x @ W into a reused buffer (resized + overwritten), on the
/// process-wide kernel backend.
pub fn packed_matmul_into(x: &Mat, w: &PackedTensor, y: &mut Mat,
                          qs: &mut QmScratch) {
    packed_matmul_into_ops(x, w, y, qs, kernels::active());
}

/// [`packed_matmul_into`] on an explicit kernel table.
pub fn packed_matmul_into_ops(x: &Mat, w: &PackedTensor, y: &mut Mat,
                              qs: &mut QmScratch, ops: &'static KernelOps) {
    assert_eq!(x.cols, w.k, "inner dim");
    y.resize_to(x.rows, w.n);
    y.data.fill(0.0);
    if x.rows <= 4 {
        packed_small_m_into(x, w, y, &mut qs.acc, ops);
    } else {
        packed_large_m_into(x, w, y, qs, ops);
    }
}

/// Fused decode kernel: every u32 of the weight row is loaded and
/// decoded exactly once per activation row via `ops.packed_word_acc`
/// (the pre-fusion kernel re-masked it once per k). Group edges that
/// fall inside a word (3-bit: 10 fields per word vs group 64) pass a
/// non-zero in-word shift.
fn packed_small_m_into(x: &Mat, w: &PackedTensor, y: &mut Mat,
                       acc: &mut Vec<f32>, ops: &'static KernelOps) {
    let n = w.n;
    let vpw = crate::config::vals_per_word(w.bits);
    let groups = w.k / w.group;
    acc.resize(n, 0.0);
    for m in 0..x.rows {
        let xrow = x.row(m);
        let yrow = &mut y.data[m * n..(m + 1) * n];
        for g in 0..groups {
            let k0 = g * w.group;
            let k1 = k0 + w.group;
            acc.fill(0.0);
            let xsum: f32 = xrow[k0..k1].iter().sum();
            let mut k = k0;
            while k < k1 {
                let wi = k / vpw;
                let j0 = k % vpw;
                let jn = (vpw - j0).min(k1 - k);
                let word_row = &w.qweight[wi * n..(wi + 1) * n];
                (ops.packed_word_acc)(
                    &mut acc[..],
                    word_row,
                    &xrow[k..k + jn],
                    (j0 * w.bits) as u32,
                    w.bits as u32,
                );
                k += jn;
            }
            let srow = &w.scales[g * n..(g + 1) * n];
            let zrow = &w.zeros[g * n..(g + 1) * n];
            (ops.packed_scale_apply)(yrow, &acc[..], srow, zrow, xsum);
        }
    }
}

fn packed_large_m_into(x: &Mat, w: &PackedTensor, y: &mut Mat,
                       qs: &mut QmScratch, ops: &'static KernelOps) {
    let n = w.n;
    let pool = WorkerPool::global();
    let flops = 2 * x.rows * w.k * n;
    let tasks = pool.width().min(n / QMM_MIN_STRIP);
    if flops >= QMM_PAR_MIN_FLOPS && tasks >= 2 && !WorkerPool::on_worker() {
        while qs.strips.len() < tasks {
            qs.strips.push(Vec::new());
        }
        let ybase = SendPtr(y.data.as_mut_ptr());
        let sbase = SendPtr(qs.strips.as_mut_ptr());
        pool.for_each(tasks, move |t| {
            let (c0, c1) = WorkerPool::strip(n, tasks, t);
            // Safety: task t exclusively owns strip buffer t and the
            // disjoint column range [c0, c1) of y.
            let strip_row = unsafe { &mut *sbase.0.add(t) };
            strip_row.resize(c1 - c0, 0.0);
            unsafe {
                packed_large_m_cols(x, w, ybase.0, c0, c1, strip_row, ops)
            };
        });
    } else {
        qs.wrow.resize(n, 0.0);
        // Safety: exclusive access to all of y.
        unsafe {
            packed_large_m_cols(x, w, y.data.as_mut_ptr(), 0, n,
                                &mut qs.wrow, ops)
        };
    }
}

/// Row-decode kernel over output columns [c0, c1): decode weight row r
/// once into `wrow` (`ops.packed_dequant_row`), then axpy into every
/// activation row. Caller guarantees `ybase` points at a [x.rows, w.n]
/// row-major buffer and concurrent calls use disjoint column ranges.
unsafe fn packed_large_m_cols(x: &Mat, w: &PackedTensor, ybase: *mut f32,
                              c0: usize, c1: usize, wrow: &mut [f32],
                              ops: &'static KernelOps) {
    let n = w.n;
    let cw = c1 - c0;
    if cw == 0 {
        return;
    }
    let vpw = crate::config::vals_per_word(w.bits);
    for r in 0..w.k {
        let word_row = &w.qweight[(r / vpw) * n + c0..(r / vpw) * n + c1];
        let field = ((r % vpw) * w.bits) as u32;
        let g = r / w.group;
        let srow = &w.scales[g * n + c0..g * n + c1];
        let zrow = &w.zeros[g * n + c0..g * n + c1];
        (ops.packed_dequant_row)(&mut wrow[..cw], word_row, srow, zrow,
                                 field, w.bits as u32);
        for m in 0..x.rows {
            let yrow = std::slice::from_raw_parts_mut(ybase.add(m * n + c0), cw);
            (ops.axpy)(yrow, &wrow[..cw], x.at(m, r));
        }
    }
}

/// y = x @ W for a binary tensor (allocating wrapper).
pub fn binary_matmul(x: &Mat, w: &BinaryTensor) -> Mat {
    let mut y = Mat::zeros(x.rows, w.n);
    let mut qs = QmScratch::new();
    binary_matmul_into(x, w, &mut y, &mut qs);
    y
}

/// y = x @ W for a binary tensor, word-unrolled: each packed u32 is
/// loaded once and its 32 sign bits decoded in one masked-add chain
/// (`ops.binary_word_acc`): acc_n = Σ_{bit=1} x_k, then y_n =
/// s_n·(2·acc_n − Σx) — one fma per element (paper Eq. 10;
/// kernels/binary_matmul.py). Runs on the process-wide backend.
pub fn binary_matmul_into(x: &Mat, w: &BinaryTensor, y: &mut Mat,
                          qs: &mut QmScratch) {
    binary_matmul_into_ops(x, w, y, qs, kernels::active());
}

/// [`binary_matmul_into`] on an explicit kernel table.
pub fn binary_matmul_into_ops(x: &Mat, w: &BinaryTensor, y: &mut Mat,
                              qs: &mut QmScratch, ops: &'static KernelOps) {
    assert_eq!(x.cols, w.k, "inner dim");
    let n = w.n;
    y.resize_to(x.rows, n);
    y.data.fill(0.0);
    qs.xsums.resize(x.rows, 0.0);
    for (m, xs) in qs.xsums.iter_mut().enumerate() {
        *xs = x.row(m).iter().sum();
    }
    let k_words = w.k.div_ceil(32);
    for wi in 0..k_words {
        let k0 = wi * 32;
        let kn = 32.min(w.k - k0);
        let word_row = &w.packed[wi * n..(wi + 1) * n];
        for m in 0..x.rows {
            let xs = &x.row(m)[k0..k0 + kn];
            let yrow = &mut y.data[m * n..(m + 1) * n];
            (ops.binary_word_acc)(yrow, word_row, xs);
        }
    }
    for m in 0..x.rows {
        let xs = qs.xsums[m];
        let yrow = &mut y.data[m * n..(m + 1) * n];
        (ops.binary_scale_apply)(yrow, &w.scales[..], xs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binary::binarize;
    use crate::quant::linear::quantize_groupwise;
    use crate::util::rng::Rng;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn packed_matmul_matches_dense_dequant() {
        let mut rng = Rng::new(0);
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, 128, 32, 1.0);
            let t = quantize_groupwise(&w, bits);
            let x = Mat::randn(&mut rng, 5, 128, 1.0);
            let fast = packed_matmul(&x, &t);
            let slow = x.matmul(&t.dequantize());
            assert_close(&fast, &slow, 1e-4);
        }
    }

    #[test]
    fn binary_matmul_matches_dense_dequant() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(&mut rng, 96, 24, 1.0);
        let b = binarize(&w, false);
        let x = Mat::randn(&mut rng, 4, 96, 1.0);
        assert_close(&binary_matmul(&x, &b), &x.matmul(&b.dequantize()), 1e-4);
    }

    #[test]
    fn binary_partial_word_tail() {
        // K = 50: the last word holds only 18 valid bits
        let mut rng = Rng::new(5);
        let w = Mat::randn(&mut rng, 50, 12, 1.0);
        let b = binarize(&w, false);
        let x = Mat::randn(&mut rng, 3, 50, 1.0);
        assert_close(&binary_matmul(&x, &b), &x.matmul(&b.dequantize()), 1e-4);
    }

    #[test]
    fn single_row_decode_path() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 16, 1.0);
        let t = quantize_groupwise(&w, 3);
        let x = Mat::randn(&mut rng, 1, 64, 1.0);
        assert_close(&packed_matmul(&x, &t), &x.matmul(&t.dequantize()), 1e-4);
    }

    #[test]
    fn into_variant_reuses_buffers() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(&mut rng, 128, 24, 1.0);
        let t = quantize_groupwise(&w, 4);
        let x = Mat::randn(&mut rng, 2, 128, 1.0);
        let mut y = Mat::zeros(0, 0);
        let mut qs = QmScratch::new();
        packed_matmul_into(&x, &t, &mut y, &mut qs);
        let (yp, ap) = (y.data.as_ptr(), qs.acc.as_ptr());
        let first = y.clone();
        packed_matmul_into(&x, &t, &mut y, &mut qs);
        assert_eq!(y.data.as_ptr(), yp, "steady-state y must not realloc");
        assert_eq!(qs.acc.as_ptr(), ap, "steady-state acc must not realloc");
        assert_eq!(y.data, first.data);
    }
}

#[cfg(test)]
mod perf_path_tests {
    use super::*;
    use crate::quant::linear::quantize_groupwise;
    use crate::util::rng::Rng;

    #[test]
    fn small_and_large_m_paths_agree() {
        let mut rng = Rng::new(7);
        let ops = kernels::active();
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, 128, 48, 1.0);
            let t = quantize_groupwise(&w, bits);
            for m in [1usize, 3, 4] {
                let x = Mat::randn(&mut rng, m, 128, 1.0);
                let mut small = Mat::zeros(0, 0);
                let mut qs = QmScratch::new();
                packed_small_m_into_for_test(&x, &t, &mut small, &mut qs, ops);
                let mut large = Mat::zeros(x.rows, t.n);
                packed_large_m_into(&x, &t, &mut large, &mut qs, ops);
                for (a, b) in small.data.iter().zip(&large.data) {
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()),
                            "bits={bits} m={m}: {a} vs {b}");
                }
            }
        }
    }

    fn packed_small_m_into_for_test(x: &Mat, w: &PackedTensor, y: &mut Mat,
                                    qs: &mut QmScratch,
                                    ops: &'static KernelOps) {
        y.resize_to(x.rows, w.n);
        y.data.fill(0.0);
        packed_small_m_into(x, w, y, &mut qs.acc, ops);
    }
}
