//! Packed dequant-matmul hot paths (the serving-time analogue of the
//! paper's HQQ CUDA kernels; EXPERIMENTS.md §Perf tracks these).
//!
//! Strategy ("ikj" with row-decode): for each input row k, decode the
//! packed weight row once into a stack buffer, then axpy into all
//! output rows. The f32 weight row never hits the heap and the decode
//! cost is amortized across the M activation rows.

use crate::tensor::Mat;

use super::binary::BinaryTensor;
use super::pack::PackedTensor;

/// y = x @ W for a packed 2/3/4-bit tensor.
///
/// Two regimes (EXPERIMENTS.md §Perf):
///   * small M (decode): group-factored form — per group g,
///       y_n = Σ_g s_gn · (Σ_{k∈g} x_k·q_kn) − s_gn·z_gn·(Σ_{k∈g} x_k)
///     so the inner loop is one shift/mask + fma per element (no
///     per-element scale/zero), and the scale/zero are applied once
///     per group.
///   * large M (prefill): decode each weight row once into a stack
///     buffer and amortize across all activation rows.
pub fn packed_matmul(x: &Mat, w: &PackedTensor) -> Mat {
    if x.rows <= 4 {
        packed_matmul_small_m(x, w)
    } else {
        packed_matmul_large_m(x, w)
    }
}

fn packed_matmul_small_m(x: &Mat, w: &PackedTensor) -> Mat {
    let n = w.n;
    assert_eq!(x.cols, w.k, "inner dim");
    let vpw = crate::config::vals_per_word(w.bits);
    let mask = (1u32 << w.bits) - 1;
    let groups = w.k / w.group;
    let mut y = Mat::zeros(x.rows, n);
    let mut acc = vec![0.0f32; n];
    for m in 0..x.rows {
        let xrow = x.row(m);
        let yrow = &mut y.data[m * n..(m + 1) * n];
        for g in 0..groups {
            acc.fill(0.0);
            let mut xsum = 0.0f32;
            for k in g * w.group..(g + 1) * w.group {
                let xv = xrow[k];
                if xv == 0.0 {
                    continue;
                }
                xsum += xv;
                let word_row = &w.qweight[(k / vpw) * n..(k / vpw + 1) * n];
                let field = ((k % vpw) * w.bits) as u32;
                for (a, &word) in acc.iter_mut().zip(word_row) {
                    // integer level scaled later: one fma per element
                    *a += xv * ((word >> field) & mask) as f32;
                }
            }
            let srow = &w.scales[g * n..(g + 1) * n];
            let zrow = &w.zeros[g * n..(g + 1) * n];
            for c in 0..n {
                yrow[c] += srow[c] * (acc[c] - zrow[c] * xsum);
            }
        }
    }
    y
}

fn packed_matmul_large_m(x: &Mat, w: &PackedTensor) -> Mat {
    let n = w.n;
    assert_eq!(x.cols, w.k, "inner dim");
    let vpw = crate::config::vals_per_word(w.bits);
    let mask = (1u32 << w.bits) - 1;
    let mut y = Mat::zeros(x.rows, n);
    let mut wrow = vec![0.0f32; n];
    for r in 0..w.k {
        // decode row r: contiguous word row + per-group scale/zero rows
        let word_row = &w.qweight[(r / vpw) * n..(r / vpw + 1) * n];
        let field = ((r % vpw) * w.bits) as u32;
        let g = r / w.group;
        let srow = &w.scales[g * n..(g + 1) * n];
        let zrow = &w.zeros[g * n..(g + 1) * n];
        for c in 0..n {
            let q = (word_row[c] >> field) & mask;
            wrow[c] = (q as f32 - zrow[c]) * srow[c];
        }
        // axpy into each activation row
        for m in 0..x.rows {
            let xv = x.at(m, r);
            if xv == 0.0 {
                continue;
            }
            let yrow = &mut y.data[m * n..(m + 1) * n];
            for (yv, &wv) in yrow.iter_mut().zip(wrow.iter()) {
                *yv += xv * wv;
            }
        }
    }
    y
}

/// y = x @ W for a binary tensor: accumulate signed sums then apply the
/// per-column scale once (paper Eq. 10 restated; see
/// kernels/binary_matmul.py for the algebraic identity).
pub fn binary_matmul(x: &Mat, w: &BinaryTensor) -> Mat {
    assert_eq!(x.cols, w.k, "inner dim");
    let n = w.n;
    let mut acc = Mat::zeros(x.rows, n);
    // masked-add form: acc_n = Σ_{bit=1} x_k, then
    // y_n = s_n * (2·acc_n − Σ x) — one fma per element in the hot loop
    // instead of the sign-select multiply (EXPERIMENTS.md §Perf).
    let mut xsums = vec![0.0f32; x.rows];
    for (m, xs) in xsums.iter_mut().enumerate() {
        *xs = x.row(m).iter().sum();
    }
    for r in 0..w.k {
        let word_row = &w.packed[(r / 32) * n..(r / 32 + 1) * n];
        let bit = (r % 32) as u32;
        for m in 0..x.rows {
            let xv = x.at(m, r);
            if xv == 0.0 {
                continue;
            }
            let yrow = &mut acc.data[m * n..(m + 1) * n];
            for (yv, &word) in yrow.iter_mut().zip(word_row) {
                *yv += xv * ((word >> bit) & 1) as f32;
            }
        }
    }
    for m in 0..x.rows {
        let xs = xsums[m];
        let yrow = &mut acc.data[m * n..(m + 1) * n];
        for (yv, &s) in yrow.iter_mut().zip(w.scales.iter()) {
            *yv = s * (2.0 * *yv - xs);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binary::binarize;
    use crate::quant::linear::quantize_groupwise;
    use crate::util::rng::Rng;

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn packed_matmul_matches_dense_dequant() {
        let mut rng = Rng::new(0);
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, 128, 32, 1.0);
            let t = quantize_groupwise(&w, bits);
            let x = Mat::randn(&mut rng, 5, 128, 1.0);
            let fast = packed_matmul(&x, &t);
            let slow = x.matmul(&t.dequantize());
            assert_close(&fast, &slow, 1e-4);
        }
    }

    #[test]
    fn binary_matmul_matches_dense_dequant() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(&mut rng, 96, 24, 1.0);
        let b = binarize(&w, false);
        let x = Mat::randn(&mut rng, 4, 96, 1.0);
        assert_close(&binary_matmul(&x, &b), &x.matmul(&b.dequantize()), 1e-4);
    }

    #[test]
    fn single_row_decode_path() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 16, 1.0);
        let t = quantize_groupwise(&w, 3);
        let x = Mat::randn(&mut rng, 1, 64, 1.0);
        assert_close(&packed_matmul(&x, &t), &x.matmul(&t.dequantize()), 1e-4);
    }
}

#[cfg(test)]
mod perf_path_tests {
    use super::*;
    use crate::quant::linear::quantize_groupwise;
    use crate::util::rng::Rng;

    #[test]
    fn small_and_large_m_paths_agree() {
        let mut rng = Rng::new(7);
        for &bits in &[2usize, 3, 4] {
            let w = Mat::randn(&mut rng, 128, 48, 1.0);
            let t = quantize_groupwise(&w, bits);
            for m in [1usize, 3, 4] {
                let x = Mat::randn(&mut rng, m, 128, 1.0);
                let small = packed_matmul_small_m(&x, &t);
                let large = packed_matmul_large_m(&x, &t);
                for (a, b) in small.data.iter().zip(&large.data) {
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()),
                            "bits={bits} m={m}: {a} vs {b}");
                }
            }
        }
    }
}
