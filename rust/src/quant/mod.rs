//! Quantization substrate: packing, group-wise linear quantization,
//! GPTQ (Hessian error compensation), 1-bit binarization (paper
//! Eqs. 7-10), the packed dequant-matmul hot path, and an
//! OmniQuant-style clipped quantizer (Tab. 8's backend swap).

pub mod binary;
pub mod gptq;
pub mod linear;
pub mod lwc;
pub mod pack;
pub mod qmatmul;

use crate::tensor::Mat;

pub use binary::BinaryTensor;
pub use pack::PackedTensor;
pub use qmatmul::QmScratch;

/// A weight matrix in any representation the engine can matmul with.
#[derive(Debug, Clone)]
pub enum QTensor {
    F32(Mat),
    /// 2/3/4-bit group-wise packed
    Packed(PackedTensor),
    /// 1-bit sign + per-column scale
    Binary(BinaryTensor),
}

impl QTensor {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            QTensor::F32(m) => (m.rows, m.cols),
            QTensor::Packed(p) => (p.k, p.n),
            QTensor::Binary(b) => (b.k, b.n),
        }
    }

    /// Effective storage bits per weight element (incl. quantizer params),
    /// the quantity the paper's "Bits" column reports.
    pub fn bits_per_weight(&self) -> f64 {
        let (k, n) = self.shape();
        let elems = (k * n) as f64;
        (self.storage_bytes() as f64) * 8.0 / elems
    }

    /// Bytes needed to store this tensor (packed words + scales/zeros).
    pub fn storage_bytes(&self) -> usize {
        match self {
            QTensor::F32(m) => m.data.len() * 4,
            QTensor::Packed(p) => {
                p.qweight.len() * 4 + p.scales.len() * 4 + p.zeros.len() * 4
            }
            QTensor::Binary(b) => b.packed.len() * 4 + b.scales.len() * 4,
        }
    }

    /// Dense reconstruction (tests / reconstruction-error measurement).
    pub fn dequantize(&self) -> Mat {
        match self {
            QTensor::F32(m) => m.clone(),
            QTensor::Packed(p) => p.dequantize(),
            QTensor::Binary(b) => b.dequantize(),
        }
    }

    /// y = x @ W via the representation-specific hot path.
    pub fn matmul(&self, x: &Mat) -> Mat {
        match self {
            QTensor::F32(m) => x.matmul(m),
            QTensor::Packed(p) => qmatmul::packed_matmul(x, p),
            QTensor::Binary(b) => qmatmul::binary_matmul(x, b),
        }
    }

    /// y = x @ W into a reused buffer (resized + overwritten), with
    /// kernel scratch from `qs` — the zero-allocation decode path.
    pub fn matmul_into(&self, x: &Mat, y: &mut Mat, qs: &mut QmScratch) {
        match self {
            QTensor::F32(m) => crate::tensor::matmul_reset_into(x, m, y),
            QTensor::Packed(p) => qmatmul::packed_matmul_into(x, p, y, qs),
            QTensor::Binary(b) => qmatmul::binary_matmul_into(x, b, y, qs),
        }
    }
}

/// Quantize a dense matrix to `bits` (1..=4, 16 = keep f32) with plain
/// round-to-nearest (the non-GPTQ baseline).
pub fn quantize_rtn(w: &Mat, bits: usize) -> QTensor {
    match bits {
        16 => QTensor::F32(w.clone()),
        1 => QTensor::Binary(binary::binarize(w, false)),
        2..=4 => QTensor::Packed(linear::quantize_groupwise(w, bits)),
        _ => panic!("unsupported bit-width {bits}"),
    }
}
