//! 1-bit binarization with the bit-change transform (paper Eqs. 7-10,
//! Appendix A.2): btilde = (sign(w)+1)/2 packed 32 rows per u32 word,
//! reconstruction w = (2*btilde - 1) * s.
//!
//! Scale: per output column s_c = mean |w[:, c]| (XNOR-Net per-filter
//! analogue; DESIGN.md) or the paper's literal scalar
//! s = ||W||_1/(d*m) via `scalar_scale = true`.

use crate::tensor::Mat;
use crate::util::alloc::AVec;

#[derive(Debug, Clone)]
pub struct BinaryTensor {
    pub k: usize,
    pub n: usize,
    /// [k_words, n] row-major; bit i of word w = row w*32+i
    /// (64-byte aligned for the SIMD backends)
    pub packed: AVec<u32>,
    /// per-column scale [n]
    pub scales: AVec<f32>,
}

impl BinaryTensor {
    pub fn k_words(&self) -> usize {
        self.k.div_ceil(32)
    }

    /// Sign bit of element (r, c): true => +1.
    #[inline]
    pub fn bit(&self, r: usize, c: usize) -> bool {
        (self.packed[(r / 32) * self.n + c] >> (r % 32)) & 1 == 1
    }

    #[inline]
    pub fn weight(&self, r: usize, c: usize) -> f32 {
        if self.bit(r, c) {
            self.scales[c]
        } else {
            -self.scales[c]
        }
    }

    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.k, self.n);
        for r in 0..self.k {
            for c in 0..self.n {
                m.data[r * self.n + c] = self.weight(r, c);
            }
        }
        m
    }
}

/// Binarize a dense [K, N] matrix.
pub fn binarize(w: &Mat, scalar_scale: bool) -> BinaryTensor {
    let (k, n) = (w.rows, w.cols);
    let mut scales = vec![0.0f32; n];
    if scalar_scale {
        let s = w.data.iter().map(|v| v.abs()).sum::<f32>() / (k * n) as f32;
        scales.fill(s);
    } else {
        for c in 0..n {
            let mut acc = 0.0;
            for r in 0..k {
                acc += w.at(r, c).abs();
            }
            scales[c] = acc / k as f32;
        }
    }
    let k_words = k.div_ceil(32);
    let mut packed = vec![0u32; k_words * n];
    for r in 0..k {
        for c in 0..n {
            if w.at(r, c) >= 0.0 {
                packed[(r / 32) * n + c] |= 1 << (r % 32);
            }
        }
    }
    BinaryTensor { k, n, packed: packed.into(), scales: scales.into() }
}

/// Binarize a single row given fixed column scales (used inside the
/// GPTQ column loop so binarization benefits from error compensation).
pub fn binarize_row(row: &[f32], scales: &[f32], out: &mut [f32]) {
    for (c, (&v, &s)) in row.iter().zip(scales).enumerate() {
        out[c] = if v >= 0.0 { s } else { -s };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn signs_preserved() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(&mut rng, 96, 16, 1.0);
        let b = binarize(&w, false);
        let wr = b.dequantize();
        for r in 0..96 {
            for c in 0..16 {
                let want = if w.at(r, c) >= 0.0 { 1.0 } else { -1.0 };
                assert_eq!(wr.at(r, c).signum(), want);
            }
        }
    }

    #[test]
    fn column_scale_is_mean_abs() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(&mut rng, 64, 8, 2.0);
        let b = binarize(&w, false);
        for c in 0..8 {
            let mean: f32 = (0..64).map(|r| w.at(r, c).abs()).sum::<f32>() / 64.0;
            assert!((b.scales[c] - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn scalar_scale_matches_paper_formula() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(&mut rng, 64, 8, 1.0);
        let b = binarize(&w, true);
        let expected = w.data.iter().map(|v| v.abs()).sum::<f32>() / (64.0 * 8.0);
        for &s in &b.scales {
            assert!((s - expected).abs() < 1e-6);
        }
    }

    #[test]
    fn non_multiple_of_32_rows() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(&mut rng, 50, 4, 1.0);
        let b = binarize(&w, false);
        assert_eq!(b.k_words(), 2);
        let wr = b.dequantize();
        assert_eq!(wr.rows, 50);
        for r in 0..50 {
            for c in 0..4 {
                assert_eq!(wr.at(r, c) >= 0.0, w.at(r, c) >= 0.0);
            }
        }
    }

    #[test]
    fn binarization_is_best_scaled_sign_approx() {
        // per-column mean |w| minimizes ||w - s*sign(w)||^2 over s
        let mut rng = Rng::new(4);
        let w = Mat::randn(&mut rng, 128, 4, 1.0);
        let b = binarize(&w, false);
        let base = w.sub(&b.dequantize()).fro_norm();
        for &delta in &[0.9f32, 1.1] {
            let mut b2 = b.clone();
            for s in b2.scales.iter_mut() {
                *s *= delta;
            }
            assert!(w.sub(&b2.dequantize()).fro_norm() >= base);
        }
    }
}
